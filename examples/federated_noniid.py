"""The paper's headline experiment, miniaturized: every registered codec on
non-iid federated data (every client holds TWO classes), CNN on a synthetic
CIFAR-shaped task.

    PYTHONPATH=src python examples/federated_noniid.py [--rounds 40]
    PYTHONPATH=src python examples/federated_noniid.py --protocols stc ternquant

Protocols come from the codec registry (`repro.core.registered_protocols`),
so a codec registered by third-party code shows up here with no changes.
"""

import argparse
import time

from repro.core import make_protocol, registered_protocols
from repro.data import make_image_classification
from repro.fed import FedEnvironment, FederatedTrainer, TrainerConfig
from repro.models.paper_models import MODEL_ZOO

# demo-sized hyperparameter overrides (the registry defaults are the paper's
# full-scale settings: p=1/400, n=400 local iterations)
DEMO_OVERRIDES = {
    "stc": dict(sparsity_up=1 / 50, sparsity_down=1 / 50),
    "topk": dict(sparsity_up=1 / 50),
    "fedavg": dict(local_iters=10),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--model", default="cnn", choices=("cnn", "mlp", "logreg",
                                                       "lstm"))
    ap.add_argument("--classes-per-client", type=int, default=2)
    ap.add_argument("--protocols", nargs="+", default=None,
                    metavar="NAME", help="codec names to run (default: every "
                    f"registered codec: {', '.join(registered_protocols())})")
    ap.add_argument("--chunks", default=None,
                    help="chunked (layer, chunk) codec states: an int chunk "
                         "size, or 'whole' for the single whole-vector chunk "
                         "(bit-identical to the flat path)")
    args = ap.parse_args()
    chunks = None
    if args.chunks is not None:
        chunks = args.chunks if args.chunks == "whole" else int(args.chunks)

    if args.model == "lstm":
        from repro.data import make_sequence_classification
        train, test = make_sequence_classification(seed=0, n=6000)
    elif args.model == "cnn":
        train, test = make_image_classification(seed=0, n=6000)
    else:
        from repro.data import make_classification
        train, test = make_classification(seed=0, n=6000)

    env = FedEnvironment(n_clients=10, participation=1.0,
                         classes_per_client=args.classes_per_client,
                         batch_size=20)
    print(f"model={args.model}  clients=10/10  "
          f"classes/client={args.classes_per_client}")
    print(f"{'method':>10s} {'acc':>6s} {'upMB':>9s} {'downMB':>9s} "
          f"{'iters':>6s} {'time':>5s}")

    for pname in args.protocols or registered_protocols():
        proto = make_protocol(pname, **DEMO_OVERRIDES.get(pname, {}))
        # a delay-period codec (fedavg) does local_iters work per round
        rounds = max(args.rounds // proto.local_iters, 1)
        t0 = time.time()
        tr = FederatedTrainer(MODEL_ZOO[args.model], train, test, env, proto,
                              TrainerConfig(lr=0.05, chunks=chunks))
        h = tr.run(rounds, eval_every=rounds)[-1]
        print(f"{pname:>10s} {h['acc']:6.3f} {h['bits_up']/8e6:9.2f} "
              f"{h['bits_down']/8e6:9.2f} {h['iterations']:6d} "
              f"{time.time()-t0:4.0f}s")

    print("\nexpected (paper): STC matches/beats the others at a fraction "
          "of the bits; signSGD degrades hardest under non-iid.")


if __name__ == "__main__":
    main()
