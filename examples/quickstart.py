"""Quickstart: the STC compression operator in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Codec, decode_ternary, decode_ternary_words,
                        encode_ternary, encode_ternary_words,
                        golomb_position_bits, make_protocol,
                        register_protocol, registered_protocols, stc_compress,
                        stc_message_bits)

# --- 1. compress a "weight update" with Sparse Ternary Compression ----------
key = jax.random.PRNGKey(0)
update = jax.random.normal(key, (100_000,))
p = 1 / 400

tern, stats = stc_compress(update, p)
print(f"STC @ p=1/400: kept {int(stats.nnz)} / {update.size} entries, "
      f"µ = {float(stats.mu):.4f}")
print(f"unique values: {np.unique(np.asarray(tern))[:5]}")

# --- 2. what does it cost on the wire? (Golomb-coded positions + sign bits) -
bits = stc_message_bits(update.size, p)
print(f"message size: {bits/8/1024:.2f} KiB "
      f"(dense fp32 would be {update.size*4/1024:.0f} KiB -> "
      f"x{update.size*32/bits:.0f} compression)")
print(f"bits per position (Eq. 17): {golomb_position_bits(p):.2f}")

# --- 3. the REAL bitstream (Algorithms 3 & 4), roundtripped ------------------
# vectorized packer (core.wire) -- what the trainers' measured ledger uses
msg = encode_ternary_words(np.asarray(tern), p)
restored = decode_ternary_words(msg, p)
assert np.allclose(restored, np.asarray(tern), atol=1e-6)
# ... and it is bit-identical to the per-bit oracle codec (Algorithm 3)
payload, bit_len, mu, n = encode_ternary(np.asarray(tern), p)
assert msg.bit_len == bit_len
assert np.array_equal(msg.payload_bytes(), payload)
assert np.allclose(decode_ternary(payload, bit_len, mu, n, p), restored)
print(f"bitstream: {msg.bit_len} bits measured "
      f"(analytic expectation {stc_message_bits(update.size, p) - 32:.0f}), "
      f"roundtrip exact: True")

# --- 4. error feedback: nothing is ever lost ---------------------------------
proto = make_protocol("stc", sparsity_up=p, sparsity_down=p)
state = proto.init_client_state(update.size)
msg, state, _ = proto.encode(update, state)
recon = msg + state.residual
assert np.allclose(np.asarray(recon), np.asarray(update), rtol=1e-5)
print("error feedback: msg + residual == update (exact)")

# --- 5. protocols are pluggable codecs: register your own -------------------
@register_protocol
@dataclasses.dataclass(frozen=True)
class RoundToHalf(Codec):
    """Toy codec: snap every coordinate to a multiple of `step`."""
    name = "round0.5"
    step: float = 0.5

    def encode(self, delta, state):
        msg = self.step * jnp.round(delta / self.step)
        return msg, state, None

    def upload_bits(self, numel):
        return 8.0 * numel                       # one int8 symbol per weight

    def download_bits(self, numel, n_participating=1):
        return 8.0 * numel

toy = make_protocol("round0.5")
msg, _, _ = toy.encode(update, None)
print(f"registered codecs: {registered_protocols()}")
print(f"custom codec kept {len(np.unique(np.asarray(msg)))} distinct values "
      f"at {toy.upload_bits(update.size)/8/1024:.0f} KiB/message")
