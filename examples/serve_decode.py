"""Serving example: prefill a prompt then decode tokens with the KV/state
cache, for any of the 10 assigned architectures (reduced variant on CPU).

    PYTHONPATH=src python examples/serve_decode.py [--arch mamba2-370m]
                                                   [--tokens 32]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import (decode_step, encode_frames, init_cache,
                          init_model)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    b = args.batch

    prompt = jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab_size)
    memory = None
    if cfg.encoder is not None:
        frames = jax.random.normal(key, (b, cfg.encoder.n_frames,
                                         cfg.d_model)) * 0.1
        memory = encode_frames(params, cfg, frames)
        print(f"encoded {cfg.encoder.n_frames} audio frames")

    # --- prefill by teacher-forcing the prompt through decode steps ---------
    caches = init_cache(cfg, b, args.prompt_len + args.tokens + 1,
                        jnp.float32)
    dstep = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c, memory=memory,
                                                compute_dtype=jnp.float32))
    logits = None
    for t in range(args.prompt_len):
        logits, caches = dstep(params, prompt[:, t : t + 1], caches)

    # --- greedy decode -------------------------------------------------------
    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.tokens):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, caches = dstep(params, tok, caches)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    dt = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name}  batch={b}  generated {args.tokens} tokens/seq")
    print(f"first sequence: {gen[0][:16]} ...")
    print(f"decode throughput: {b*args.tokens/dt:.1f} tok/s "
          f"({1e3*dt/args.tokens:.1f} ms/step) on CPU (untuned)")


if __name__ == "__main__":
    main()
