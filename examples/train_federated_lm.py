"""End-to-end driver: federated STC training of a transformer LM on the
distributed train_step (shard_map over client axes, GSPMD tensor parallelism),
on a debug mesh of fake CPU devices.

    PYTHONPATH=src python examples/train_federated_lm.py \
        [--arch smollm-135m] [--steps 200] [--protocol stc] [--full]

Default trains a reduced (~10M-param) variant of the chosen architecture for a
few hundred steps on synthetic token data -- small enough for CPU, while
exercising the REAL production code path (the same make_train_step the
512-chip dry-run lowers).  --full uses the full assigned config (TPU-sized).
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import make_lm_tokens
from repro.launch.mesh import make_debug_mesh
from repro.launch.train import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    from repro.core.protocols import get_protocol_class, registered_protocols
    ap.add_argument("--protocol", default="stc",
                    choices=registered_protocols())
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (TPU-sized)")
    ap.add_argument("--eval-every", type=int, default=25)
    args = ap.parse_args()

    mesh = make_debug_mesh(data=2, model=2)
    n_clients = 2

    if args.full:
        cfg = get_config(args.arch)
    else:
        # reduced variant: same family, a few more layers than the smoke
        # config (keeps head/dim divisibility of the family intact)
        smoke = get_smoke_config(args.arch)
        cfg = dataclasses.replace(smoke, n_layers=min(smoke.n_layers * 2, 6))
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"protocol={args.protocol} mesh={dict(mesh.shape)}")

    # demo-scale communication delay: cap the codec's default period at 4
    delay = min(get_protocol_class(args.protocol)().local_iters, 4)
    tc = TrainConfig(protocol=args.protocol, lr=args.lr,
                     sparsity_up=1 / 100, sparsity_down=1 / 100,
                     local_iters=delay)
    state = init_train_state(cfg, tc, n_clients=n_clients,
                             key=jax.random.PRNGKey(0))
    step = make_train_step(cfg, mesh, tc)

    tokens = make_lm_tokens(seed=0, n_tokens=1 << 22, vocab=cfg.vocab_size)
    rng = np.random.default_rng(0)

    def sample_batch():
        b, s = args.batch, args.seq
        starts = rng.integers(0, len(tokens) - s - 1, size=b)
        toks = np.stack([tokens[i : i + s] for i in starts])
        labs = np.stack([tokens[i + 1 : i + s + 1] for i in starts])
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}
        if cfg.encoder is not None:
            batch["frames"] = jnp.zeros((b, cfg.encoder.n_frames, cfg.d_model),
                                        jnp.float32)
        if cfg.n_prefix_tokens:
            batch["prefix"] = jnp.zeros((b, cfg.n_prefix_tokens, cfg.d_model),
                                        jnp.float32)
        return batch

    t0 = time.time()
    losses = []
    for i in range(args.steps):
        state, metrics = step(state, sample_batch())
        losses.append(float(metrics["loss"]))
        if (i + 1) % args.eval_every == 0 or i == 0:
            window = np.mean(losses[-args.eval_every:])
            extras = {k: int(v) for k, v in metrics.items() if k != "loss"}
            print(f"step {i+1:4d}  loss {window:.4f}  {extras}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")

    print(f"\nfinal loss {np.mean(losses[-20:]):.4f} "
          f"(started {np.mean(losses[:5]):.4f}) in {time.time()-t0:.0f}s")
    assert np.mean(losses[-20:]) < np.mean(losses[:5]), "training must learn"


if __name__ == "__main__":
    main()
