"""recurrentgemma-2b [hybrid] -- Griffin/RecurrentGemma (arXiv:2402.19427).

Assigned: 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
Pattern: RG-LRU + local attention, 1:2 (two recurrent blocks per local-attn
block), local window 2048.  Sub-quadratic by construction -> runs long_500k
natively (recurrent state + ring-buffer window cache).
"""

from repro.models.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "local"),
    sliding_window=2048,
    rglru=RGLRUConfig(d_conv=4, c=8.0),
    mlp_act="gelu",
    tie_embeddings=True,
)

LONG_CONFIG = CONFIG  # natively sub-quadratic

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    arch_type="hybrid",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=1,
    d_ff=256,
    vocab_size=512,
    head_dim=32,
    block_pattern=("rglru", "rglru", "local"),
    sliding_window=16,
    rglru=RGLRUConfig(d_conv=4, c=8.0),
    mlp_act="gelu",
    tie_embeddings=True,
    remat=False,
)
