"""deepseek-v2-lite-16b [moe] -- DeepSeek-V2-Lite (arXiv:2405.04434).

Assigned: 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE 64 experts top-6, MLA kv_lora=512, 2 shared experts.
(The release-card 160-routed-expert variant is noted in DESIGN.md; the
assignment's 64e figure is canonical here.)  Attention is MLA, so the GQA
kv=16 figure is subsumed by the latent cache.
"""

import dataclasses

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,                      # dense first layer FFN (V2-Lite)
    vocab_size=102400,
    head_dim=128,
    block_pattern=("mla",),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                  first_dense=1),
    rope_theta=10000.0,
)

# sliding-window variant for long_500k (sub-quadratic requirement)
LONG_CONFIG = dataclasses.replace(CONFIG, sliding_window=8192)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-16b-smoke",
    arch_type="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    head_dim=32,
    block_pattern=("mla",),
    mla=MLAConfig(kv_lora_rank=64, qk_nope_head_dim=32, qk_rope_head_dim=16,
                  v_head_dim=32),
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_expert=64,
                  first_dense=1),
    remat=False,
)
