"""Architecture registry: ``--arch <id>`` resolution + ShapeDtypeStruct
input specs per (architecture, input shape).

``long_500k`` resolves each arch's LONG_CONFIG (sliding-window variant for
full-attention archs; identity for SSM/hybrid). Coverage decisions are
documented in DESIGN.md §Decode-shape coverage.
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

from .shapes import INPUT_SHAPES, InputShape

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "input_specs",
           "INPUT_SHAPES"]

_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "smollm-135m": "smollm_135m",
    "qwen2-0.5b": "qwen2_0_5b",
    "whisper-medium": "whisper_medium",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mamba2-370m": "mamba2_370m",
    "phi3-medium-14b": "phi3_medium_14b",
    "internvl2-2b": "internvl2_2b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; options: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str, shape: str | InputShape | None = None) -> ModelConfig:
    """Full config; resolves the LONG_CONFIG variant for long_500k."""
    mod = _module(arch)
    if shape is not None:
        name = shape if isinstance(shape, str) else shape.name
        if name == "long_500k":
            return mod.LONG_CONFIG
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def input_specs(cfg: ModelConfig, shape: InputShape | str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train:   tokens + labels (B, S) int32  [+ frames / prefix embeddings]
    prefill: tokens (B, S)                 [+ frames / prefix embeddings]
    decode:  token (B, 1)                  [+ encoder memory for enc-dec]
    """
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.bfloat16

    specs: dict = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    else:  # decode: one new token against a seq_len cache
        specs["token"] = jax.ShapeDtypeStruct((b, 1), i32)

    if cfg.encoder is not None:
        if shape.kind == "decode":
            # decoder attends to the precomputed encoder memory
            specs["memory"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder.n_frames, cfg.d_model), f32)
        else:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder.n_frames, cfg.d_model), f32)
    if cfg.n_prefix_tokens and shape.kind != "decode":
        specs["prefix"] = jax.ShapeDtypeStruct(
            (b, cfg.n_prefix_tokens, cfg.d_model), f32)
    return specs
