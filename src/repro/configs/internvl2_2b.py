"""internvl2-2b [vlm] -- InternVL2-2B (arXiv:2404.16821).

Assigned: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
The InternViT vision encoder + projector are a STUB per the carve-out:
``input_specs()`` provides (batch, 256, d_model) patch embeddings which a
learned projector maps into the InternLM2-style decoder's prefix.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    arch_type="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    block_pattern=("attn",),
    n_prefix_tokens=256,
)

LONG_CONFIG = dataclasses.replace(CONFIG, sliding_window=8192)

SMOKE = ModelConfig(
    name="internvl2-2b-smoke",
    arch_type="vlm",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    block_pattern=("attn",),
    n_prefix_tokens=8,
    remat=False,
)
