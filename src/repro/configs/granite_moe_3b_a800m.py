"""granite-moe-3b-a800m [moe] -- IBM Granite MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base family].

Assigned: 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155,
MoE 40 experts top-8.
"""

import dataclasses

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    block_pattern=("attn",),
    moe=MoEConfig(n_experts=40, top_k=8, n_shared=0, d_expert=512,
                  first_dense=0),
    tie_embeddings=True,
)

LONG_CONFIG = dataclasses.replace(CONFIG, sliding_window=8192)

SMOKE = ModelConfig(
    name="granite-moe-3b-a800m-smoke",
    arch_type="moe",
    n_layers=2,
    d_model=96,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=512,
    block_pattern=("attn",),
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_expert=64,
                  first_dense=0),
    tie_embeddings=True,
    remat=False,
)
