"""phi3-medium-14b [dense] -- Phi-3 Medium (arXiv:2404.14219). RoPE SwiGLU GQA.

Assigned: 40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    arch_type="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    block_pattern=("attn",),
)

LONG_CONFIG = dataclasses.replace(CONFIG, sliding_window=8192)

SMOKE = ModelConfig(
    name="phi3-medium-14b-smoke",
    arch_type="dense",
    n_layers=2,
    d_model=160,
    n_heads=4,
    n_kv_heads=2,
    d_ff=384,
    vocab_size=512,
    block_pattern=("attn",),
    remat=False,
)
