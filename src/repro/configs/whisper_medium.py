"""whisper-medium [audio] -- Whisper (arXiv:2212.04356), enc-dec backbone.

Assigned: 24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
The mel-spectrogram + conv frontend is a STUB per the carve-out:
``input_specs()`` provides (batch, 1500, d_model) frame embeddings; the full
24-layer bidirectional encoder + 24-layer causal decoder with cross-attention
are implemented.  RoPE replaces Whisper's learned positions (TPU adaptation,
noted in DESIGN.md).
"""

import dataclasses

from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    block_pattern=("attn",),
    mlp_act="gelu",
    encoder=EncoderConfig(n_layers=24, n_frames=1500),
)

LONG_CONFIG = dataclasses.replace(CONFIG, sliding_window=8192)

SMOKE = ModelConfig(
    name="whisper-medium-smoke",
    arch_type="audio",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    block_pattern=("attn",),
    mlp_act="gelu",
    encoder=EncoderConfig(n_layers=2, n_frames=30),
    remat=False,
)
