"""Selectable architecture configs (``--arch <id>``) + input shapes."""

from .registry import (ARCH_IDS, INPUT_SHAPES, get_config, get_smoke_config,
                       input_specs)
from .shapes import InputShape

__all__ = ["ARCH_IDS", "INPUT_SHAPES", "get_config", "get_smoke_config",
           "input_specs", "InputShape"]
