"""moonshot-v1-16b-a3b -- Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

Assigned: 48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840,
MoE 64 experts top-6.  The assignment tags it [dense] but specifies a MoE
layout ("MoE?"); Moonlight *is* a DeepSeek-V3-style MoE, so it is built as a
GQA-attention MoE with 2 shared experts (DESIGN.md §Arch-applicability).
"""

import dataclasses

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    arch_type="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=11264,                      # dense first layer
    vocab_size=163840,
    block_pattern=("attn",),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                  first_dense=1),
    rope_theta=50000.0,
)

LONG_CONFIG = dataclasses.replace(CONFIG, sliding_window=8192)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke",
    arch_type="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    block_pattern=("attn",),
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_expert=64,
                  first_dense=1),
    remat=False,
)
