"""smollm-135m [dense] -- [hf:HuggingFaceTB/SmolLM-135M], llama-arch small.

Assigned: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    arch_type="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    block_pattern=("attn",),
    tie_embeddings=True,
)

LONG_CONFIG = dataclasses.replace(CONFIG, sliding_window=8192)

SMOKE = ModelConfig(
    name="smollm-135m-smoke",
    arch_type="dense",
    n_layers=2,
    d_model=96,
    n_heads=3,
    n_kv_heads=1,
    d_ff=256,
    vocab_size=512,
    block_pattern=("attn",),
    tie_embeddings=True,
    remat=False,
)
