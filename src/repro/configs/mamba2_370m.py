"""mamba2-370m [ssm] -- Mamba-2 SSD (arXiv:2405.21060). Attention-free.

Assigned: 48L d_model=1024 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
Sub-quadratic (O(1) recurrent state) -> runs long_500k natively.
STC applies unchanged (gradient-space; DESIGN.md §Arch-applicability).
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("ssd",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    tie_embeddings=True,
)

LONG_CONFIG = CONFIG  # natively sub-quadratic

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    arch_type="ssm",
    n_layers=2,
    d_model=128,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=512,
    block_pattern=("ssd",),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, n_groups=1,
                  chunk=8),
    tie_embeddings=True,
    remat=False,
)
