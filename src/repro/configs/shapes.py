"""The four assigned input shapes and their ShapeDtypeStruct input specs.

Decode shapes (decode_32k, long_500k) lower ``serve_step`` -- one new token
with a KV/state cache of ``seq_len`` -- not ``train_step``.  long_500k
requires sub-quadratic attention: SSM/hybrid run natively; full-attention
archs run their sliding-window variant (see configs.registry / DESIGN.md).
"""

from __future__ import annotations

import dataclasses

__all__ = ["InputShape", "INPUT_SHAPES"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
