"""qwen2-0.5b [dense] -- Qwen2-0.5B (arXiv:2407.10671). GQA with QKV bias.

Assigned: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    block_pattern=("attn",),
    attn_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

LONG_CONFIG = dataclasses.replace(CONFIG, sliding_window=8192)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke",
    arch_type="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    block_pattern=("attn",),
    attn_bias=True,
    tie_embeddings=True,
    remat=False,
)
