"""One string-or-instance resolution helper for every registry in the repo.

The codebase has grown a family of name -> class registries -- protocols
(:mod:`repro.core.protocols`), aggregation rules
(:mod:`repro.core.aggregation`), fleet scenarios
(:mod:`repro.fed.scenarios`), fault models (:mod:`repro.fed.faults`) and
client samplers (:mod:`repro.fed.sampling`).  Each used to hand-roll the
same two snippets: "unknown name" error formatting, and the
``make_x(v) if isinstance(v, str) else v`` dance wherever a driver accepts
either a registered name or an already-built instance.  This module is the
single implementation both snippets share, so every registry reports
unknown names identically (a ``KeyError`` listing the registered names) and
every ``make_*`` factory accepts instances as pass-throughs.

Registries keep owning their own dicts and ``register_*`` decorators (the
registration side is already uniform); only the *resolution* side funnels
through here::

    def make_scenario(scenario, **overrides):
        return resolve("scenario", scenario, _REGISTRY, Scenario,
                       **overrides)
"""

from __future__ import annotations

from typing import Callable, Mapping, Optional

__all__ = ["lookup", "resolve"]


def lookup(kind: str, name: str, registry: Mapping[str, type]) -> type:
    """The class registered under ``name``, or a KeyError naming ``kind``
    and listing every registered name (sorted) -- the one error message
    every registry in the repo raises for a typo'd name."""
    if name not in registry:
        raise KeyError(
            f"unknown {kind} {name!r}; registered: "
            f"{', '.join(sorted(registry))}")
    return registry[name]


def resolve(kind: str, value, registry: Mapping[str, type], base: type, *,
            instantiate: Optional[Callable] = None, **overrides):
    """Resolve ``value`` -- a registered name or an already-built instance
    of ``base`` -- into an instance.

    A string is looked up via :func:`lookup` and instantiated as
    ``cls(**overrides)`` (or through ``instantiate(cls, overrides)`` when a
    factory needs custom kwarg handling, e.g. ``make_protocol``'s legacy
    field filtering).  An instance passes through untouched; combining an
    instance with overrides is ambiguous and raises, as does any other
    type.
    """
    if isinstance(value, base):
        if overrides:
            raise TypeError(
                f"cannot apply overrides {sorted(overrides)} to an "
                f"already-constructed {kind} instance; pass a registered "
                f"name, or build the instance with those values directly")
        return value
    if not isinstance(value, str):
        raise TypeError(
            f"{kind} must be a registered name or a {base.__name__} "
            f"instance, got {type(value).__name__}")
    cls = lookup(kind, value, registry)
    if instantiate is not None:
        return instantiate(cls, overrides)
    return cls(**overrides)
