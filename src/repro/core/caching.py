"""Server-side partial-sum cache for partial client participation (Sec. V-B).

The server keeps the last ``τ`` compressed global updates
``{ΔW~^(T-1), ..., ΔW~^(T-τ)}`` and their partial sums
``P^(s) = Σ_{t=1..s} ΔW~^(T-t)``.  A client that skipped ``s`` rounds
downloads ``P^(s)`` (one message) instead of replaying ``s`` updates; a client
that skipped more than ``τ`` rounds downloads the full model ``W^(T)``.

Entropy bound (Eq. 13): H(P^(τ)) <= τ·H(ΔW~), i.e. download size grows at most
linearly in the number of skipped rounds -- we account bits accordingly.
"""

from __future__ import annotations

import collections
from typing import Deque, Optional

import numpy as np

__all__ = ["UpdateCache"]


class UpdateCache:
    """Host-side ring buffer of global updates + lazily materialized partials.

    ``partial_sum`` answers from a cached cumulative sum over the stacked
    ring buffer (one vectorized ``np.add.accumulate``, no Python
    accumulation loop), grown lazily to the deepest staleness actually
    queried -- so a cohort of repeated queries costs O(1) each, and memory
    stays bounded by the worst staleness seen, not ``max_rounds``.
    """

    def __init__(self, numel: int, max_rounds: int = 32) -> None:
        self.numel = numel
        self.max_rounds = max_rounds
        self._updates: Deque[np.ndarray] = collections.deque(maxlen=max_rounds)
        self._cum: Optional[np.ndarray] = None   # (depth, numel) prefix sums
        self.round = 0

    def push(self, update: np.ndarray) -> None:
        self._updates.appendleft(np.asarray(update, dtype=np.float32).reshape(-1))
        self._cum = None                          # invalidate prefix cache
        self.round += 1

    def _prefix_sums(self, depth: int) -> np.ndarray:
        """(>= depth, numel) rows with row s-1 = P^(s), newest update first."""
        have = 0 if self._cum is None else self._cum.shape[0]
        if have < depth:
            extra = np.stack([self._updates[t] for t in range(have, depth)])
            np.add.accumulate(extra, axis=0, out=extra)
            if have:
                extra += self._cum[-1]
                self._cum = np.concatenate([self._cum, extra])
            else:
                self._cum = extra
        return self._cum

    def partial_sum(self, skipped: int) -> Optional[np.ndarray]:
        """P^(s): the sum of the last ``skipped`` updates, or None if too stale."""
        if skipped == 0:
            return np.zeros(self.numel, dtype=np.float32)
        if skipped > len(self._updates):
            return None  # caller must download the full model
        return self._prefix_sums(skipped)[skipped - 1].copy()

    def sync_bits(self, skipped: int, bits_per_update: float, model_bits: float) -> float:
        """Download cost for a client that skipped ``skipped`` rounds (Eq. 13).

        ``bits_per_update`` may be the analytic expectation OR the measured
        wire size of this round's update (see ``Codec.measured_download_bits``)
        -- the Eq. 13 bound H(P^(s)) <= s*H(ΔW~) is applied either way.
        """
        if skipped > len(self._updates):
            return model_bits
        # The partial sum of s sparse updates has at most s-times the nnz;
        # H(P^(s)) <= s * H(ΔW~) is attained in the worst case (disjoint masks).
        return max(1, skipped) * bits_per_update

    def sync_bits_batch(self, skipped, bits_per_update: float,
                        model_bits: float) -> float:
        """Total download cost for a cohort: vectorized ``sync_bits`` over an
        integer array of per-client skipped-round counts."""
        skipped = np.asarray(skipped, dtype=np.int64)
        per_client = np.where(
            skipped > len(self._updates), model_bits,
            np.maximum(skipped, 1).astype(np.float64) * bits_per_update)
        return float(per_client.sum())
