"""Server-side partial-sum cache for partial client participation (Sec. V-B).

The server keeps the last ``τ`` compressed global updates
``{ΔW~^(T-1), ..., ΔW~^(T-τ)}`` and their partial sums
``P^(s) = Σ_{t=1..s} ΔW~^(T-t)``.  A client that skipped ``s`` rounds
downloads ``P^(s)`` (one message) instead of replaying ``s`` updates; a client
that skipped more than ``τ`` rounds downloads the full model ``W^(T)``.

Entropy bound (Eq. 13): H(P^(τ)) <= τ·H(ΔW~), i.e. download size grows at most
linearly in the number of skipped rounds -- we account bits accordingly.
"""

from __future__ import annotations

import collections
from typing import Deque, Optional

import numpy as np

__all__ = ["UpdateCache"]


class UpdateCache:
    """Host-side ring buffer of global updates + lazily materialized partials."""

    def __init__(self, numel: int, max_rounds: int = 32) -> None:
        self.numel = numel
        self.max_rounds = max_rounds
        self._updates: Deque[np.ndarray] = collections.deque(maxlen=max_rounds)
        self.round = 0

    def push(self, update: np.ndarray) -> None:
        self._updates.appendleft(np.asarray(update, dtype=np.float32).reshape(-1))
        self.round += 1

    def partial_sum(self, skipped: int) -> Optional[np.ndarray]:
        """P^(s): the sum of the last ``skipped`` updates, or None if too stale."""
        if skipped == 0:
            return np.zeros(self.numel, dtype=np.float32)
        if skipped > len(self._updates):
            return None  # caller must download the full model
        out = np.zeros(self.numel, dtype=np.float32)
        for t in range(skipped):
            out += self._updates[t]
        return out

    def sync_bits(self, skipped: int, bits_per_update: float, model_bits: float) -> float:
        """Download cost for a client that skipped ``skipped`` rounds (Eq. 13)."""
        if skipped > len(self._updates):
            return model_bits
        # The partial sum of s sparse updates has at most s-times the nnz;
        # H(P^(s)) <= s * H(ΔW~) is attained in the worst case (disjoint masks).
        return max(1, skipped) * bits_per_update

    def sync_bits_batch(self, skipped, bits_per_update: float,
                        model_bits: float) -> float:
        """Total download cost for a cohort: vectorized ``sync_bits`` over an
        integer array of per-client skipped-round counts."""
        skipped = np.asarray(skipped, dtype=np.int64)
        per_client = np.where(
            skipped > len(self._updates), model_bits,
            np.maximum(skipped, 1).astype(np.float64) * bits_per_update)
        return float(per_client.sum())
