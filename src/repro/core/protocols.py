"""Communication protocols for federated optimization (paper Table I).

Each protocol defines how a *flat fp32 update vector* is compressed on the
client (upstream) and on the server (downstream), how updates from several
clients are aggregated, and what the communicated message costs in bits.

Implemented protocols (the paper's comparison set):

* ``baseline``  -- uncompressed distributed SGD
* ``fedavg``    -- Federated Averaging (communication delay; dense messages)
* ``signsgd``   -- sign quantization + majority vote (Bernstein et al.)
* ``topk``      -- upload-only top-k sparsification + error feedback (Aji/Lin)
* ``stc``       -- the paper's contribution: bidirectional sparse ternary
                   compression + error feedback + Golomb-coded messages

All compression math is jit-able; the bit accounting is host-side analytic
(see :mod:`repro.core.golomb`) and validated against the real codec in tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import golomb
from .compression import (
    CompressionStats,
    get_stc_backend,
    majority_vote_sign,
    sign_compress,
    top_k_sparsify,
)
from .residual import ResidualState, compress_with_feedback, init_residual

__all__ = ["Protocol", "make_protocol", "PROTOCOLS"]


def _identity(x: jnp.ndarray) -> tuple[jnp.ndarray, CompressionStats]:
    stats = CompressionStats(
        nnz=jnp.asarray(x.size), numel=jnp.asarray(x.size), mu=jnp.asarray(0.0)
    )
    return x, stats


@dataclasses.dataclass(frozen=True)
class Protocol:
    """A (possibly stateful via explicit residuals) compression protocol."""

    name: str
    sparsity_up: Optional[float] = None     # p_up  (stc / topk)
    sparsity_down: Optional[float] = None   # p_down (stc)
    sign_step: Optional[float] = None       # δ (signsgd)
    local_iters: int = 1                    # n (fedavg delay period)
    error_feedback: bool = False
    backend: str = "jnp"                    # STC impl: "jnp" | "kernel"

    # -- state ------------------------------------------------------------
    def init_client_state(self, numel: int) -> Optional[ResidualState]:
        if self.error_feedback:
            return init_residual(jnp.zeros((numel,), jnp.float32))
        return None

    def init_server_state(self, numel: int) -> Optional[ResidualState]:
        if self.name == "stc":
            return init_residual(jnp.zeros((numel,), jnp.float32))
        return None

    # -- client side (upstream) --------------------------------------------
    def client_compress(self, update: jnp.ndarray, state):
        """Compress a flat client update. Returns (msg, new_state, stats)."""
        if self.name in ("baseline", "fedavg"):
            msg, stats = _identity(update)
            return msg, state, stats
        if self.name == "signsgd":
            msg, stats = sign_compress(update, self.sign_step)
            return msg, state, stats
        if self.name == "topk":
            return compress_with_feedback(
                update, state, lambda v: top_k_sparsify(v, self.sparsity_up)
            )
        if self.name == "stc":
            be = get_stc_backend(self.backend)
            msg, new_res, stats = be.compress_with_residual(
                update, state.residual, self.sparsity_up)
            return msg, ResidualState(residual=new_res), stats
        raise ValueError(self.name)

    # -- server side (aggregation + downstream) -----------------------------
    def server_aggregate(self, stacked: jnp.ndarray, state):
        """Aggregate (n_clients, numel) messages. Returns (broadcast, state, stats)."""
        if self.name == "signsgd":
            msg = majority_vote_sign(stacked, self.sign_step)
            _, stats = _identity(msg)
            stats = stats._replace(mu=jnp.asarray(self.sign_step))
            return msg, state, stats
        mean = jnp.mean(stacked, axis=0)
        if self.name == "stc":
            be = get_stc_backend(self.backend)
            msg, new_res, stats = be.compress_with_residual(
                mean, state.residual, self.sparsity_down)
            return msg, ResidualState(residual=new_res), stats
        msg, stats = _identity(mean)
        return msg, state, stats

    # -- bit ledger ----------------------------------------------------------
    def upload_bits(self, numel: int) -> float:
        if self.name in ("baseline", "fedavg"):
            return golomb.fedavg_message_bits(numel)
        if self.name == "signsgd":
            return golomb.signsgd_message_bits(numel)
        if self.name == "topk":
            k = max(int(numel * self.sparsity_up), 1)
            # positions (naive 16-bit distance coding per the paper's comparison)
            return k * (golomb.golomb_position_bits(self.sparsity_up) + 32.0)
        if self.name == "stc":
            return golomb.stc_message_bits(numel, self.sparsity_up)
        raise ValueError(self.name)

    def download_bits(self, numel: int, n_participating: int = 1) -> float:
        if self.name in ("baseline", "fedavg"):
            return golomb.fedavg_message_bits(numel)
        if self.name == "signsgd":
            return golomb.signsgd_message_bits(numel)
        if self.name == "topk":
            # upload-only compression: downstream density grows with clients
            # (Section V-A) until the update is effectively dense.
            k = max(int(numel * self.sparsity_up), 1)
            nnz = min(k * n_participating, numel)
            if nnz >= numel:          # fully densified: plain dense download
                return golomb.fedavg_message_bits(numel)
            p_eff = max(nnz / numel, 1.0 / numel)
            return nnz * (golomb.golomb_position_bits(p_eff) + 32.0)
        if self.name == "stc":
            return golomb.stc_message_bits(numel, self.sparsity_down)
        raise ValueError(self.name)


_DEFAULTS = {
    "baseline": dict(),
    "fedavg": dict(local_iters=400),
    "signsgd": dict(sign_step=2e-4),
    "topk": dict(sparsity_up=1 / 400, error_feedback=True),
    "stc": dict(sparsity_up=1 / 400, sparsity_down=1 / 400, error_feedback=True),
}

PROTOCOLS = tuple(_DEFAULTS)


def make_protocol(name: str, **overrides) -> Protocol:
    """Factory with the paper's default hyperparameters (Section VI)."""
    if name not in _DEFAULTS:
        raise ValueError(f"unknown protocol {name!r}; options: {PROTOCOLS}")
    kwargs = dict(_DEFAULTS[name])
    kwargs.update(overrides)
    return Protocol(name=name, **kwargs)
