"""Pluggable communication codecs for federated optimization (paper Table I).

Every protocol is a :class:`Codec`: a frozen dataclass holding the protocol's
hyperparameters and implementing a small, jit-able interface.  The federated
trainer (:mod:`repro.fed.loop`) and the distributed mesh trainer
(:mod:`repro.launch.train`) call ONLY this interface -- there is no string
dispatch anywhere outside the registry lookup, so a new compressor drops in
without touching either trainer.

The interface (flat-vector path, used by :class:`repro.fed.FederatedTrainer`):

* ``init_client_state(numel)`` / ``init_server_state(numel)`` -- per-client /
  server codec state as a pytree (or ``None`` for stateless codecs); the
  trainer carries it through jit and stacks client states along a leading
  ``(n_clients,)`` axis (see ``residual.stack_states``).
* ``encode_batch(deltas, states)`` -- **batched-first** client-side
  compression of a whole ``(P, numel)`` round; returns ``(msgs, states,
  stats)`` with a leading client axis on every output.  The default
  implementation vmaps the single-vector :meth:`Codec.encode`; codecs with a
  genuinely batched implementation (STC's Pallas kernels) override it.
* ``aggregate(msgs, server_state, mask=None, staleness=None)`` -- server
  aggregation of the stacked ``(P, numel)`` messages plus downstream
  compression; returns ``(global_delta, server_state, stats)``.  ``mask`` is
  a per-message participation mask and ``staleness`` the per-message age in
  rounds (both ``(P,)``), used by the buffered/async trainer.  The combine
  estimator itself is the codec's pluggable ``rule``
  (:mod:`repro.core.aggregation`): the default ``mean`` rule is the
  staleness-decayed weighted mean of :meth:`Codec.combine` (``signsgd``
  then instead casts a weighted majority vote); ``coordinate_median`` /
  ``trimmed_mean`` / ``norm_screened_mean`` trade statistical efficiency
  for Byzantine robustness.  ``mask=None`` (the synchronous trainer) is
  the plain mean.
* ``upload_bits(numel)`` / ``download_bits(numel, n_participating)`` --
  analytic bit ledger (Eq. 1), host-side floats.
* ``encode_wire`` / ``decode_wire`` / ``encode_wire_batch`` +
  ``measured_upload_bits`` / ``measured_download_bits`` -- the REAL
  bitstream (host-side, :mod:`repro.core.wire`): codecs that set
  ``wire_format = True`` get exact measured bits in the trainers' ledgers,
  with the analytic formulas kept as a cross-check (``wire_bound_bits`` is
  the deterministic per-message ceiling asserted in tests).

The tree path (``tree_encode`` / ``tree_reduce`` / ``tree_decode``) is the
same protocol expressed over a parameter *pytree* for the shard_map trainer,
where flattening would force an all-gather; states there are bare residual
pytrees allocated by the trainer.

Codecs self-register::

    @register_protocol
    @dataclasses.dataclass(frozen=True)
    class MyCodec(Codec):
        name = "mine"
        def encode(self, delta, state): ...
        def upload_bits(self, numel): ...

``make_protocol(name, **overrides)`` stays the factory (paper defaults are
the dataclass field defaults).  Implemented codecs: the paper's comparison
set (``baseline`` / ``fedavg`` / ``signsgd`` / ``topk`` / ``stc``) plus
``ternquant`` -- dense ternary quantization in the style of T-FedAvg (Xu et
al., 2020) -- as the proof that third-party codecs are drop-in.
"""

from __future__ import annotations

import dataclasses
import inspect
import math
import warnings
from typing import ClassVar, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import golomb, wire
from .aggregation import AggregationRule, MeanRule, NormScreenedMeanRule, \
    make_rule
from .ingest import IngestAccumulator
from .registry import lookup as _registry_lookup, resolve as _registry_resolve
from .compression import (
    CompressionStats,
    get_stc_backend,
    majority_vote_sign,
    sign_compress,
    stc_compress_blocks,
    ternary_quantize,
    top_k_sparsify,
)
from .residual import ResidualState, compress_with_feedback, init_residual

__all__ = [
    "Codec", "Protocol", "make_protocol", "register_protocol",
    "registered_protocols", "get_protocol_class", "PROTOCOLS",
    "BaselineCodec", "FedAvgCodec", "SignSGDCodec", "TopKCodec", "StcCodec",
    "TernQuantCodec",
]


def _identity(x: jnp.ndarray) -> tuple[jnp.ndarray, CompressionStats]:
    stats = CompressionStats(
        nnz=jnp.asarray(x.size), numel=jnp.asarray(x.size), mu=jnp.asarray(0.0)
    )
    return x, stats


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type["Codec"]] = {}


def register_protocol(cls=None, *, name: Optional[str] = None,
                      override: bool = False):
    """Register a :class:`Codec` subclass under ``name`` (default:
    ``cls.name``).  Usable as a bare decorator or with a name override.
    Re-registering an existing name with a *different* class raises unless
    ``override=True`` (typo-collisions with builtins should be loud)."""

    def _register(c):
        key = name if name is not None else getattr(c, "name", None)
        if not key:
            raise ValueError(f"codec {c!r} needs a `name` class attribute")
        prior = _REGISTRY.get(key)
        if prior is not None and prior is not c and not override:
            raise ValueError(
                f"protocol {key!r} is already registered to {prior.__name__}; "
                f"pass register_protocol(..., override=True) to replace it")
        _REGISTRY[key] = c
        return c

    return _register(cls) if cls is not None else _register


def registered_protocols() -> tuple[str, ...]:
    """Names of every registered codec (sorted)."""
    return tuple(sorted(_REGISTRY))


def get_protocol_class(name: str) -> type["Codec"]:
    return _registry_lookup("protocol", name, _REGISTRY)


# the pre-registry Protocol dataclass carried EVERY protocol's fields; for
# backward compatibility the factory still accepts this set on any codec,
# dropping the ones a codec does not declare (they were functionally inert)
_LEGACY_FIELDS = frozenset({"sparsity_up", "sparsity_down", "sign_step",
                            "error_feedback", "backend", "local_iters"})


def _instantiate_protocol(cls: type["Codec"], overrides: dict) -> "Codec":
    """``make_protocol``'s kwarg handling: declared fields pass through,
    legacy monolithic-Protocol fields drop silently when inert (loudly when
    they contradict a ClassVar), anything else is a typo."""
    fields = {f.name for f in dataclasses.fields(cls)}
    kwargs = {}
    for k, v in overrides.items():
        if k in fields:
            kwargs[k] = v
        elif k in _LEGACY_FIELDS:
            # inert on this codec in the old API too -- but refuse a value
            # that contradicts a ClassVar (e.g. error_feedback=False on stc)
            cur = getattr(cls, k, None)
            if cur is not None and cur != v:
                raise ValueError(
                    f"{cls.name!r} fixes {k}={cur!r}; "
                    f"override is not supported")
        else:
            raise TypeError(
                f"{cls.name!r} codec has no field {k!r}; declared fields: "
                f"{sorted(fields)}")
    return cls(**kwargs)


def make_protocol(name, **overrides) -> "Codec":
    """Factory with the paper's default hyperparameters (Section VI).
    Accepts a registered name (plus field overrides) or an already-built
    :class:`Codec` instance, which passes through untouched."""
    return _registry_resolve("protocol", name, _REGISTRY, Codec,
                             instantiate=_instantiate_protocol, **overrides)


# ---------------------------------------------------------------------------
# the abstract base
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Codec:
    """A (possibly stateful via explicit pytree state) compression protocol."""

    name: ClassVar[str] = ""
    error_feedback: ClassVar[bool] = False

    local_iters: int = 1                    # n (communication delay period)
    # staleness-weighted combining (buffered/async aggregation): an update
    # that is s rounds old enters the weighted mean with weight (1+s)^-decay
    # (FedBuff-style polynomial decay; 0.0 = ignore staleness entirely)
    staleness_decay: float = 0.5
    # DEPRECATED norm-bound screen (PR 8): forwarded to
    # ``rule=norm_screened_mean(bound=, policy=)`` with a DeprecationWarning;
    # setting them alongside an explicit ``rule`` raises.
    norm_bound: Optional[float] = None
    norm_policy: str = "clip"               # "clip" | "reject"
    # the server-side combine estimator: a registered AggregationRule name
    # or instance (see repro.core.aggregation).  ``None`` -> "mean", the
    # participation-weighted mean, bit-identical to the pre-rule combine.
    rule: Optional[AggregationRule] = None

    def __post_init__(self):
        if self.norm_policy not in ("clip", "reject"):
            raise ValueError(
                f"norm_policy must be 'clip' or 'reject', "
                f"got {self.norm_policy!r}")
        if self.norm_bound is not None and not self.norm_bound > 0.0:
            raise ValueError(
                f"norm_bound must be > 0 (or None), got {self.norm_bound}")
        rule = self.rule
        if self.norm_bound is not None:
            shim = NormScreenedMeanRule(bound=float(self.norm_bound),
                                        policy=self.norm_policy)
            if rule is None:
                warnings.warn(
                    "Codec(norm_bound=, norm_policy=) is deprecated; use "
                    "rule=make_rule('norm_screened_mean', bound=..., "
                    "policy=...) -- the shim forwards bit-identically for "
                    "one release", DeprecationWarning, stacklevel=3)
                rule = shim
            elif rule != shim:
                # (an equal rule instance means dataclasses.replace() of an
                # already-shimmed codec: re-normalizing is not a conflict)
                raise ValueError(
                    "norm_bound/norm_policy cannot be combined with an "
                    "explicit aggregation rule; fold the screen into "
                    "rule=make_rule('norm_screened_mean', bound=..., "
                    "policy=...)")
        object.__setattr__(
            self, "rule", make_rule(rule if rule is not None else "mean"))

    def __init_subclass__(cls, **kwargs):
        super().__init_subclass__(**kwargs)
        # the pre-PR-4 2-arg aggregate/tree_reduce spelling is gone: fail
        # loudly at class-definition time, naming the migration, instead of
        # silently mis-aggregating masked rounds at runtime
        for meth in ("aggregate", "tree_reduce"):
            fn = cls.__dict__.get(meth)
            if fn is None or not callable(fn):
                continue
            try:
                params = inspect.signature(fn).parameters
            except (TypeError, ValueError):
                continue
            if any(p.kind is inspect.Parameter.VAR_KEYWORD
                   for p in params.values()):
                continue
            if "mask" not in params or "staleness" not in params:
                raise TypeError(
                    f"{cls.__name__}.{meth} predates the masked aggregation "
                    f"API: every codec now implements {meth}(..., mask=None, "
                    "staleness=None); the legacy 2-arg compatibility path "
                    "was removed with the AggregationRule redesign (see "
                    "README 'Migration notes')")

    # -- state ------------------------------------------------------------
    def init_client_state(self, numel: int):
        """One client's codec state pytree (None = stateless)."""
        return None

    def init_server_state(self, numel: int):
        return None

    # -- client side (upstream) --------------------------------------------
    def encode(self, delta: jnp.ndarray, state):
        """Compress ONE flat client update. Returns (msg, new_state, stats)."""
        raise NotImplementedError(type(self).__name__)

    def encode_batch(self, deltas: jnp.ndarray, states):
        """Compress a whole (P, numel) round. Returns (msgs, states, stats),
        every output carrying the leading client axis."""
        return jax.vmap(lambda d, s: self.encode(d, s))(deltas, states)

    # -- chunked (layer, chunk) block path ------------------------------------
    # A codec with ``chunk_blocks = True`` compresses a zero-padded
    # (P, n_chunks, chunk_numel) block tensor in ONE fused call with a static
    # per-chunk k vector, instead of the generic per-group loop of
    # :class:`repro.core.chunking.ChunkedCodec`.  Semantics contract: each
    # block is compressed EXACTLY as the flat codec would compress its
    # unpadded slice (padding is zero and must never be selected).

    chunk_blocks: ClassVar[bool] = False

    def encode_chunk_blocks(self, blocks, states, *, ks):
        """Fused chunked upstream compression; see ``chunk_blocks`` above."""
        raise NotImplementedError(
            f"{type(self).__name__} has no fused chunk-blocks path")

    def aggregate_chunk_blocks(self, blocks, server_state, *, ks, mask=None,
                               staleness=None):
        """Fused chunked aggregation + downstream compression."""
        raise NotImplementedError(
            f"{type(self).__name__} has no fused chunk-blocks path")

    # Adaptive-controller variants (repro.core.adaptive): per-chunk k is no
    # longer static -- the controller observes the error-feedback pre-image
    # inside the jitted round and its (optional) state threads through the
    # call.  Only meaningful for ``chunk_blocks = True`` codecs.

    def encode_chunk_blocks_adaptive(self, blocks, states, controller,
                                     ctrl_state, *, base_ks, caps):
        """Fused upstream compression with controller-chosen per-chunk k.

        Returns ``(tern, new_states, new_ctrl_state, stats)``."""
        raise NotImplementedError(
            f"{type(self).__name__} has no adaptive chunk-blocks path")

    def aggregate_chunk_blocks_adaptive(self, blocks, server_state,
                                        controller, ctrl_state, *, base_ks,
                                        caps, mask=None, staleness=None):
        """Fused aggregation + downstream compression with controller-chosen
        per-chunk k.  Returns ``(out, new_state, new_ctrl_state, stats)``."""
        raise NotImplementedError(
            f"{type(self).__name__} has no adaptive chunk-blocks path")

    # -- server side (aggregation + downstream) -----------------------------
    def participation_weights(self, mask, staleness=None) -> jnp.ndarray:
        """Per-message combining weights ``w_i = mask_i * (1+s_i)^-decay``.

        ``mask`` is the (P,) participation mask (1 = arrived, 0 = absent /
        padding) and ``staleness`` the (P,) per-message age in rounds; with
        ``staleness=None`` (or all zeros) the weights are exactly the mask,
        so an all-ones mask reproduces the synchronous combine bit for bit.
        """
        w = jnp.asarray(mask, jnp.float32)
        if staleness is not None:
            decay = (1.0 + jnp.asarray(staleness, jnp.float32)) \
                ** (-self.staleness_decay)
            w = w * decay
        return w

    def combine(self, msgs: jnp.ndarray, mask=None, staleness=None):
        """Combine (P, ...) messages over the client axis through the
        codec's :class:`AggregationRule`: the rule's screen runs on the raw
        mask (a rejected message loses its weight BEFORE staleness decay),
        then the rule combines under ``participation_weights``.  With the
        default ``mean`` rule this is bit-identical to the historical
        combine -- the plain mean when unmasked, otherwise the
        staleness-weighted mean (weight mass 0 combines to zero)."""
        msgs, mask = self.rule.screen(msgs, mask)
        if mask is None and staleness is None:
            return self.rule.combine_weighted(msgs, None)
        if mask is None:
            mask = jnp.ones(msgs.shape[0], jnp.float32)
        w = self.participation_weights(mask, staleness)
        return self.rule.combine_weighted(msgs, w)

    def aggregate(self, msgs: jnp.ndarray, server_state, mask=None,
                  staleness=None):
        """Aggregate (P, numel) messages. Returns (global_delta, state, stats).

        ``mask`` / ``staleness`` (both (P,), optional) come from the buffered
        trainer: only ``mask>0`` rows count, each weighted by the codec's
        staleness decay (see :meth:`combine`).  ``None`` = synchronous round.
        """
        mean = self.combine(msgs, mask, staleness)
        out, stats = _identity(mean)
        return out, server_state, stats

    # -- bit ledger ----------------------------------------------------------
    def upload_bits(self, numel: int) -> float:
        raise NotImplementedError(type(self).__name__)

    def download_bits(self, numel: int, n_participating: int = 1) -> float:
        raise NotImplementedError(type(self).__name__)

    # -- wire format (host-side measured ledger) -----------------------------
    # A codec with ``wire_format = True`` can serialize its messages to the
    # REAL bitstream, so trainers account measured bits (exact stream length
    # + ``wire_header_bits`` of side information per message) instead of the
    # analytic expectations above -- which are then kept as a cross-check.

    wire_format: ClassVar[bool] = False
    wire_header_bits: ClassVar[float] = 0.0
    # True when the wire size is statically known (measured == analytic by
    # construction, e.g. a dense 1-bit sign plane): trainers then skip the
    # per-round device->host transfer + serialization unless explicitly
    # asked to measure anyway.
    wire_static_size: ClassVar[bool] = False

    def encode_wire(self, msg: np.ndarray, *,
                    direction: str = "up") -> wire.WireMessage:
        """Serialize ONE already-compressed message to its wire bitstream."""
        raise NotImplementedError(
            f"{type(self).__name__} has no wire format")

    def decode_wire(self, msg: wire.WireMessage, *,
                    direction: str = "up") -> np.ndarray:
        """Inverse of :meth:`encode_wire`, exact up to the wire format's
        resolution (STC's position stream is lossless; a 1-bit sign plane
        cannot represent exact zeros -- see :func:`wire.pack_sign_words`)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no wire format")

    def validate_wire(self, msg: wire.WireMessage, *,
                      direction: str = "up") -> None:
        """Admission-control validation of ONE arriving wire message:
        raises :class:`wire.WireDecodeError` on any corruption class the
        decoder can detect (truncated words, dangling unary runs, position
        or nnz overflow), returns None on success.  The default decodes the
        full message and discards it; codecs with a cheaper structural
        check (STC's fields-only parse, signSGD's size check) override it.
        """
        self.decode_wire(msg, direction=direction)

    def wire_norm(self, msg: wire.WireMessage) -> float:
        """Cheap l2-norm estimate of ONE encoded message, from its wire
        side information alone (no decode) -- the ingest paths' input to
        a screening rule's ``screen_weight``."""
        raise NotImplementedError(
            f"{type(self).__name__} has no wire-norm estimate; norm "
            "screening on the wire ingest path needs wire_norm()")

    def encode_wire_batch(self, msgs: np.ndarray, *,
                          direction: str = "up") -> wire.WireBatch:
        """Serialize a stacked (P, numel) round of messages.  Codecs with a
        genuinely batched packer (STC) override this fallback."""
        return wire.concat_messages([
            self.encode_wire(m, direction=direction)
            for m in np.asarray(msgs)])

    def measured_batch_bits(self, batch: wire.WireBatch) -> float:
        """Total size of an already-encoded batch (override for codecs with
        non-constant per-message side information)."""
        return batch.total_bits() + batch.n_msgs * self.wire_header_bits

    def measured_message_bits(self, msg: wire.WireMessage) -> float:
        """Total size of ONE already-encoded message (stream + header)."""
        return msg.bit_len + self.wire_header_bits

    def measured_upload_bits(self, msgs: np.ndarray) -> float:
        """EXACT upstream bits for a (P, numel) stack of compressed client
        messages; falls back to the analytic model for wire-less codecs."""
        msgs = np.asarray(msgs)
        if not self.wire_format:
            return msgs.shape[0] * self.upload_bits(msgs.shape[-1])
        return self.measured_batch_bits(
            self.encode_wire_batch(msgs, direction="up"))

    def measured_download_bits(self, msg: np.ndarray,
                               n_participating: int = 1) -> float:
        """EXACT bits of ONE downstream (global update) message.

        ``n_participating`` only matters for the analytic fallback of
        wire-less codecs (whose downstream density can grow with the
        cohort, e.g. topk); a real wire stream is measured as-is."""
        msg = np.asarray(msg)
        if not self.wire_format:
            return self.download_bits(msg.size,
                                      n_participating=n_participating)
        return self.measured_message_bits(self.encode_wire(msg,
                                                           direction="down"))

    def wire_bound_bits(self, numel: int, nnz: int,
                        direction: str = "up") -> Optional[float]:
        """Deterministic per-message ceiling on the measured size (stream
        PLUS header bits; None = no bound known); trainers log it so tests
        can assert ``measured <= bound`` round by round."""
        return None

    # -- fused decode→aggregate ingestion (repro.core.ingest) ----------------
    # A codec with ``supports_ingest = True`` can consume a round as a STREAM
    # of arriving messages: each upload scatters into one O(numel)
    # :class:`IngestAccumulator` at arrival time (``ingest_wire`` /
    # ``ingest_dense``), and ``aggregate_ingest`` finalizes the round from
    # the accumulator alone -- the dense (P, numel) message block never
    # exists.  Contract (property-tested): ``ingest_wire*`` is bit-identical
    # to decoding every message dense and feeding it through
    # ``ingest_dense`` (the oracle), and both share ``finalize_ingest``.

    supports_ingest: ClassVar[bool] = False

    def make_ingest(self, numel: int) -> IngestAccumulator:
        """A fresh per-round accumulator sized for the flat message vector."""
        if not self.supports_ingest:
            raise NotImplementedError(
                f"{type(self).__name__} has no ingest path")
        if not self.rule.supports_streaming:
            raise NotImplementedError(
                f"aggregation rule {self.rule.name!r} needs every client's "
                "coordinates at once and cannot stream through "
                "IngestAccumulator; use the dense aggregate path (trainers "
                "asked for ingest=True fall back automatically)")
        return IngestAccumulator(numel)

    def ingest_dense(self, acc: IngestAccumulator, vec: np.ndarray,
                     weight: float) -> None:
        """One dense (decoded, or never wire-encoded) message into the
        accumulator -- the fused wire paths' bit-exactness oracle."""
        if self.rule.screens:
            norm = float(np.linalg.norm(np.asarray(vec, np.float64)))
            scale, rejected = self.rule.screen_weight(norm)
            if rejected:
                acc.begin_message(0.0)
                acc.note_screened()
                return
            acc.begin_message(weight)
            acc.add_dense(vec, weight * scale)
            return
        acc.begin_message(weight)
        acc.add_dense(vec, weight)

    def ingest_wire_chunk(self, acc: IngestAccumulator, msg, weight: float,
                          *, direction: str = "up", offset: int = 0) -> None:
        """Scatter ONE wire sub-stream at flat ``offset`` (no per-message
        bookkeeping: chunked codecs call this once per chunk)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no wire ingest path")

    def ingest_wire(self, acc: IngestAccumulator, msg, weight: float, *,
                    direction: str = "up") -> None:
        """One arriving wire message: account its weight + measured bits,
        then scatter its decoded fields into the accumulator.  Under a
        screening rule, the message's wire-side norm estimate is screened
        first -- a rejected message still bills its bits but enters the
        aggregate with zero weight."""
        bits = self.measured_message_bits(msg)
        if self.rule.screens:
            scale, rejected = self.rule.screen_weight(self.wire_norm(msg))
            if rejected:
                acc.begin_message(0.0, bits=bits)
                acc.note_screened()
                return
            acc.begin_message(weight, bits=bits)
            self.ingest_wire_chunk(acc, msg, weight * scale,
                                   direction=direction)
            return
        acc.begin_message(weight, bits=bits)
        self.ingest_wire_chunk(acc, msg, weight, direction=direction)

    def ingest_wire_batch(self, acc: IngestAccumulator, batch, weights, *,
                          direction: str = "up") -> None:
        """A whole encoded round, message-major.  The default loops
        :meth:`ingest_wire`; codecs with a batched field decoder (STC)
        override it with one fused decode + scatter."""
        for i, w in enumerate(np.asarray(weights, np.float64)):
            self.ingest_wire(acc, batch.message(i), float(w),
                             direction=direction)

    def finalize_ingest(self, combined, server_state):
        """Downstream compression of the accumulator's weighted mean; the
        ingest twin of the tail of :meth:`aggregate`.  Returns
        ``(global_delta, new_server_state, stats)``."""
        raise NotImplementedError(
            f"{type(self).__name__} has no ingest path")

    def aggregate_ingest(self, acc: IngestAccumulator, server_state):
        """Finalize a round straight from the accumulator (both the fused
        wire path and the dense oracle end here, so they agree bitwise)."""
        return self.finalize_ingest(acc.combined(), server_state)

    # -- tree path (distributed shard_map trainer) ---------------------------
    def has_client_state(self) -> bool:
        return self.init_client_state(0) is not None

    def has_server_state(self) -> bool:
        return self.init_server_state(0) is not None

    def tree_encode(self, delta, residual, *, numel: int, iters: int = 32):
        """Client-side compression over a parameter pytree.  ``residual`` is a
        bare fp32 pytree (or None). Returns (msg_tree, new_residual, metrics).
        """
        return delta, residual, {}

    def _tree_reduce_gather(self, msgs, axes, mask, staleness):
        """Order-statistic rules need every shard's coordinates at once:
        all_gather the per-shard message trees plus their weight mass, then
        run the rule once per leaf.  O(n_shards * numel) on the interconnect
        where the mean-family psum is O(numel) -- the price of a nonlinear
        estimator, paid only when such a rule is configured."""
        rule = self.rule
        if mask is None:
            mask = jnp.ones((1,), jnp.float32)
        w = jnp.sum(self.participation_weights(mask, staleness))
        if not axes:
            return jax.tree.map(lambda t: rule.combine(t[None], w[None]),
                                msgs)
        ws = jax.lax.all_gather(w, axes)
        return jax.tree.map(
            lambda t: rule.combine(jax.lax.all_gather(t, axes), ws), msgs)

    def tree_reduce(self, msgs, axes, n_clients: int, mask=None,
                    staleness=None):
        """The one protocol-level collective: combine per-client message trees
        over the manual mesh axes ``axes``.

        Mean-family rules reduce via the historical (bit-identical) psum
        paths below; other rules route through the gathered
        :meth:`_tree_reduce_gather`.  ``mask`` / ``staleness`` are THIS
        shard's slice of the per-client participation mask and staleness
        vectors (shape ``(local_clients,)`` inside shard_map): a masked-out
        shard contributes zero weight, so a dropped client no longer stalls
        or skews the step, and the weighted psum renormalizes by the total
        arrived weight mass.
        """
        if not isinstance(self.rule, MeanRule):
            return self._tree_reduce_gather(msgs, axes, mask, staleness)
        if mask is None and staleness is None:
            if axes:
                return jax.tree.map(
                    lambda t: jax.lax.psum(t, axes) / n_clients, msgs)
            return msgs
        if mask is None:
            mask = jnp.ones((1,), jnp.float32)
        w = jnp.sum(self.participation_weights(mask, staleness))
        if axes:
            total = jax.lax.psum(w, axes)
            denom = jnp.where(total > 0, total, 1.0)
            return jax.tree.map(
                lambda t: jax.lax.psum(w * t, axes) / denom, msgs)
        denom = jnp.where(w > 0, w, 1.0)
        return jax.tree.map(lambda t: w * t / denom, msgs)

    def tree_decode(self, combined, residual, *, numel: int, iters: int = 32):
        """Server-side downstream compression of the combined tree.  Returns
        (global_delta_tree, new_server_residual, metrics)."""
        return combined, residual, {}


# Deprecated alias: `Protocol` was the pre-registry monolithic class.
Protocol = Codec


# ---------------------------------------------------------------------------
# error-feedback mixin: EF codecs share state init + the carried-vector step
# ---------------------------------------------------------------------------


class _ErrorFeedbackMixin:
    error_feedback: ClassVar[bool] = True

    def init_client_state(self, numel: int) -> ResidualState:
        return init_residual(jnp.zeros((numel,), jnp.float32))


# ---------------------------------------------------------------------------
# the paper's comparison set (Table I)
# ---------------------------------------------------------------------------


@register_protocol
@dataclasses.dataclass(frozen=True)
class BaselineCodec(Codec):
    """Uncompressed distributed SGD: dense fp32 both ways."""

    name: ClassVar[str] = "baseline"

    def encode(self, delta, state):
        msg, stats = _identity(delta)
        return msg, state, stats

    def upload_bits(self, numel: int) -> float:
        return golomb.fedavg_message_bits(numel)

    def download_bits(self, numel: int, n_participating: int = 1) -> float:
        return golomb.fedavg_message_bits(numel)


@register_protocol
@dataclasses.dataclass(frozen=True)
class FedAvgCodec(BaselineCodec):
    """Federated Averaging: dense messages every ``local_iters`` iterations."""

    name: ClassVar[str] = "fedavg"

    local_iters: int = 400


@register_protocol
@dataclasses.dataclass(frozen=True)
class SignSGDCodec(Codec):
    """signSGD with majority vote (Bernstein et al. '18); δ = ``sign_step``."""

    name: ClassVar[str] = "signsgd"

    sign_step: float = 2e-4
    wire_backend: str = "numpy"             # wire packer: "numpy" | "kernel"

    wire_format: ClassVar[bool] = True      # dense sign plane, 1 bit/coord
    wire_static_size: ClassVar[bool] = True  # numel bits, exactly, always
    supports_ingest: ClassVar[bool] = True

    def encode(self, delta, state):
        msg, stats = sign_compress(delta, self.sign_step)
        return msg, state, stats

    def encode_wire(self, msg, *, direction="up"):
        return wire.pack_sign_words(msg, self.sign_step,
                                    backend=self.wire_backend)

    def decode_wire(self, msg, *, direction="up"):
        return wire.unpack_sign_words(msg)

    def validate_wire(self, msg, *, direction="up"):
        # a sign plane is exactly numel bits; anything else is truncation
        # or padding corruption, by construction
        if int(msg.bit_len) != int(msg.numel):
            raise wire.WireDecodeError(
                "corrupt sign plane: bit_len != numel")
        wire.sign_plane_bits(msg, backend=self.wire_backend)

    def wire_norm(self, msg):
        # every coordinate is exactly ±sign_step, so the norm is constant
        # (the screen is inert here unless the bound is set below it)
        return self.sign_step * math.sqrt(int(msg.numel))

    def wire_bound_bits(self, numel, nnz, direction="up"):
        return float(numel)                 # measured == analytic, exactly

    # ---- fused ingest: the vote tally IS the weighted plane sum ----
    def ingest_wire_chunk(self, acc, msg, weight, *, direction="up",
                          offset=0):
        bits01 = wire.sign_plane_bits(msg, backend=self.wire_backend)
        acc.add_sign_plane(bits01, self.sign_step, weight, offset=offset)

    def finalize_ingest(self, combined, server_state):
        # sign(weighted mean) == sign(weighted vote tally): the arrived
        # mass is positive, and the wire planes are exactly ±step.  Ingest
        # aggregates the WIRE truth (a dense message's exact zeros were
        # already -step on the wire -- see wire.pack_sign_words).
        out = self.sign_step * jnp.sign(jnp.asarray(combined))
        _, stats = _identity(out)
        stats = stats._replace(mu=jnp.asarray(self.sign_step))
        return out, server_state, stats

    def aggregate(self, msgs, server_state, mask=None, staleness=None):
        if not isinstance(self.rule, MeanRule):
            # order-statistic rules: combine the ±step messages through the
            # rule, then re-quantize to the sign plane the downstream wire
            # format requires (a coordinate's median of ±step values lies
            # in {-step, 0, +step} already)
            out = self.sign_step * jnp.sign(
                self.combine(msgs, mask, staleness))
            _, stats = _identity(out)
            stats = stats._replace(mu=jnp.asarray(self.sign_step))
            return out, server_state, stats
        # mean family: the weighted majority vote (its own robust estimator
        # over sign planes), bit-identical to the pre-rule aggregate
        weights = None
        if mask is not None or staleness is not None:
            if mask is None:
                mask = jnp.ones(msgs.shape[0], jnp.float32)
            weights = self.participation_weights(mask, staleness)
        out = majority_vote_sign(msgs, self.sign_step, weights=weights)
        _, stats = _identity(out)
        stats = stats._replace(mu=jnp.asarray(self.sign_step))
        return out, server_state, stats

    def upload_bits(self, numel: int) -> float:
        return golomb.signsgd_message_bits(numel)

    def download_bits(self, numel: int, n_participating: int = 1) -> float:
        return golomb.signsgd_message_bits(numel)

    # ---- tree path ----
    def tree_encode(self, delta, residual, *, numel, iters=32):
        from .distributed import sign_compress_tree
        return sign_compress_tree(delta, self.sign_step), residual, {}

    def tree_reduce(self, msgs, axes, n_clients, mask=None, staleness=None):
        if not isinstance(self.rule, MeanRule):
            # gathered rule over the ±step trees; tree_decode's sign()
            # re-quantizes the combined tree either way
            return self._tree_reduce_gather(msgs, axes, mask, staleness)
        if mask is None and staleness is None:
            if axes:
                return jax.tree.map(
                    lambda t: jax.lax.psum(jnp.sign(t), axes), msgs)
            return jax.tree.map(jnp.sign, msgs)
        # weighted vote: an absent shard casts no vote (weight 0); no
        # renormalization -- tree_decode takes the sign of the tally anyway
        if mask is None:
            mask = jnp.ones((1,), jnp.float32)
        w = jnp.sum(self.participation_weights(mask, staleness))
        if axes:
            return jax.tree.map(
                lambda t: jax.lax.psum(w * jnp.sign(t), axes), msgs)
        return jax.tree.map(lambda t: w * jnp.sign(t), msgs)

    def tree_decode(self, combined, residual, *, numel, iters=32):
        out = jax.tree.map(
            lambda v: self.sign_step * jnp.sign(v), combined)
        return out, residual, {}


# topk wire format: naive 16-bit distance coding per position (the paper's
# comparison baseline, Appx. A) + one fp32 value per surviving entry.
_TOPK_POSITION_BITS = 16.0
_TOPK_VALUE_BITS = 32.0


@register_protocol
@dataclasses.dataclass(frozen=True)
class TopKCodec(_ErrorFeedbackMixin, Codec):
    """Upload-only top-k sparsification + error feedback (Aji/Lin)."""

    name: ClassVar[str] = "topk"

    sparsity_up: float = 1 / 400

    def encode(self, delta, state):
        return compress_with_feedback(
            delta, state, lambda v: top_k_sparsify(v, self.sparsity_up))

    def _message_bits(self, numel: int, nnz: int) -> float:
        """Sparse message cost shared by the up/down ledger entries: 16-bit
        positions + 32-bit values, densifying to plain fp32 when full."""
        if nnz >= numel:
            return golomb.fedavg_message_bits(numel)
        return nnz * (_TOPK_POSITION_BITS + _TOPK_VALUE_BITS)

    def upload_bits(self, numel: int) -> float:
        k = max(int(numel * self.sparsity_up), 1)
        return self._message_bits(numel, k)

    def download_bits(self, numel: int, n_participating: int = 1) -> float:
        # upload-only compression: downstream density grows with clients
        # (Section V-A) until the update is effectively dense.
        k = max(int(numel * self.sparsity_up), 1)
        return self._message_bits(numel, min(k * n_participating, numel))

    # ---- tree path ----
    def tree_encode(self, delta, residual, *, numel, iters=32):
        from .distributed import stc_compress_tree, tree_add
        carried = tree_add(delta, residual)
        _, st = stc_compress_tree(carried, self.sparsity_up, numel=numel,
                                  iters=iters)
        # pure top-k keeps magnitudes: mask = |x| >= thresh
        msg = jax.tree.map(
            lambda x: jnp.where(jnp.abs(x) >= st.thresh, x, 0.0), carried)
        new_res = jax.tree.map(lambda c, t: c - t, carried, msg)
        return msg, new_res, {"nnz_up": st.nnz}


@register_protocol
@dataclasses.dataclass(frozen=True)
class StcCodec(_ErrorFeedbackMixin, Codec):
    """The paper's contribution: bidirectional sparse ternary compression +
    error feedback + Golomb-coded messages."""

    name: ClassVar[str] = "stc"

    sparsity_up: float = 1 / 400
    sparsity_down: float = 1 / 400
    backend: str = "jnp"                    # STC impl: "jnp" | "kernel"
    wire_backend: str = "numpy"             # wire packer: "numpy" | "kernel"
    # tree-path chunking (the mesh trainer's TrainConfig.chunks): when set,
    # tree_encode/tree_decode select per (leaf, chunk) block through the
    # backend registry instead of one global flat top-k -- selection then
    # stays local to each shard and pipelines across the mesh.  ``p_fn``
    # is the per-layer sparsity schedule hook (p_fn(layer_name, depth)).
    # The FLAT trainers chunk by wrapping (see repro.core.chunking); this
    # field only drives the tree path.
    chunk_size: Optional[int] = None
    p_fn: Optional[object] = None
    # adaptive per-chunk sparsity controller (repro.core.adaptive): a
    # registered name or SparsityController instance.  Like ``p_fn``, this
    # field only drives the TREE path; the flat trainers thread their
    # controller through chunk_codec(..., controller=) instead.
    controller: Optional[object] = None

    wire_format: ClassVar[bool] = True      # Golomb position stream (Alg. 3)
    wire_header_bits: ClassVar[float] = 32.0  # fp32 µ per message (Eq. 15)
    chunk_blocks: ClassVar[bool] = True     # fused (P, chunk, W) block path
    supports_ingest: ClassVar[bool] = True

    def init_server_state(self, numel: int) -> ResidualState:
        return init_residual(jnp.zeros((numel,), jnp.float32))

    def _wire_p(self, direction: str) -> float:
        return self.sparsity_up if direction == "up" else self.sparsity_down

    def encode_wire(self, msg, *, direction="up"):
        return wire.encode_ternary_words(msg, self._wire_p(direction),
                                         backend=self.wire_backend)

    def decode_wire(self, msg, *, direction="up"):
        return wire.decode_ternary_words(msg, self._wire_p(direction))

    def validate_wire(self, msg, *, direction="up"):
        # fields-only parse: every decoder corruption check fires without
        # materializing the dense vector
        wire.decode_ternary_fields(msg, self._wire_p(direction),
                                   backend=self.wire_backend)

    def wire_norm(self, msg):
        # a ternary message is nnz coordinates of magnitude |µ| exactly;
        # abs() matters: a Byzantine sign-flip negates µ on an otherwise
        # valid stream, and a negative "norm" would sail past the screen
        return abs(float(msg.mu)) * math.sqrt(max(int(msg.nnz), 0))

    def encode_wire_batch(self, msgs, *, direction="up"):
        return wire.encode_ternary_words_batch(
            np.asarray(msgs), self._wire_p(direction),
            backend=self.wire_backend)

    def wire_bound_bits(self, numel, nnz, direction="up"):
        return golomb.stc_stream_bound_bits(numel, nnz,
                                            self._wire_p(direction))

    def encode(self, delta, state):
        be = get_stc_backend(self.backend)
        msg, new_res, stats = be.compress_with_residual(
            delta, state.residual, self.sparsity_up)
        return msg, ResidualState(residual=new_res), stats

    def encode_batch(self, deltas, states):
        # one batched backend call (a single kernel launch per stage on the
        # "kernel" backend) instead of a vmap of selections
        be = get_stc_backend(self.backend)
        msgs, new_res, stats = be.compress_with_residual_batch(
            deltas, states.residual, self.sparsity_up)
        return msgs, ResidualState(residual=new_res), stats

    def aggregate(self, msgs, server_state, mask=None, staleness=None):
        be = get_stc_backend(self.backend)
        mean = self.combine(msgs, mask, staleness)
        out, new_res, stats = be.compress_with_residual(
            mean, server_state.residual, self.sparsity_down)
        return out, ResidualState(residual=new_res), stats

    # ---- fused ingest: Golomb fields -> accumulator scatter ----
    def ingest_wire_chunk(self, acc, msg, weight, *, direction="up",
                          offset=0):
        pos, signs = wire.decode_ternary_fields(
            msg, self._wire_p(direction), backend=self.wire_backend)
        acc.scatter_ternary(pos, signs, msg.mu, weight, offset=offset)

    #: fused-ingest decode block: rows are grouped so each multi-segment
    #: decode pass touches at most this many stream words, keeping the
    #: decode workspace bounded regardless of how many clients arrive.
    ingest_block_words: ClassVar[int] = 1 << 16

    def ingest_wire_batch(self, acc, batch, weights, *, direction="up"):
        # multi-segment field decode + one scatter per bounded word block
        # (bitwise the sequential ingest_wire loop: np.add.at applies in
        # element order, and the fields come out message-major)
        if self.rule.screens:
            # screened rounds take the per-message path: the screen is
            # per-message anyway, and this keeps batch == oracle bitwise
            # (a rejected row must not scatter or count nnz)
            return Codec.ingest_wire_batch(self, acc, batch, weights,
                                           direction=direction)
        w = np.asarray(weights, np.float64)
        for i in range(batch.n_msgs):
            acc.begin_message(float(w[i]),
                              bits=float(batch.bit_len[i])
                              + self.wire_header_bits)
        p = self._wire_p(direction)
        i0, P = 0, batch.n_msgs
        while i0 < P:
            i1, words = i0, 0
            while i1 < P and (i1 == i0
                              or words + int(batch.word_count[i1])
                              <= self.ingest_block_words):
                words += int(batch.word_count[i1])
                i1 += 1
            sub = batch.rows(i0, i1)
            seg, pos, signs = wire.decode_ternary_fields_batch(
                sub, p, backend=self.wire_backend)
            acc.scatter_ternary_batch(seg, pos, signs, sub.mu, w[i0:i1])
            i0 = i1

    def finalize_ingest(self, combined, server_state):
        be = get_stc_backend(self.backend)
        out, new_res, stats = be.compress_with_residual(
            jnp.asarray(combined), server_state.residual, self.sparsity_down)
        return out, ResidualState(residual=new_res), stats

    # ---- fused chunked block path (repro.core.chunking) ----
    def encode_chunk_blocks(self, blocks, states, *, ks):
        """One ``select_batch`` launch over every (client, chunk) row."""
        P, C, W = blocks.shape
        carried = (blocks.astype(jnp.float32)
                   + states.residual.astype(jnp.float32))
        tern, cnt, mu = stc_compress_blocks(
            carried.reshape(P * C, W), np.tile(np.asarray(ks), P),
            backend=self.backend)
        tern = tern.reshape(P, C, W)
        stats = CompressionStats(nnz=cnt.reshape(P, C).sum(axis=1),
                                 numel=jnp.full(P, C * W),
                                 mu=mu.reshape(P, C).mean(axis=1))
        return tern, ResidualState(residual=carried - tern), stats

    def aggregate_chunk_blocks(self, blocks, server_state, *, ks, mask=None,
                               staleness=None):
        mean = self.combine(blocks, mask, staleness)        # (C, W)
        carried = mean + server_state.residual.astype(jnp.float32)
        tern, cnt, mu = stc_compress_blocks(carried, ks, backend=self.backend)
        stats = CompressionStats(nnz=jnp.sum(cnt),
                                 numel=jnp.asarray(carried.size),
                                 mu=jnp.mean(mu))
        return tern, ResidualState(residual=carried - tern), stats

    # ---- adaptive-controller chunked path (repro.core.adaptive) ----
    def encode_chunk_blocks_adaptive(self, blocks, states, controller,
                                     ctrl_state, *, base_ks, caps):
        """Controller-chosen per-(client, chunk) k: the controller observes
        the carried (update + residual) blocks and picks traced ks, bounded
        by the static ``caps``, then one dynamic ``select_batch`` sweep
        compresses every row."""
        P, C, W = blocks.shape
        carried = (blocks.astype(jnp.float32)
                   + states.residual.astype(jnp.float32))
        ks, new_ctrl = controller.chunk_ks(carried, ctrl_state,
                                           base_ks=base_ks, caps=caps)
        tern, cnt, mu = stc_compress_blocks(
            carried.reshape(P * C, W), jnp.asarray(ks).reshape(P * C),
            backend=self.backend, k_cap=int(np.asarray(caps).max()))
        tern = tern.reshape(P, C, W)
        stats = CompressionStats(nnz=cnt.reshape(P, C).sum(axis=1),
                                 numel=jnp.full(P, C * W),
                                 mu=mu.reshape(P, C).mean(axis=1))
        return (tern, ResidualState(residual=carried - tern), new_ctrl,
                stats)

    def aggregate_chunk_blocks_adaptive(self, blocks, server_state,
                                        controller, ctrl_state, *, base_ks,
                                        caps, mask=None, staleness=None):
        mean = self.combine(blocks, mask, staleness)        # (C, W)
        carried = mean + server_state.residual.astype(jnp.float32)
        ks, new_ctrl = controller.chunk_ks(carried[None], ctrl_state,
                                           base_ks=base_ks, caps=caps)
        tern, cnt, mu = stc_compress_blocks(
            carried, jnp.asarray(ks).reshape(carried.shape[0]),
            backend=self.backend, k_cap=int(np.asarray(caps).max()))
        stats = CompressionStats(nnz=jnp.sum(cnt),
                                 numel=jnp.asarray(carried.size),
                                 mu=jnp.mean(mu))
        return (tern, ResidualState(residual=carried - tern), new_ctrl,
                stats)

    def upload_bits(self, numel: int) -> float:
        return golomb.stc_message_bits(numel, self.sparsity_up)

    def download_bits(self, numel: int, n_participating: int = 1) -> float:
        return golomb.stc_message_bits(numel, self.sparsity_down)

    # ---- tree path ----
    def tree_encode(self, delta, residual, *, numel, iters=32):
        from .distributed import (stc_compress_tree,
                                  stc_compress_tree_chunked, tree_add)
        carried = tree_add(delta, residual)
        if self.chunk_size:
            tern, st = stc_compress_tree_chunked(
                carried, self.sparsity_up, self.chunk_size, p_fn=self.p_fn,
                backend=self.backend, controller=self.controller)
        else:
            tern, st = stc_compress_tree(carried, self.sparsity_up,
                                         numel=numel, iters=iters)
        new_res = jax.tree.map(lambda c, t: c - t, carried, tern)
        return tern, new_res, {"nnz_up": st.nnz}

    def tree_decode(self, combined, residual, *, numel, iters=32):
        from .distributed import (stc_compress_tree,
                                  stc_compress_tree_chunked, tree_add)
        carried = tree_add(combined, residual)
        if self.chunk_size:
            down, st = stc_compress_tree_chunked(
                carried, self.sparsity_down, self.chunk_size, p_fn=self.p_fn,
                backend=self.backend, controller=self.controller)
        else:
            down, st = stc_compress_tree(carried, self.sparsity_down,
                                         numel=numel, iters=iters)
        new_res = jax.tree.map(lambda c, t: c - t, carried, down)
        return down, new_res, {"nnz_down": st.nnz}


@register_protocol
@dataclasses.dataclass(frozen=True)
class TernQuantCodec(_ErrorFeedbackMixin, Codec):
    """Dense ternary quantization à la T-FedAvg (Xu et al., 2020).

    Every coordinate is quantized to {-µ, 0, +µ} with TWN thresholding
    (Δ = θ·mean|x|) and error feedback on both sides; the wire format is an
    uncoded dense ternary stream (log2(3) bits/weight -- no position coding).
    Ships as the registry's proof that third-party codecs are drop-in.
    """

    name: ClassVar[str] = "ternquant"

    theta: float = 0.75                     # TWN threshold factor

    supports_ingest: ClassVar[bool] = True  # dense ingest only (no wire)

    def init_server_state(self, numel: int) -> ResidualState:
        return init_residual(jnp.zeros((numel,), jnp.float32))

    def encode(self, delta, state):
        return compress_with_feedback(
            delta, state, lambda v: ternary_quantize(v, self.theta))

    def aggregate(self, msgs, server_state, mask=None, staleness=None):
        mean = self.combine(msgs, mask, staleness)
        return compress_with_feedback(
            mean, server_state, lambda v: ternary_quantize(v, self.theta))

    def finalize_ingest(self, combined, server_state):
        return compress_with_feedback(
            jnp.asarray(combined), server_state,
            lambda v: ternary_quantize(v, self.theta))

    def upload_bits(self, numel: int) -> float:
        return golomb.ternary_dense_bits(numel)

    def download_bits(self, numel: int, n_participating: int = 1) -> float:
        return golomb.ternary_dense_bits(numel)

    # ---- tree path ----
    def tree_encode(self, delta, residual, *, numel, iters=32):
        from .distributed import ternary_quantize_tree, tree_add
        carried = tree_add(delta, residual)
        tern, st = ternary_quantize_tree(carried, self.theta, numel=numel)
        new_res = jax.tree.map(lambda c, t: c - t, carried, tern)
        return tern, new_res, {"nnz_up": st.nnz}

    def tree_decode(self, combined, residual, *, numel, iters=32):
        from .distributed import ternary_quantize_tree, tree_add
        carried = tree_add(combined, residual)
        down, st = ternary_quantize_tree(carried, self.theta, numel=numel)
        new_res = jax.tree.map(lambda c, t: c - t, carried, down)
        return down, new_res, {"nnz_down": st.nnz}


# The paper's comparison set (Table I); the live registry may hold more.
PROTOCOLS = ("baseline", "fedavg", "signsgd", "topk", "stc")
