"""Core compression operators of the STC paper (Sattler et al., 2019).

Implements, in pure jit-able JAX:

* ``top_k_sparsify``     -- top-k magnitude sparsification (Aji & Heafield '17)
* ``ternarize``          -- Algorithm 1: Sparse Ternary Compression of a tensor
* ``stc_compress``       -- sparsify + ternarize in one call (the STC operator)
* ``sign_compress``      -- signSGD quantization (Bernstein et al. '18)
* ``majority_vote_sign`` -- signSGD server aggregation
* pytree helpers that flatten a parameter pytree into a single vector so the
  "fraction p of *all* parameters" semantics of the paper hold globally rather
  than per-tensor (matching Algorithm 1's flattened-tensor input).

All operators are shape-polymorphic and dtype-preserving. Residual (error
feedback) handling lives in :mod:`repro.core.residual`.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CompressionStats",
    "top_k_mask",
    "top_k_sparsify",
    "ternarize",
    "ternary_quantize",
    "stc_compress",
    "stc_compress_blocks",
    "sign_compress",
    "majority_vote_sign",
    "flatten_pytree",
    "unflatten_pytree",
    "stc_compress_pytree",
    "StcBackend",
    "select_batch_dynamic",
    "register_stc_backend",
    "get_stc_backend",
    "STC_BACKENDS",
]


class CompressionStats(NamedTuple):
    """Side information produced by a compression op (for the bit ledger)."""

    nnz: jnp.ndarray        # number of non-zero elements communicated
    numel: jnp.ndarray      # total number of elements
    mu: jnp.ndarray         # ternary magnitude (0.0 for non-ternary schemes)


def _k_from_p(n: int, p: float) -> int:
    """Paper Algorithm 1 line 3: ``k <- max(np, 1)``."""
    return max(int(n * p), 1)


def top_k_mask(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask of the ``k`` largest-magnitude entries of flattened ``x``.

    Uses a threshold derived from ``jax.lax.top_k`` over magnitudes; ties at
    the threshold are broken deterministically by index so that *exactly* the
    mask of Algorithm 1 line 5 (``|T| >= v``, with v the k-th largest value) is
    produced.  Note the paper's mask can keep >k entries on ties; we follow the
    paper (>= threshold) because the downstream µ re-normalizes anyway.
    """
    flat = jnp.abs(x.reshape(-1))
    # kth largest magnitude == threshold v (paper line 4: v <- top_k(|T|)).
    v = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= v) & (jnp.abs(x) > 0.0)


def top_k_sparsify(x: jnp.ndarray, p: float) -> tuple[jnp.ndarray, CompressionStats]:
    """``top_p%`` operator of Eq. (8): keep the fraction-p largest magnitudes."""
    k = _k_from_p(x.size, p)
    mask = top_k_mask(x, k)
    out = jnp.where(mask, x, 0.0).astype(x.dtype)
    stats = CompressionStats(
        nnz=jnp.sum(mask), numel=jnp.asarray(x.size), mu=jnp.asarray(0.0, x.dtype)
    )
    return out, stats


def ternarize(x_masked: jnp.ndarray, mask: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Algorithm 1 lines 6-8: quantize kept entries to ``{-µ, 0, +µ}``.

    µ is the mean magnitude of the kept population. Returns ``(T*, µ)``.
    """
    k = jnp.maximum(jnp.sum(mask), 1)
    masked = jnp.where(mask, x_masked, 0.0)
    mu = jnp.sum(jnp.abs(masked)) / k.astype(x_masked.dtype)
    tern = mu * jnp.sign(masked)
    return tern.astype(x_masked.dtype), mu.astype(x_masked.dtype)


def stc_compress(x: jnp.ndarray, p: float) -> tuple[jnp.ndarray, CompressionStats]:
    """Sparse Ternary Compression: Algorithm 1 of the paper.

    ``T* = µ · sign(mask_k(T) · T)`` with ``k = max(|T|·p, 1)`` and µ the mean
    magnitude of the surviving entries.
    """
    k = _k_from_p(x.size, p)
    mask = top_k_mask(x, k)
    tern, mu = ternarize(x, mask)
    stats = CompressionStats(nnz=jnp.sum(mask), numel=jnp.asarray(x.size), mu=mu)
    return tern, stats


def ternary_quantize(x: jnp.ndarray, theta: float = 0.75) -> tuple[jnp.ndarray, CompressionStats]:
    """Dense ternary quantization (TWN thresholding; T-FedAvg, Xu et al. '20).

    Keeps every entry with ``|x| > Δ`` where ``Δ = θ·mean(|x|)`` and maps the
    survivors to ``{-µ, +µ}`` with µ the mean kept magnitude.  Unlike STC the
    message is *dense* on the wire (every coordinate carries a ternary symbol)
    so no position coding is needed -- see ``golomb.ternary_dense_bits``.
    """
    a = jnp.abs(x.astype(jnp.float32))
    delta = theta * jnp.mean(a)
    mask = a > delta
    k = jnp.maximum(jnp.sum(mask), 1)
    mu = jnp.sum(jnp.where(mask, a, 0.0)) / k.astype(jnp.float32)
    out = jnp.where(mask, mu * jnp.sign(x.astype(jnp.float32)), 0.0).astype(x.dtype)
    stats = CompressionStats(
        nnz=jnp.sum(mask), numel=jnp.asarray(x.size), mu=mu.astype(x.dtype)
    )
    return out, stats


def sign_compress(x: jnp.ndarray, step: float) -> tuple[jnp.ndarray, CompressionStats]:
    """signSGD with a coordinate-wise step size δ (paper Section VI uses δ=2e-4)."""
    out = (step * jnp.sign(x)).astype(x.dtype)
    stats = CompressionStats(
        nnz=jnp.asarray(x.size), numel=jnp.asarray(x.size),
        mu=jnp.asarray(step, x.dtype),
    )
    return out, stats


def majority_vote_sign(stacked_signs: jnp.ndarray, step: float,
                       weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """signSGD-with-majority-vote server aggregation (Bernstein et al. '18).

    ``stacked_signs``: (n_clients, ...) tensor of ±step (or ±1) client updates.
    Returns the ±step majority direction per coordinate.  ``weights`` (a
    per-client vector, e.g. participation-mask × staleness decay) turns the
    vote into a weighted vote -- an absent/zero-weight client simply does not
    vote; ``weights=None`` is the plain unweighted vote.
    """
    signs = jnp.sign(stacked_signs)
    if weights is not None:
        w = jnp.asarray(weights, signs.dtype)
        signs = signs * w.reshape((-1,) + (1,) * (signs.ndim - 1))
    vote = jnp.sign(jnp.sum(signs, axis=0))
    return (step * vote).astype(stacked_signs.dtype)


# ---------------------------------------------------------------------------
# Pytree-level helpers: the paper compresses the *flattened* update of the
# whole network, so top-k competes globally across layers.
# ---------------------------------------------------------------------------


def flatten_pytree(tree) -> tuple[jnp.ndarray, list]:
    """Concatenate all leaves into one fp32 vector; return (vector, treedef-ish)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [(l.shape, l.dtype) for l in leaves]
    vec = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return vec, (treedef, shapes)


def unflatten_pytree(vec: jnp.ndarray, spec) -> object:
    treedef, shapes = spec
    leaves = []
    offset = 0
    for shape, dtype in shapes:
        size = 1
        for s in shape:
            size *= s
        leaves.append(vec[offset : offset + size].reshape(shape).astype(dtype))
        offset += size
    return jax.tree.unflatten(treedef, leaves)


@functools.partial(jax.jit, static_argnames=("p",))
def stc_compress_pytree(tree, p: float):
    """Apply STC to the globally flattened pytree (paper semantics).

    Returns ``(compressed_tree, stats)``.
    """
    vec, spec = flatten_pytree(tree)
    tern, stats = stc_compress(vec, p)
    return unflatten_pytree(tern, spec), stats


# ---------------------------------------------------------------------------
# Compressor backend registry: the federated loop (and Protocol) pick the STC
# implementation by name -- "jnp" (lax.top_k operator above) or "kernel" (the
# Pallas histogram-selection path in repro.kernels).  Both produce oracle-
# identical (tern, new_residual, stats) so the flag is purely a perf choice.
# ---------------------------------------------------------------------------


class StcBackend(NamedTuple):
    """STC-with-error-feedback in single and batched (client-axis) forms.

    ``compress_with_residual(delta (n,), residual (n,), p)`` and
    ``compress_with_residual_batch(deltas (B, n), residuals (B, n), p)`` both
    return ``(msg, new_residual, CompressionStats)``; the batched form carries
    a leading client axis on every output.

    ``select_batch(x (B, n), ks)`` is the registry's k-selection primitive:
    ``ks`` is a static per-row k (int or (B,) array) and the result is
    ``(thresh, count, sum_abs)`` vectors of shape (B,) with ``thresh`` the
    exact ks[b]-th largest magnitude of row b (ties kept, as in
    :func:`top_k_mask`).  It serves the chunked ``(layer, chunk)`` block
    codecs and the per-leaf tree path, so "jnp" vs "kernel" is one flag for
    every selection sweep in the repo.

    ``select_batch_dynamic(x (B, n), ks, k_cap)`` is the TRACED-ks variant
    behind the adaptive sparsity controllers
    (:mod:`repro.core.adaptive`): ``ks`` may be a jnp array computed inside
    the jitted round, bounded by the static ``k_cap``.  Backends that leave
    it None fall back to the shared ``lax.top_k``-gather implementation
    (the histogram kernel needs static per-row k).
    """

    name: str
    compress_with_residual: object
    compress_with_residual_batch: object
    select_batch: object = None
    select_batch_dynamic: object = None


def _jnp_compress_with_residual(delta, residual, p: float):
    carried = delta.astype(jnp.float32) + residual.astype(jnp.float32)
    msg, stats = stc_compress(carried, p)
    return msg, carried - msg, stats


def _jnp_compress_with_residual_batch(deltas, residuals, p: float):
    return jax.vmap(
        lambda d, r: _jnp_compress_with_residual(d, r, p))(deltas, residuals)


def _static_ks(ks, n_rows: int, n: int) -> np.ndarray:
    """Normalize a static per-row k spec to a (B,) numpy int array."""
    arr = np.broadcast_to(np.asarray(ks, np.int64), (n_rows,))
    if arr.size and not (1 <= int(arr.min()) and int(arr.max()) <= n):
        raise ValueError(f"per-row k out of range [1, {n}]: {arr}")
    return arr


def _jnp_select_batch(x: jnp.ndarray, ks):
    """Per-row exact k-selection via one ``lax.top_k`` gather.

    The threshold is a pure selection (no arithmetic), and count/sum are
    mask-then-reduce in natural element order -- exactly the ops of
    :func:`top_k_mask` / :func:`ternarize`, so a single whole-vector row
    reproduces the flat path bit for bit.
    """
    bsz, n = x.shape
    ks = _static_ks(ks, bsz, n)
    a = jnp.abs(x.astype(jnp.float32))
    topc = jax.lax.top_k(a, min(int(ks.max()), n))[0]
    kj = jnp.asarray(ks, jnp.int32)
    v = jnp.take_along_axis(topc, (kj - 1)[:, None], axis=1)[:, 0]
    mask = (a >= v[:, None]) & (a > 0.0)
    cnt = jnp.sum(mask.astype(jnp.int32), axis=1)
    sums = jnp.sum(jnp.where(mask, a, 0.0), axis=1)
    return v, cnt, sums


def _jnp_select_batch_dynamic(x: jnp.ndarray, ks, k_cap: int):
    """Per-row k-selection with TRACED per-row ks (the adaptive-controller
    path): one static-size ``top_k`` of width ``k_cap`` bounds the
    workspace, then the row's threshold is a dynamic ``take_along_axis``
    gather at ``ks[b]-1``.  For any concrete ks <= k_cap this computes
    exactly what :func:`_jnp_select_batch` computes (same selection, same
    tie semantics); ks are clipped into ``[1, k_cap]``.
    """
    bsz, n = x.shape
    k_cap = min(int(k_cap), n)
    if k_cap < 1:
        raise ValueError(f"k_cap must be >= 1, got {k_cap}")
    a = jnp.abs(x.astype(jnp.float32))
    topc = jax.lax.top_k(a, k_cap)[0]
    kj = jnp.clip(jnp.asarray(ks, jnp.int32), 1, k_cap)
    v = jnp.take_along_axis(topc, (kj - 1)[:, None], axis=1)[:, 0]
    mask = (a >= v[:, None]) & (a > 0.0)
    cnt = jnp.sum(mask.astype(jnp.int32), axis=1)
    sums = jnp.sum(jnp.where(mask, a, 0.0), axis=1)
    return v, cnt, sums


def select_batch_dynamic(x: jnp.ndarray, ks, k_cap: int, *,
                         backend: str = "jnp"):
    """Registry dispatch for the traced-ks selection (falls back to the
    shared jnp implementation for backends without a dynamic kernel)."""
    be = get_stc_backend(backend)
    sel = be.select_batch_dynamic or _jnp_select_batch_dynamic
    return sel(x, ks, k_cap)


def stc_compress_blocks(carried: jnp.ndarray, ks, *, backend: str = "jnp",
                        k_cap: Optional[int] = None):
    """STC over independent (B, block_numel) rows with per-row k.

    The chunked-codec core: every row (one ``(layer, chunk)`` block, zero-
    padded past its valid length -- padding can never be selected since
    exact zeros are excluded) gets its own threshold and ternary magnitude.
    Returns ``(tern, count, mu)`` with ``tern`` of the input shape and
    (B,) count/mu vectors.  A single whole-vector row is bit-identical to
    :func:`stc_compress`.

    ``ks`` is normally a static numpy/int spec; a jnp array (possibly a
    tracer -- the adaptive-controller path) switches to the dynamic
    selection, which then needs the static ceiling ``k_cap``.
    """
    be = get_stc_backend(backend)
    a = jnp.abs(carried.astype(jnp.float32))
    if isinstance(ks, jax.Array):
        if k_cap is None:
            raise ValueError(
                "traced per-row ks (adaptive controller) require a static "
                "k_cap bound; pass k_cap=int(caps.max())")
        sel = be.select_batch_dynamic or _jnp_select_batch_dynamic
        thresh, cnt, sums = sel(carried, ks, int(k_cap))
    elif be.select_batch is None:
        raise NotImplementedError(
            f"STC backend {be.name!r} does not implement select_batch; "
            "chunked (layer, chunk) selection requires it -- see "
            "StcBackend.select_batch")
    else:
        thresh, cnt, sums = be.select_batch(carried, ks)
    mu = sums / jnp.maximum(cnt, 1).astype(jnp.float32)
    mask = (a >= thresh[:, None]) & (a > 0.0)
    tern = jnp.where(mask, mu[:, None] * jnp.sign(carried.astype(jnp.float32)),
                     0.0)
    return tern, cnt, mu


STC_BACKENDS: dict[str, StcBackend] = {
    "jnp": StcBackend("jnp", _jnp_compress_with_residual,
                      _jnp_compress_with_residual_batch,
                      _jnp_select_batch, _jnp_select_batch_dynamic),
}


def register_stc_backend(backend: StcBackend) -> None:
    STC_BACKENDS[backend.name] = backend


def _make_kernel_backend() -> StcBackend:
    # lazy: keeps core import-light and avoids a hard kernels dependency here
    from repro.kernels import (hist_topk_threshold_batched, stc_compress_batch,
                               stc_compress_kernel)

    def single(delta, residual, p: float):
        tern, new_res, mu, _, nnz = stc_compress_kernel(delta, residual, p)
        stats = CompressionStats(nnz=nnz, numel=jnp.asarray(delta.size), mu=mu)
        return tern, new_res, stats

    def batch(deltas, residuals, p: float):
        tern, new_res, mu, _, nnz = stc_compress_batch(deltas, residuals, p)
        numel = jnp.full(deltas.shape[0], deltas.shape[1])
        stats = CompressionStats(nnz=nnz, numel=numel, mu=mu)
        return tern, new_res, stats

    def select(x, ks):
        # histogram selection batched over every (client, chunk) row in ONE
        # kernel launch (per-row k rides in as a vector)
        return hist_topk_threshold_batched(
            x, _static_ks(ks, x.shape[0], x.shape[1]))

    return StcBackend("kernel", single, batch, select)


def get_stc_backend(name: str) -> StcBackend:
    """Look up a registered STC backend ("jnp" / "kernel") by name."""
    if name == "kernel" and name not in STC_BACKENDS:
        register_stc_backend(_make_kernel_backend())
    if name not in STC_BACKENDS:
        raise ValueError(
            f"unknown STC backend {name!r}; options: "
            f"{sorted(set(STC_BACKENDS) | {'kernel'})}")
    return STC_BACKENDS[name]
