"""Vectorized wire-format subsystem: batched Golomb/ternary bitstream packing.

The paper's communication claims rest on the REAL Golomb-encoded ternary
bitstream (Algorithms 3-4, Eqs. 15-17).  The per-bit host loop in
:mod:`repro.core.golomb` is the correctness oracle; this module is the
production packer, vectorized end to end:

1. **Codeword fields** -- every non-zero's gap splits into the Golomb pair
   ``(q, r) = divmod(gap - 1, 2^b*)``; the codeword is ``q`` unary ones, a
   terminator ``0``, ``b*`` remainder bits (MSB first) and one sign bit.
   All fields are computed with numpy vector ops over the whole tensor.
2. **Chunk decomposition** -- each codeword becomes ``q // 32`` full
   32-one chunks plus one tail chunk ``(rem_ones, 0, r, sign)`` of at most
   ``31 + b* + 2 <= 63`` bits, so every chunk fits a uint64 ``(length,
   value)`` pair regardless of how pathological the gaps are.
3. **Exclusive-scan scatter** -- chunk bit offsets are the exclusive cumsum
   of chunk lengths; each chunk lands in the packed word stream with two
   masked shifts (a chunk spans at most one uint64 boundary), OR-aggregated
   per word by ``bitwise_or.reduceat`` over the (sorted) word indices.
   No per-bit Python anywhere.

The packed stream is canonical: stream bit ``t`` lives in uint32 word
``t >> 5`` at bit ``31 - (t & 31)`` (MSB-first), so the byte view equals
``np.packbits`` of the oracle's bit sequence -- bit-identical streams are a
byte-compare away (asserted in tests/test_wire.py).

``encode_ternary_words_batch`` packs a whole federated round's ``(P, numel)``
client messages in ONE vectorized pass into a single word-aligned stream
(per-client slices are views), which beats P sequential single-message packs.

Backends mirror :func:`repro.core.compression.get_stc_backend`: ``"numpy"``
is the host scatter above; ``"kernel"`` expands chunks to a bit tensor and
packs 32-bit words on-device through the Pallas kernel in
:mod:`repro.kernels.bitpack`, so TPU and CPU share one API.

Decode is vectorized end to end -- and multi-segment: ONE pass parses every
client stream of a word-aligned batch.  One bit unpack (host ``unpackbits``
or the Pallas :mod:`repro.kernels.wiredecode` kernel on the ``"kernel"``
backend), one ``searchsorted`` over the zero positions giving each candidate
terminator its successor (capped at its own segment's data end), then a
pointer-doubling transitive closure -- ``O(Z log Z)`` array ops, no Python
chase -- marks each segment's terminator chain; batch gathers recover
remainders and signs and a segmented cumsum the positions.  Truncated or
corrupt payloads (``bit_len`` past the buffer, a run past ``numel``, a
stream ending mid-codeword) raise :class:`WireDecodeError` on every path.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np

from . import golomb

__all__ = [
    "WireMessage",
    "WireBatch",
    "ChunkedWireBatch",
    "ChunkedWireMessage",
    "WireBackend",
    "WireDecodeError",
    "register_wire_backend",
    "get_wire_backend",
    "encode_ternary_words",
    "encode_ternary_words_batch",
    "decode_ternary_words",
    "decode_ternary_words_batch",
    "decode_ternary_fields",
    "decode_ternary_fields_batch",
    "pack_sign_words",
    "unpack_sign_words",
    "sign_plane_bits",
    "concat_messages",
    "words_to_bits",
    "words_to_bytes",
]


class WireDecodeError(ValueError):
    """A wire payload failed validation during decode: the advertised
    ``bit_len`` overruns the word buffer, a unary run crosses the stream
    end, the stream ends mid-codeword, or a decoded position overflows the
    target tensor.  Subclasses :class:`ValueError` so pre-existing callers
    catching the old untyped errors keep working."""

_U64 = np.uint64
_MAX_B_STAR = 30  # tail chunk must fit 63 bits: 31 ones + b* + 2
# fused-batch crossover: above this many total non-zeros the fused pass's
# working set leaves L2 and cache-resident per-client packs are faster
_FUSED_NNZ_MAX = 32768


class WireMessage(NamedTuple):
    """One packed bitstream message.

    ``words`` is the canonical uint32 stream (MSB-first within each word),
    ``bit_len`` the number of meaningful bits, ``mu`` the ternary magnitude
    (or the signSGD step), ``numel`` the decoded tensor length and ``nnz``
    the number of coded positions (= ``numel`` for dense sign streams).
    """

    words: np.ndarray
    bit_len: int
    mu: float
    numel: int
    nnz: int

    def payload_bytes(self) -> np.ndarray:
        """Packed uint8 view, trimmed to ``ceil(bit_len / 8)`` bytes."""
        return words_to_bytes(self.words, self.bit_len)


class WireBatch(NamedTuple):
    """A batch of messages packed into ONE word-aligned uint32 stream.

    Client ``i`` owns ``words[word_start[i] : word_start[i] + word_count[i]]``
    with ``bit_len[i]`` meaningful bits; slicing is a view, not a copy.
    """

    words: np.ndarray       # (total_words,) uint32
    word_start: np.ndarray  # (P,) int64
    word_count: np.ndarray  # (P,) int64
    bit_len: np.ndarray     # (P,) int64
    mu: np.ndarray          # (P,) float64
    nnz: np.ndarray         # (P,) int64
    numel: int

    @property
    def n_msgs(self) -> int:
        return len(self.bit_len)

    def message(self, i: int) -> WireMessage:
        s, c = int(self.word_start[i]), int(self.word_count[i])
        return WireMessage(self.words[s : s + c], int(self.bit_len[i]),
                           float(self.mu[i]), self.numel, int(self.nnz[i]))

    def rows(self, i0: int, i1: int) -> "WireBatch":
        """View of message rows ``[i0, i1)`` as their own batch (no copy --
        rows are word-contiguous by construction).  Lets the ingest path
        decode a fleet round in bounded-workspace blocks."""
        w0 = int(self.word_start[i0]) if i1 > i0 else 0
        w1 = (int(self.word_start[i1 - 1] + self.word_count[i1 - 1])
              if i1 > i0 else 0)
        return WireBatch(self.words[w0:w1], self.word_start[i0:i1] - w0,
                         self.word_count[i0:i1], self.bit_len[i0:i1],
                         self.mu[i0:i1], self.nnz[i0:i1], self.numel)

    def total_bits(self) -> float:
        return float(self.bit_len.sum())


class ChunkedWireBatch(NamedTuple):
    """A round of chunked messages: per-(message, chunk) sub-streams.

    The chunked codecs (:mod:`repro.core.chunking`) frame every message as
    one independent sub-stream PER CHUNK, each with its own side-information
    header (e.g. a per-chunk Golomb µ).  Chunks sharing wire parameters are
    fused group-wise: ``batches[g]`` is ONE word-aligned :class:`WireBatch`
    whose rows are message-major -- row ``p * len(chunk_ids[g]) + j`` is
    message ``p``'s sub-stream for chunk ``chunk_ids[g][j]`` (a tensor of
    ``chunk_valid[g]`` decoded elements).

    ``bit_len`` / ``nnz`` are per-MESSAGE totals (summed over that message's
    chunks), so the ledger sees the same shape contract as
    :class:`WireBatch`.
    """

    batches: tuple          # tuple[WireBatch], one per wire-parameter group
    chunk_ids: tuple        # tuple[tuple[int, ...]] chunk ids per group
    chunk_valid: tuple      # tuple[int] decoded elements per chunk, per group
    bit_len: np.ndarray     # (P,) total stream bits per message
    nnz: np.ndarray         # (P,) total coded positions per message
    n_msgs: int
    numel: int              # decoded (merged) tensor length
    n_chunks: int

    def total_bits(self) -> float:
        return float(self.bit_len.sum())

    def message(self, i: int) -> "ChunkedWireMessage":
        """Message ``i`` as a standalone single-row chunked batch (per-group
        word buffers are copies of just that message's rows, so the view is
        safe to ship through the arrival simulator independently)."""
        subs = []
        for wb, ids in zip(self.batches, self.chunk_ids):
            g = len(ids)
            subs.append(concat_messages([wb.message(i * g + j)
                                         for j in range(g)]))
        return ChunkedWireMessage(ChunkedWireBatch(
            tuple(subs), self.chunk_ids, self.chunk_valid,
            self.bit_len[i : i + 1], self.nnz[i : i + 1], 1, self.numel,
            self.n_chunks))


class ChunkedWireMessage(NamedTuple):
    """ONE chunked message (a :class:`ChunkedWireBatch` with ``n_msgs==1``),
    quacking like :class:`WireMessage` for the trainers' ledger hooks."""

    batch: ChunkedWireBatch

    @property
    def bit_len(self) -> int:
        return int(self.batch.bit_len[0])

    @property
    def nnz(self) -> int:
        return int(self.batch.nnz[0])

    @property
    def numel(self) -> int:
        return self.batch.numel

    @property
    def n_chunks(self) -> int:
        return self.batch.n_chunks


# ---------------------------------------------------------------------------
# word-stream helpers (canonical bit order: MSB-first within uint32 words)
# ---------------------------------------------------------------------------


def words_to_bytes(words: np.ndarray, bit_len: int) -> np.ndarray:
    """uint32 word stream -> packed uint8 payload (np.packbits convention)."""
    by = np.ascontiguousarray(np.asarray(words).astype(">u4")).view(np.uint8)
    return by[: (int(bit_len) + 7) // 8]


def words_to_bits(words: np.ndarray, bit_len: int) -> np.ndarray:
    """uint32 word stream -> uint8 0/1 array of length ``bit_len``."""
    nbytes = (int(bit_len) + 7) // 8
    payload = words_to_bytes(words, 8 * nbytes)
    return np.unpackbits(payload)[: int(bit_len)]


def _bytes_to_words(payload: np.ndarray) -> np.ndarray:
    by = np.ascontiguousarray(payload, np.uint8)
    pad = (-by.size) % 4
    if pad:
        by = np.concatenate([by, np.zeros(pad, np.uint8)])
    return by.view(">u4").astype(np.uint32)


# ---------------------------------------------------------------------------
# packing backends ("numpy" host scatter / "kernel" Pallas word packer)
# ---------------------------------------------------------------------------


class WireBackend(NamedTuple):
    """How chunk streams and dense bit planes become uint32 words -- and back.

    ``pack_chunks(vals, lens, offs, total_bits)``: uint64 ``(value, length)``
    chunk arrays at exclusive-scan bit offsets -> canonical uint32 words.
    ``pack_bits(bits)``: a dense uint8 0/1 array -> canonical uint32 words.
    ``unpack_bits(words)``: the decode inverse -- ALL ``32 * n_words`` MSB-
    first bits as uint8 0/1 (``None`` falls back to the numpy route, so
    pre-existing backend registrations stay valid).
    All must be bit-identical across backends.
    """

    name: str
    pack_chunks: Callable
    pack_bits: Callable
    unpack_bits: Callable | None = None


def _or_group_sorted(u64: np.ndarray, idx: np.ndarray,
                     contrib: np.ndarray) -> None:
    """``u64[idx] |= contrib`` with OR-aggregation of duplicate indices.

    ``idx`` is non-decreasing (chunk offsets are an exclusive scan), so the
    duplicates are runs: one ``bitwise_or.reduceat`` per run start replaces
    the (much slower) ``ufunc.at`` scatter.
    """
    first = np.empty(idx.shape, bool)
    first[0] = True
    np.not_equal(idx[1:], idx[:-1], out=first[1:])
    starts = np.flatnonzero(first)
    u64[idx[starts]] |= np.bitwise_or.reduceat(contrib, starts)


def _scatter_chunks_numpy(vals: np.ndarray, lens: np.ndarray,
                          offs: np.ndarray, total_bits: int) -> np.ndarray:
    """Exclusive-scan chunk scatter into uint64 accumulation words.

    (A uint32-specialized variant for <=32-bit chunks was measured SLOWER
    than this uint64 path on x86 numpy -- the narrow-int ops don't pay for
    the extra conversions -- so one width serves every regime.)
    """
    n_words32 = (int(total_bits) + 31) // 32
    u64 = np.zeros((n_words32 + 1) // 2, _U64)
    if len(vals):
        vals = vals.astype(_U64, copy=False)
        end = (offs + lens).astype(_U64)
        k_hi = (end - _U64(1)) >> _U64(6)
        s = _U64(64) * (k_hi + _U64(1)) - end          # 0..63
        _or_group_sorted(u64, k_hi, np.left_shift(vals, s))
        k_lo = offs.astype(_U64) >> _U64(6)
        cross = k_lo != k_hi                            # cross => 1 <= 64-s <= 63
        if cross.any():
            _or_group_sorted(u64, k_lo[cross],
                             np.right_shift(vals[cross], _U64(64) - s[cross]))
    words = np.empty(2 * u64.size, np.uint32)
    words[0::2] = (u64 >> _U64(32)).astype(np.uint32)
    words[1::2] = (u64 & _U64(0xFFFFFFFF)).astype(np.uint32)
    return words[:n_words32]


def _pack_bits_numpy(bits: np.ndarray) -> np.ndarray:
    return _bytes_to_words(np.packbits(np.asarray(bits, np.uint8)))


def _unpack_bits_numpy(words: np.ndarray) -> np.ndarray:
    return words_to_bits(words, 32 * int(np.asarray(words).size))


def _chunks_to_bits(vals: np.ndarray, lens: np.ndarray, offs: np.ndarray,
                    total_bits: int) -> np.ndarray:
    """Expand (value, length) chunks at explicit bit offsets into 0/1.

    Offsets may leave gaps (the batched stream word-aligns each client);
    gap bits stay zero, matching the scatter backend's padding.
    """
    bits = np.zeros(int(total_bits), np.uint8)
    if not len(vals):
        return bits
    owner = np.repeat(np.arange(len(lens)), lens)
    dense_start = np.cumsum(lens) - lens
    within = np.arange(int(lens.sum())) - dense_start[owner]
    shift = (lens[owner] - 1 - within).astype(_U64)
    bits[offs[owner] + within] = (
        (vals.astype(_U64)[owner] >> shift) & _U64(1)).astype(np.uint8)
    return bits


WIRE_BACKENDS: dict[str, WireBackend] = {
    "numpy": WireBackend("numpy", _scatter_chunks_numpy, _pack_bits_numpy,
                         _unpack_bits_numpy),
}


def register_wire_backend(backend: WireBackend) -> None:
    WIRE_BACKENDS[backend.name] = backend


def _make_kernel_backend() -> WireBackend:
    # lazy: keeps core import-light (layering: kernels -> core, never back)
    from repro.kernels import pack_bits_words, unpack_bits_words

    def pack_bits(bits: np.ndarray) -> np.ndarray:
        return np.asarray(pack_bits_words(np.asarray(bits, np.uint8)))

    def pack_chunks(vals, lens, offs, total_bits):
        # vectorized chunk->bit expansion on the host; the 32-bit word
        # assembly itself runs as the Pallas packing kernel
        return pack_bits(_chunks_to_bits(vals, lens, offs, total_bits))

    def unpack_bits(words: np.ndarray) -> np.ndarray:
        # per-word bit extraction on-device (the dense half of decode); the
        # chain/field logic stays the host's vectorized scan, mirroring the
        # encode-side split
        return np.asarray(unpack_bits_words(np.ascontiguousarray(words)))

    return WireBackend("kernel", pack_chunks, pack_bits, unpack_bits)


def _backend_unpack(backend: str, words: np.ndarray) -> np.ndarray:
    """All ``32 * n_words`` stream bits through the named backend (entries
    registered before the decode API fall back to the numpy route)."""
    be = get_wire_backend(backend)
    if be.unpack_bits is None:
        return _unpack_bits_numpy(words)
    return be.unpack_bits(words)


def get_wire_backend(name: str) -> WireBackend:
    """Look up a registered wire packing backend ("numpy" / "kernel")."""
    if name == "kernel" and name not in WIRE_BACKENDS:
        register_wire_backend(_make_kernel_backend())
    if name not in WIRE_BACKENDS:
        raise ValueError(
            f"unknown wire backend {name!r}; options: "
            f"{sorted(set(WIRE_BACKENDS) | {'kernel'})}")
    return WIRE_BACKENDS[name]


# ---------------------------------------------------------------------------
# Golomb ternary encode (vectorized Algorithms 3/4)
# ---------------------------------------------------------------------------


def _b_star_checked(p: float) -> int:
    b = golomb.golomb_b_star(p)
    if b > _MAX_B_STAR:
        raise ValueError(
            f"golomb b*={b} exceeds the packer's 63-bit tail chunk "
            f"(p={p} is far below any practical sparsity)")
    return b


def _codeword_chunks(d: np.ndarray, signs: np.ndarray, b: int):
    """Vectorized codeword fields -> uint64 (value, length) chunk arrays.

    ``d`` is gap-1 per non-zero (int64, >= 0), ``signs`` bool.  Returns
    ``(vals, lens, lengths)`` where ``lengths`` is bits per codeword.
    """
    if b:
        q, r = d >> b, d & ((1 << b) - 1)
    else:
        q, r = d, None
    lengths = q + (b + 2)
    if int(q.max(initial=0)) < 32:
        # fast path (overwhelmingly common: quotients < 32 whenever the
        # configured p is within ~3 octaves of the realized sparsity):
        # one <=63-bit chunk per codeword, no repeat/ownership machinery
        tail_val = ((_U64(1) << q.astype(_U64)) - _U64(1)) << _U64(b + 2)
        if r is not None:
            tail_val |= r.astype(_U64) << _U64(1)
        tail_val |= signs.astype(_U64)
        return tail_val, lengths, lengths
    f = (q >> 5).astype(np.int64)        # full 32-one chunks per codeword
    rem = (q & 31).astype(_U64)
    # tail chunk: rem ones, terminator 0, b remainder bits, sign (<= 63 bits)
    tail_val = ((((_U64(1) << rem) - _U64(1)) << _U64(b + 2))
                | signs.astype(_U64))
    if r is not None:
        tail_val |= r.astype(_U64) << _U64(1)
    tail_len = rem.astype(np.int64) + b + 2
    counts = f + 1
    total_chunks = int(counts.sum())
    owner = np.repeat(np.arange(len(q)), counts)
    starts = np.cumsum(counts) - counts
    is_tail = (np.arange(total_chunks) - starts[owner]) == f[owner]
    vals = np.where(is_tail, tail_val[owner], _U64(0xFFFFFFFF))
    lens = np.where(is_tail, tail_len[owner], 32)
    return vals, lens, lengths


def _encode_from_nz(x: np.ndarray, nz: np.ndarray, b: int,
                    backend: str) -> WireMessage:
    """Pack one flat ternary vector given its precomputed nonzero indices."""
    n = int(x.size)
    if nz.size == 0:
        return WireMessage(np.zeros(0, np.uint32), 0, 0.0, n, 0)
    nzv = x[nz]
    mu = float(np.abs(nzv).mean())
    d = np.diff(nz, prepend=np.int64(-1)) - 1           # gap-1 >= 0
    vals, lens, _ = _codeword_chunks(d, (nzv > 0), b)
    cs = np.cumsum(lens)
    offs = cs - lens
    total_bits = int(cs[-1])    # == lengths.sum(): chunks partition codewords
    words = get_wire_backend(backend).pack_chunks(vals, lens, offs,
                                                  total_bits)
    return WireMessage(words, total_bits, mu, n, int(nz.size))


def encode_ternary_words(tensor: np.ndarray, p: float, *,
                         backend: str = "numpy") -> WireMessage:
    """Vectorized Algorithm 3: pack a flat ternary tensor into uint32 words.

    Bit-identical to :func:`repro.core.golomb.encode_ternary` (the per-bit
    oracle), orders of magnitude faster on real model sizes.
    """
    b = _b_star_checked(p)
    x = np.asarray(tensor).reshape(-1)
    nz = np.flatnonzero(x != 0)       # bool scan: ~10x faster than on floats
    return _encode_from_nz(x, nz, b, backend)


def encode_ternary_words_batch(tensors: np.ndarray, p: float, *,
                               backend: str = "numpy") -> WireBatch:
    """Batched client-axis encode: ``(P, numel)`` -> one word-aligned stream.

    Cache-resident per-row nonzero scans, then ONE fused chunk/scatter pass
    for the whole cohort; each client's stream starts on a 32-bit word
    boundary so per-client slices are views into the shared buffer.
    """
    b = _b_star_checked(p)
    x = np.asarray(tensors)
    assert x.ndim == 2, x.shape
    P, n = x.shape
    # per-row bool scans stay cache-resident (one (P*n,) scan thrashes LLC)
    per_client = [np.flatnonzero(x[i] != 0) for i in range(P)]
    nnz_c = np.asarray([v.size for v in per_client], np.int64)
    nnz_total = int(nnz_c.sum())
    if nnz_total == 0:
        z = np.zeros(P, np.int64)
        return WireBatch(np.zeros(0, np.uint32), z, z.copy(), z.copy(),
                         np.zeros(P, np.float64), z.copy(), n)
    if nnz_total > _FUSED_NNZ_MAX:
        # dense regime: the fused pass's working set falls out of L2 and
        # per-element cost triples; cache-resident per-client packs win
        # (reusing the scans above)
        return concat_messages([
            _encode_from_nz(x[i], per_client[i], b, backend)
            for i in range(P)])
    # sparse regime (the paper's operating point): ONE fused vectorized
    # pass over all clients amortizes every fixed-cost stage
    pos = np.concatenate(per_client)
    seg_start = np.cumsum(nnz_c) - nnz_c      # first codeword per client
    nonempty = nnz_c > 0                      # reduceat over these starts
    cl = np.repeat(np.arange(P), nnz_c)
    nzvals = x[cl, pos]
    mu_c = np.zeros(P, np.float64)
    mu_c[nonempty] = (np.add.reduceat(np.abs(nzvals, dtype=np.float64),
                                      seg_start[nonempty])
                      / nnz_c[nonempty])

    first = np.zeros(cl.size, bool)
    first[seg_start[nonempty]] = True
    prev = np.empty_like(pos)
    prev[0] = -1
    prev[1:] = pos[:-1]
    d = np.where(first, pos, pos - prev - 1).astype(np.int64)  # gap-1
    vals, lens, lengths = _codeword_chunks(d, (nzvals > 0), b)

    bits_c = np.zeros(P, np.int64)
    bits_c[nonempty] = np.add.reduceat(lengths, seg_start[nonempty])
    word_count = (bits_c + 31) // 32
    word_start = np.cumsum(word_count) - word_count
    # per-codeword global offset: within-client exclusive scan, rebased to
    # the client's word-aligned start
    excl = np.cumsum(lengths) - lengths
    bits_before_client = np.concatenate([[0], np.cumsum(bits_c)[:-1]])
    rebase = 32 * word_start - bits_before_client
    offsets_cw = excl + rebase[cl]
    if len(vals) == len(lengths):
        offs = offsets_cw           # fast path: one chunk per codeword
    else:
        # a codeword's chunks are f 32-one words then the tail, contiguous
        # from its offset; f = (codeword_bits - b - 2) >> 5
        f = ((lengths - b - 2) >> 5).astype(np.int64)
        chunk_counts = f + 1
        owner = np.repeat(np.arange(len(lengths)), chunk_counts)
        starts = np.cumsum(chunk_counts) - chunk_counts
        within = np.arange(int(chunk_counts.sum())) - starts[owner]
        offs = offsets_cw[owner] + 32 * within
    total_words = int(word_count.sum())
    words = get_wire_backend(backend).pack_chunks(
        vals, lens, offs, 32 * total_words)
    return WireBatch(words[:total_words], word_start, word_count, bits_c,
                     mu_c, nnz_c, n)


# ---------------------------------------------------------------------------
# decode (vectorized Algorithm 4, multi-segment)
# ---------------------------------------------------------------------------


def _decode_stream_fields(bits: np.ndarray, seg_start: np.ndarray,
                          seg_len: np.ndarray, numel: int,
                          b: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Parse every segment's Golomb codewords out of ONE unpacked bit array.

    ``bits`` covers ALL ``32 * n_words`` stream bits (word padding included);
    segment ``i`` owns ``[seg_start[i], seg_start[i] + seg_len[i])``.  A
    codeword terminator is a 0-bit whose successor terminator sits ``b + 2``
    bits past it: one ``searchsorted`` over the zero positions builds those
    links for every candidate at once (final terminators -- landing exactly
    on their segment's data end -- and overruns point at a sentinel), then a
    pointer-doubling transitive closure marks each segment's chain from its
    first zero in ``O(Z log Z)`` array ops.  Padding zeros overrun their
    segment end, so a reached overrun IS a truncated codeword; every active
    segment must reach a final terminator or the stream ended mid-codeword.
    (A corrupt segment's chain may escape into a neighbour's zeros -- that
    only ADDS failure flags, never removes one, so valid batches are immune.)

    Returns ``(cw_seg, positions, signs)``: the owning segment index, decoded
    tensor position and ±1.0 sign of every codeword, segment-major in stream
    order.  Raises :class:`WireDecodeError` on any corruption.
    """
    empty = (np.zeros(0, np.int64), np.zeros(0, np.int64),
             np.zeros(0, np.float32))
    active = np.flatnonzero(seg_len > 0)
    if active.size == 0:
        return empty
    seg_end = seg_start + seg_len
    zeros = np.flatnonzero(bits == 0).astype(np.int64)
    Z = zeros.size
    if Z == 0:
        raise WireDecodeError("corrupt golomb stream: no unary terminator")
    seg_of = np.searchsorted(seg_start, zeros, side="right") - 1
    nxt = zeros + b + 2
    is_final = nxt == seg_end[seg_of]
    overrun = nxt > seg_end[seg_of]
    succ = np.full(Z + 1, Z, np.int64)          # sentinel self-loop at Z
    interior = ~(is_final | overrun)
    succ[:Z][interior] = np.searchsorted(zeros, nxt[interior])
    seeds = np.searchsorted(zeros, seg_start[active])
    if np.any(seeds >= Z):
        raise WireDecodeError("corrupt golomb stream: no unary terminator")
    reached = np.zeros(Z + 1, bool)
    reached[seeds] = True
    jump = succ                                  # covers 2^k steps at iter k
    while True:
        idx = np.flatnonzero(reached[:Z])
        reached[jump[idx]] = True
        if np.count_nonzero(reached[:Z]) == idx.size:
            break
        jump = jump[jump]
    sel = reached[:Z]
    if np.any(sel & overrun):
        raise WireDecodeError("corrupt golomb stream: truncated codeword")
    ok = np.zeros(len(seg_start), bool)
    ok[seg_of[sel & is_final]] = True
    if not ok[active].all():
        raise WireDecodeError("corrupt golomb stream: truncated codeword")
    T = zeros[sel]                               # terminators, stream order
    cw_seg = seg_of[sel]
    first = np.ones(T.size, bool)
    first[1:] = cw_seg[1:] != cw_seg[:-1]
    fidx = np.flatnonzero(first)
    starts = np.empty_like(T)
    starts[fidx] = seg_start[cw_seg[fidx]]
    nonfirst = np.flatnonzero(~first)
    starts[nonfirst] = T[nonfirst - 1] + b + 2
    q = T - starts
    if b:
        rbits = bits[T[:, None] + 1 + np.arange(b)].astype(np.int64)
        r = rbits @ (1 << np.arange(b - 1, -1, -1, dtype=np.int64))
    else:
        r = np.zeros_like(q)
    signs = np.where(bits[T + b + 1] == 1, np.float32(1.0), np.float32(-1.0))
    gaps = q * (np.int64(1) << np.int64(b)) + r + 1
    cum = np.cumsum(gaps)
    seg_base = cum[fidx] - gaps[fidx]            # segmented cumsum rebase
    counts = np.diff(np.append(fidx, T.size))
    positions = cum - np.repeat(seg_base, counts) - 1
    last = np.append(fidx[1:], T.size) - 1       # gaps >= 1: max is the last
    if np.any(positions[last] >= numel):
        raise WireDecodeError(
            "corrupt golomb stream: position overflows tensor")
    return cw_seg, positions, signs


def _check_bit_len(bit_len, word_count) -> None:
    if np.any(np.asarray(bit_len) > 32 * np.asarray(word_count)):
        raise WireDecodeError(
            "corrupt wire payload: bit_len past the word buffer")


def decode_ternary_fields(msg: WireMessage, p: float, *,
                          backend: str = "numpy"
                          ) -> tuple[np.ndarray, np.ndarray]:
    """One message's coded ``(positions, signs)`` -- no dense scatter.

    The fused ingest path (:mod:`repro.core.ingest`) consumes these fields
    directly; :func:`decode_ternary_words` adds the scatter on top.
    """
    b = _b_star_checked(p)
    if msg.bit_len == 0:
        if int(msg.nnz) != 0:
            raise WireDecodeError(
                "corrupt golomb stream: decoded nnz mismatch")
        return np.zeros(0, np.int64), np.zeros(0, np.float32)
    words = np.ascontiguousarray(msg.words)
    _check_bit_len(msg.bit_len, words.size)
    bits = _backend_unpack(backend, words)
    _, positions, signs = _decode_stream_fields(
        bits, np.zeros(1, np.int64), np.asarray([msg.bit_len], np.int64),
        msg.numel, b)
    # integrity: the advertised nnz is side information the decoder can
    # cross-check for free -- a mutated stream that still parses but yields
    # a different codeword count is corruption, not data
    if positions.size != int(msg.nnz):
        raise WireDecodeError("corrupt golomb stream: decoded nnz mismatch")
    return positions, signs


def decode_ternary_fields_batch(batch: WireBatch, p: float, *,
                                backend: str = "numpy"
                                ) -> tuple[np.ndarray, np.ndarray,
                                           np.ndarray]:
    """All messages' ``(seg, positions, signs)`` in ONE decode pass.

    ``seg`` maps every codeword to its message row.  One hoisted unpack of
    the shared word buffer + one multi-segment field scan -- no per-client
    Python loop or repeated ``unpackbits`` views.
    """
    b = _b_star_checked(p)
    if batch.n_msgs == 0 or int(batch.bit_len.sum()) == 0:
        if batch.n_msgs and np.any(np.asarray(batch.nnz) != 0):
            raise WireDecodeError(
                "corrupt golomb stream: decoded nnz mismatch")
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.float32))
    _check_bit_len(batch.bit_len, batch.word_count)
    bits = _backend_unpack(backend, batch.words)
    seg, positions, signs = _decode_stream_fields(
        bits, (32 * batch.word_start).astype(np.int64),
        batch.bit_len.astype(np.int64), batch.numel, b)
    # per-row integrity: every message's decoded codeword count must match
    # its advertised nnz (same check class as the single-message path)
    counts = np.bincount(seg, minlength=batch.n_msgs)
    if np.any(counts != np.asarray(batch.nnz, np.int64)):
        raise WireDecodeError("corrupt golomb stream: decoded nnz mismatch")
    return seg, positions, signs


def decode_ternary_words(msg: WireMessage, p: float, *,
                         backend: str = "numpy") -> np.ndarray:
    """Vectorized Algorithm 4: unpack a word stream back to the flat tensor."""
    out = np.zeros(msg.numel, np.float32)
    positions, signs = decode_ternary_fields(msg, p, backend=backend)
    if positions.size:
        out[positions] = signs * np.float32(msg.mu)
    return out


def decode_ternary_words_batch(batch: WireBatch, p: float, *,
                               backend: str = "numpy") -> np.ndarray:
    """Decode every message of a batch; returns ``(P, numel)`` fp32.

    The whole batch decodes as one multi-segment pass (shared unpack,
    vectorized per-client offset arithmetic) followed by one 2-D scatter.
    """
    out = np.zeros((batch.n_msgs, batch.numel), np.float32)
    seg, positions, signs = decode_ternary_fields_batch(batch, p,
                                                        backend=backend)
    if positions.size:
        mu32 = batch.mu.astype(np.float32)
        out[seg, positions] = signs * mu32[seg]
    return out


# ---------------------------------------------------------------------------
# dense sign planes (signSGD wire format)
# ---------------------------------------------------------------------------


def pack_sign_words(tensor: np.ndarray, step: float, *,
                    backend: str = "numpy") -> WireMessage:
    """Dense one-bit-per-coordinate sign plane (the signSGD message).

    One bit cannot represent a zero: coordinates with ``x <= 0`` (including
    exact zeros from dead units or tied majority votes) pack as the ``-step``
    symbol, exactly like the real 1-bit protocol on the wire.  The measured
    size (``numel`` bits) is unaffected.
    """
    x = np.asarray(tensor).reshape(-1)
    bits = (x > 0).astype(np.uint8)
    words = get_wire_backend(backend).pack_bits(bits)
    return WireMessage(words, int(x.size), float(step), int(x.size),
                       int(x.size))


def unpack_sign_words(msg: WireMessage) -> np.ndarray:
    bits = words_to_bits(msg.words, msg.bit_len)
    return np.where(bits == 1, np.float32(msg.mu),
                    -np.float32(msg.mu)).astype(np.float32)


def sign_plane_bits(msg: WireMessage, *, backend: str = "numpy") -> np.ndarray:
    """The ``bit_len`` 0/1 sign bits of a dense sign-plane message, through
    the named unpack backend (validated like the Golomb decode paths)."""
    words = np.ascontiguousarray(msg.words)
    _check_bit_len(msg.bit_len, words.size)
    return _backend_unpack(backend, words)[: int(msg.bit_len)]


# ---------------------------------------------------------------------------
# generic batch assembly (default Codec.encode_wire_batch fallback)
# ---------------------------------------------------------------------------


def concat_messages(msgs: list[WireMessage]) -> WireBatch:
    """Assemble independently packed messages into one word-aligned batch."""
    word_count = np.asarray([m.words.size for m in msgs], np.int64)
    word_start = np.cumsum(word_count) - word_count
    words = (np.concatenate([m.words for m in msgs])
             if msgs else np.zeros(0, np.uint32))
    return WireBatch(
        words, word_start, word_count,
        np.asarray([m.bit_len for m in msgs], np.int64),
        np.asarray([m.mu for m in msgs], np.float64),
        np.asarray([m.nnz for m in msgs], np.int64),
        msgs[0].numel if msgs else 0)
