"""Pure-jnp building blocks of histogram k-selection, shared across layers.

These helpers are used both by the Pallas kernels (:mod:`repro.kernels`) and
by the tree-level compressor (:mod:`repro.core.distributed`).  They live here
— below the kernels — so that core modules never import
``jax.experimental.pallas``: the layering is kernels -> core, never the
reverse (see the lazy "kernel" backend lookup in :mod:`.compression`).

* ``bin_index`` / ``locate_bin`` -- the 256-bin linear magnitude binning and
  the cumulative-sum bin/rank search of the histogram selector.  The binning
  expression MUST stay bit-identical everywhere it is evaluated (histogram
  kernel, refinement pass, tree sweep), so there is exactly one definition.
* ``resolve_interpret`` -- backend autodetect for the kernels' ``interpret``
  flag (interpret everywhere but on a real TPU).
* ``PASSES`` -- trace-time streaming-pass counter: every logical full sweep
  over the data records itself here, and tests assert the histogram selector
  stays within its ≤3-pass budget where bisection spends 33.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["NBINS", "DEFAULT_CAP", "bin_index", "locate_bin",
           "resolve_interpret", "PASSES", "PassCounter"]

NBINS = 256         # histogram bins (one-hot matmul lane group on TPU)
DEFAULT_CAP = 8192  # static refinement-gather capacity (candidate bin size)


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> interpret everywhere but on a real TPU backend."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def bin_index(a: jnp.ndarray, scale: jnp.ndarray, bins: int) -> jnp.ndarray:
    """Linear magnitude binning; MUST be bit-identical everywhere it is used
    (histogram kernel, refinement pass, tree path)."""
    return jnp.clip((a * scale).astype(jnp.int32), 0, bins - 1)


def locate_bin(cnt, sums, k, bins):
    """Candidate bin + above-bin partials from a (bins,) histogram."""
    rc = jnp.cumsum(cnt[::-1])[::-1]             # rc[j] = #{bin >= j}
    rs = jnp.cumsum(sums[::-1])[::-1]
    iota = jnp.arange(bins, dtype=jnp.int32)
    b = jnp.max(jnp.where(rc >= k, iota, -1))    # largest bin with rc >= k
    rc_pad = jnp.concatenate([rc, jnp.zeros((1,), rc.dtype)])
    rs_pad = jnp.concatenate([rs, jnp.zeros((1,), rs.dtype)])
    cnt_gt = jnp.take(rc_pad, b + 1, mode="clip")
    sum_gt = jnp.take(rs_pad, b + 1, mode="clip")
    cnt_b = jnp.take(cnt, b, mode="clip")
    return b, cnt_gt, sum_gt, cnt_b


class PassCounter:
    """Counts logical streaming passes over the full input vector.

    Recording happens at Python level (trace time under jit, every call when
    eager), so tests exercise the un-jitted selection functions directly.
    """

    def __init__(self):
        self.counts: dict[str, int] = {}

    def reset(self) -> None:
        self.counts.clear()

    def record(self, name: str, n: int = 1) -> None:
        self.counts[name] = self.counts.get(name, 0) + n

    def total(self) -> int:
        return sum(self.counts.values())


PASSES = PassCounter()
