"""Pluggable server-side aggregation rules (the combine estimator).

``Codec.combine`` used to hard-code one estimator: the
``participation_weights``-weighted mean.  That mean is statistically
optimal for honest clients but has a breakdown point of zero -- a single
client sending a perfectly *valid* sign-flipped or 100x-scaled update
drags the global model arbitrarily far (Blanchard et al. 2017).  This
module makes the estimator a registered, frozen-dataclass
:class:`AggregationRule` that every codec threads through ``combine`` /
``aggregate`` / ``tree_reduce`` / the fused ingest path:

=====================  ==========  =========  =================================
rule                   streaming   screens    estimator
=====================  ==========  =========  =================================
``mean``               yes         no         weighted mean (bit-identical to
                                              the pre-rule combine; default)
``norm_screened_mean`` yes         yes        weighted mean after the PR-8
                                              norm clip/reject screen
``coordinate_median``  no          no         coordinate-wise weighted median
                                              (Yin et al. 2018); breakdown
                                              point 1/2 of the weight mass
``trimmed_mean``       no          no         coordinate-wise beta-trimmed
                                              weighted mean; breakdown point
                                              beta
=====================  ==========  =========  =================================

``supports_streaming`` declares whether the rule factors into a running
per-message sum (so the O(numel) :class:`~repro.core.ingest.IngestAccumulator`
applies); median and trimmed mean need every client's coordinates
simultaneously, so trainers asked for ``ingest=True`` with those rules
loudly fall back to the dense combine (the bit ledgers are unaffected --
they bill the wire, not the server's working set).

Weighted semantics, shared by every rule: each message row carries the
weight ``participation_weights(mask, staleness)`` gives it.  A rule must
be invariant to permuting (row, weight) pairs together and to inserting
rows of zero weight -- that contract is property-tested for every
registered rule in ``tests/test_aggregation.py``.

Registering a custom rule::

    @register_rule
    @dataclasses.dataclass(frozen=True)
    class KrumLiteRule(AggregationRule):
        name = "krum-lite"
        def combine_weighted(self, msgs, weights):
            flat = msgs.reshape(msgs.shape[0], -1)
            d = jnp.sum((flat[:, None] - flat[None]) ** 2, axis=-1)
            score = jnp.sum(jnp.sort(d, axis=1)[:, 1:-1], axis=1)
            return msgs[jnp.argmin(score)]

    make_protocol("stc", rule="krum-lite")
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional, Tuple

import jax.numpy as jnp

from . import registry as _registry

__all__ = [
    "AggregationRule",
    "MeanRule",
    "NormScreenedMeanRule",
    "CoordinateMedianRule",
    "TrimmedMeanRule",
    "register_rule",
    "make_rule",
    "get_rule_class",
    "registered_rules",
]

_REGISTRY: dict = {}


def register_rule(cls=None, *, name: Optional[str] = None,
                  override: bool = False):
    """Class decorator adding an :class:`AggregationRule` to the registry."""

    def _register(cls):
        key = name or cls.name
        if not key:
            raise ValueError(f"rule class {cls.__name__} has no name")
        if key in _REGISTRY and not override:
            raise ValueError(f"aggregation rule {key!r} already registered")
        _REGISTRY[key] = cls
        return cls

    return _register(cls) if cls is not None else _register


def get_rule_class(name: str) -> type:
    return _registry.lookup("aggregation rule", name, _REGISTRY)


def make_rule(rule, **overrides) -> "AggregationRule":
    """Resolve a registered name (plus field overrides) or pass an
    :class:`AggregationRule` instance through untouched."""
    return _registry.resolve("aggregation rule", rule, _REGISTRY,
                             AggregationRule, **overrides)


def registered_rules() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


@dataclasses.dataclass(frozen=True)
class AggregationRule:
    """Base class: a frozen (hashable, jit-closure-safe) combine estimator.

    Subclasses implement :meth:`combine_weighted`; screening rules
    additionally override :meth:`screen` (batched, used by ``combine``)
    and :meth:`screen_weight` (per-message host-side twin, used by the
    streaming ingest path).
    """

    name: ClassVar[str] = ""
    #: the rule factors into a running per-message accumulation, so the
    #: O(numel) fused-ingest path (`core/ingest.py`) can apply it without
    #: materializing the (clients, numel) matrix
    supports_streaming: ClassVar[bool] = False
    #: the rule screens individual messages (per-message weight rescale /
    #: rejection) before combining -- the ingest path then routes every
    #: message through :meth:`screen_weight`
    screens: ClassVar[bool] = False

    # -- hooks -----------------------------------------------------------
    def screen(self, msgs, weights):
        """Batched pre-combine screen: ``(msgs, weights) -> (msgs, weights)``.

        ``weights`` may be None (the unweighted fast path); a screen that
        rejects messages must then *introduce* a weight vector.  The base
        rule screens nothing.
        """
        return msgs, weights

    def screen_weight(self, norm: float) -> Tuple[float, bool]:
        """Host-side per-message twin of :meth:`screen` for the streaming
        ingest path: maps a message's L2 norm to
        ``(weight_scale, rejected)``."""
        return 1.0, False

    def combine_weighted(self, msgs, weights):
        """Combine ``msgs`` (clients-first stacked array) under per-row
        ``weights``; ``weights is None`` means the plain unweighted round
        (every row fully present, no staleness)."""
        raise NotImplementedError

    # -- public entry ----------------------------------------------------
    def combine(self, msgs, weights=None):
        """Screen, then combine.  ``Codec.combine`` inlines the same two
        steps with ``participation_weights`` in between; this entry serves
        the gathered tree path and direct (test / bench) callers."""
        msgs, weights = self.screen(msgs, weights)
        return self.combine_weighted(msgs, weights)


@register_rule
@dataclasses.dataclass(frozen=True)
class MeanRule(AggregationRule):
    """The participation-weighted mean -- bit-identical to the pre-rule
    ``Codec.combine`` in both its branches, and the registry default."""

    name: ClassVar[str] = "mean"
    supports_streaming: ClassVar[bool] = True

    def combine_weighted(self, msgs, weights):
        if weights is None:
            return jnp.mean(msgs, axis=0)
        total = jnp.sum(weights)
        denom = jnp.where(total > 0, total, 1.0)
        wb = weights.reshape((msgs.shape[0],) + (1,) * (msgs.ndim - 1))
        return jnp.sum(msgs * wb, axis=0) / denom


@register_rule
@dataclasses.dataclass(frozen=True)
class NormScreenedMeanRule(MeanRule):
    """PR 8's ``norm_bound`` clip/reject screen, as a rule.

    ``clip`` rescales any message with L2 norm above ``bound`` down onto
    the ball (weight unchanged); ``reject`` zeroes the message's weight
    entirely.  Both catch *overscaled* updates; a poisoned update of
    honest magnitude sails through -- that is what the median/trimmed
    rules are for.
    """

    name: ClassVar[str] = "norm_screened_mean"
    screens: ClassVar[bool] = True

    bound: float = 1.0
    policy: str = "clip"

    def __post_init__(self):
        if self.policy not in ("clip", "reject"):
            raise ValueError(
                f"policy must be 'clip' or 'reject', got {self.policy!r}")
        if not self.bound > 0.0:
            raise ValueError(f"bound must be positive, got {self.bound!r}")

    def screen(self, msgs, weights):
        flat = msgs.reshape(msgs.shape[0], -1)
        norms = jnp.sqrt(jnp.sum(flat * flat, axis=1))
        bound = jnp.float32(self.bound)
        if self.policy == "clip":
            scale = jnp.minimum(1.0, bound / jnp.maximum(norms, 1e-30))
            shape = (msgs.shape[0],) + (1,) * (msgs.ndim - 1)
            return msgs * scale.reshape(shape), weights
        keep = (norms <= bound).astype(jnp.float32)
        if weights is None:
            return msgs, keep
        return msgs, jnp.asarray(weights, jnp.float32) * keep

    def screen_weight(self, norm: float) -> Tuple[float, bool]:
        if norm <= self.bound or norm <= 0.0:
            return 1.0, False
        if self.policy == "clip":
            return float(self.bound) / float(norm), False
        return 0.0, True


def _sorted_with_cumweights(msgs, weights):
    """Common prefix of the order-statistic rules: per-coordinate stable
    sort of the (clients, numel) matrix with the weight rows carried
    along, plus inclusive cumulative weights.  Stable sort keeps equal
    values in input order, so ties cannot break value-level permutation
    invariance."""
    flat = msgs.reshape(msgs.shape[0], -1)
    if weights is None:
        weights = jnp.ones(msgs.shape[0], flat.dtype)
    w = jnp.broadcast_to(
        jnp.asarray(weights, flat.dtype)[:, None], flat.shape)
    order = jnp.argsort(flat, axis=0, stable=True)
    xs = jnp.take_along_axis(flat, order, axis=0)
    ws = jnp.take_along_axis(w, order, axis=0)
    return xs, ws, jnp.cumsum(ws, axis=0)


@register_rule
@dataclasses.dataclass(frozen=True)
class CoordinateMedianRule(AggregationRule):
    """Coordinate-wise weighted median (Yin et al. 2018).

    Per coordinate, the midpoint of the lower and upper weighted medians
    -- with unit weights and an even client count that is the classic
    two-middle-values average, matching ``jnp.median``.  Rows of zero
    weight can never be selected (the cumulative mass does not move at
    them), which is what makes masked-out clients true no-ops.  The
    estimator ignores up to half the weight mass being adversarial.
    """

    name: ClassVar[str] = "coordinate_median"

    def combine_weighted(self, msgs, weights):
        xs, ws, cw = _sorted_with_cumweights(msgs, weights)
        total = cw[-1]
        half = 0.5 * total
        lo = jnp.argmax(cw >= half[None], axis=0)
        above = cw[-1][None] - cw + ws  # mass at-or-above each position
        hi = (xs.shape[0] - 1) - jnp.argmax((above >= half[None])[::-1],
                                            axis=0)
        med = 0.5 * (jnp.take_along_axis(xs, lo[None], axis=0)[0] +
                     jnp.take_along_axis(xs, hi[None], axis=0)[0])
        med = jnp.where(total > 0, med, jnp.zeros_like(med))
        return med.reshape(msgs.shape[1:])


@register_rule
@dataclasses.dataclass(frozen=True)
class TrimmedMeanRule(AggregationRule):
    """Coordinate-wise beta-trimmed weighted mean (Yin et al. 2018).

    Per coordinate, discard the smallest and largest ``beta`` fractions
    of the *weight mass* and average what remains; ``beta=0`` reduces to
    the weighted mean, ``beta -> 0.5`` approaches the median.  Robust to
    any adversarial fraction below ``beta``.
    """

    name: ClassVar[str] = "trimmed_mean"

    beta: float = 0.1

    def __post_init__(self):
        if not 0.0 <= self.beta < 0.5:
            raise ValueError(
                f"beta must lie in [0, 0.5), got {self.beta!r}")

    def combine_weighted(self, msgs, weights):
        xs, ws, cw = _sorted_with_cumweights(msgs, weights)
        total = cw[-1]
        lo = self.beta * total
        hi = (1.0 - self.beta) * total
        # effective weight of each sorted entry inside the [lo, hi] mass
        # window: the overlap of its cumulative-mass interval with it
        eff = (jnp.clip(cw, lo[None], hi[None]) -
               jnp.clip(cw - ws, lo[None], hi[None]))
        span = hi - lo
        denom = jnp.where(span > 0, span, 1.0)
        out = jnp.sum(xs * eff, axis=0) / denom
        out = jnp.where(total > 0, out, jnp.zeros_like(out))
        return out.reshape(msgs.shape[1:])
