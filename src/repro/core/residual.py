"""Error-feedback residual accumulation (paper Eqs. 9, 11, 12).

Both clients and the server keep a residual ``A`` holding the part of the
update that compression dropped:

    client:  A_i <- A_i + ΔW_i - STC(ΔW_i + A_i)        (Eq. 11)
    server:  A   <- A   + ΔW   - STC(ΔW   + A)          (Eq. 12)

The residual MUST be kept in fp32 even for bf16 models: the dropped mass per
round is tiny and would underflow bf16's 8-bit mantissa, silently breaking the
telescoping-sum property that makes error feedback converge.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .compression import CompressionStats

__all__ = ["ResidualState", "init_residual", "compress_with_feedback",
           "stack_states", "take_states", "scatter_states"]


class ResidualState(NamedTuple):
    """fp32 residual, same structure as the update pytree (or a flat vector)."""

    residual: object  # pytree or array


def init_residual(like) -> ResidualState:
    res = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), like)
    return ResidualState(residual=res)


# ---------------------------------------------------------------------------
# Stacked per-client codec state.  A codec's ``init_client_state`` returns ONE
# client's state pytree (or None for stateless codecs); the federated trainer
# keeps the whole cohort as the same pytree with a leading (n_clients,) axis.
# These helpers are pytree-generic so the trainer never inspects the codec.
# ---------------------------------------------------------------------------


def stack_states(state, n: int):
    """Replicate one client's state pytree along a leading (n,) client axis."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), state)


def take_states(states, idx):
    """Select the per-client slices ``states[idx]`` of a stacked state."""
    return jax.tree.map(lambda x: x[idx], states)


def scatter_states(states, idx, new):
    """Write updated per-client slices back into the stacked state."""
    return jax.tree.map(lambda full, upd: full.at[idx].set(upd), states, new)


def compress_with_feedback(
    update,
    state: ResidualState,
    compress_fn: Callable[[jnp.ndarray], tuple[jnp.ndarray, CompressionStats]],
):
    """One error-feedback step over an *array* update (flat-vector form).

    ``compressed, new_state, stats = compress_with_feedback(ΔW, A, stc)``
    implements:  ΔW~ = C(ΔW + A);  A' = (ΔW + A) - ΔW~.
    """
    carried = update.astype(jnp.float32) + state.residual
    compressed, stats = compress_fn(carried)
    new_res = carried - compressed.astype(jnp.float32)
    return compressed, ResidualState(residual=new_res), stats
