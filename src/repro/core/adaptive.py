"""Adaptive per-chunk sparsity controllers (accuracy-per-bit Pareto).

STC's central claim is Pareto-superiority: target accuracies reached within
both fewer iterations and a smaller communication budget.  The static
``p_fn`` schedule of :func:`repro.core.chunking.chunk_codec` fixes each
(layer, chunk)'s sparsity for the whole run; this module closes the loop --
each chunk's k is set from OBSERVED per-chunk update statistics inside the
jitted round, in the spirit of CFedAvg's SNR-constant compressors (Yang et
al. 2021) with the residual-mass budget allocator as the simpler stateless
sibling.

A :class:`SparsityController` is a frozen dataclass (hashable, safe as a
jit-closure constant on a frozen codec) with three hooks:

* ``caps(base_ks, valid)`` -- STATIC per-chunk selection ceilings, computed
  host-side once per trace.  They bound the dynamic k so the in-jit
  selection can run one fixed-size ``top_k`` (see
  :func:`repro.core.compression.select_batch_dynamic`) and so the measured
  wire bits stay below the deterministic stream bound.
* ``init_state(base_ks)`` -- the controller's state pytree leaf (or None
  for stateless controllers).  Stateful controllers live INSIDE the codec's
  client/server state pytrees (`{"base": codec_state, "ctrl": state}`), so
  state updates ride the jitted round with no host round-trips and
  checkpoint/restore for free.
* ``chunk_ks(carried, state, base_ks=, caps=)`` -- the in-jit policy:
  observe the ``(R, n_chunks, chunk_numel)`` error-feedback pre-image
  (update + residual, zero-padded past each chunk's valid length) and
  return ``((R, n_chunks) int32 per-row k, new_state)``.  Everything here
  is traced jnp; ks are clipped to ``[1, caps]`` by contract.

Registered controllers::

    fixed          -- byte-identical to the static p_fn path (no-op marker)
    residual_mass  -- k per chunk proportional to its share of residual
                      l2 mass, under ``budget`` x the fixed-p k budget
    snr_constant   -- holds each chunk's selected-vs-discarded energy ratio
                      at ``snr`` via an EMA over instantaneous k (stateful)

Hyphens and underscores are interchangeable in names ("residual-mass" ==
"residual_mass").
"""

from __future__ import annotations

import dataclasses
import math
from typing import ClassVar, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .registry import resolve

__all__ = [
    "SparsityController",
    "FixedController",
    "ResidualMassController",
    "SnrConstantController",
    "register_controller",
    "make_controller",
    "registered_controllers",
    "validate_sparsity",
]


def validate_sparsity(p, layer: str, depth) -> float:
    """Guard a schedule- or controller-produced sparsity: finite and in
    (0, 1].  Raises a typed ValueError naming the (layer, chunk) so a bad
    ``p_fn`` fails loudly at wrap time instead of silently yielding k=0
    selections or full-dense chunks with a wrong bit ledger."""
    try:
        pf = float(p)
    except (TypeError, ValueError):
        raise ValueError(
            f"sparsity schedule returned non-numeric p={p!r} for layer "
            f"{layer!r} (depth {depth}); p must be a float in (0, 1]")
    if not math.isfinite(pf) or not 0.0 < pf <= 1.0:
        raise ValueError(
            f"sparsity schedule returned invalid p={pf!r} for layer "
            f"{layer!r} (depth {depth}); p must be finite and in (0, 1]")
    return pf


@dataclasses.dataclass(frozen=True)
class SparsityController:
    """Base class: per-chunk k policy evaluated inside the jitted round.

    Subclass, set ``name``, and register with :func:`register_controller`.
    ``adapts=False`` marks controllers that are pure markers for the static
    path (the chunked codec then runs the byte-identical fixed-k fast
    path); ``stateful=True`` makes the codec carry ``init_state``'s leaf in
    its state pytrees and thread it through ``chunk_ks``.
    """

    name: ClassVar[str] = ""
    adapts: ClassVar[bool] = True
    stateful: ClassVar[bool] = False

    #: dynamic k may exceed the fixed-p k by at most this factor (per
    #: chunk, always capped by the chunk's unpadded length).  Bounds both
    #: the top_k workspace and the worst-case wire bits.
    k_max_scale: float = 4.0

    def __post_init__(self):
        if not (isinstance(self.k_max_scale, (int, float))
                and math.isfinite(self.k_max_scale)
                and self.k_max_scale >= 1.0):
            raise ValueError(
                f"{type(self).__name__}: k_max_scale must be finite and "
                f">= 1, got {self.k_max_scale!r}")

    # -- static geometry (host-side, once per trace) ------------------------
    def caps(self, base_ks: np.ndarray, valid: np.ndarray) -> np.ndarray:
        """Per-chunk ceiling on the dynamic k (static int64 numpy)."""
        base_ks = np.asarray(base_ks, np.int64)
        valid = np.asarray(valid, np.int64)
        hi = np.ceil(base_ks.astype(np.float64) * float(self.k_max_scale))
        return np.minimum(np.maximum(hi.astype(np.int64), base_ks), valid)

    def init_state(self, base_ks: np.ndarray):
        """Controller state leaf for one client / the server (None when
        stateless)."""
        return None

    # -- the in-jit policy --------------------------------------------------
    def chunk_ks(self, carried, state, *, base_ks, caps):
        """``(R, C, W)`` carried blocks -> ``((R, C) int32 ks, new_state)``.

        ``state`` is ``init_state``'s leaf (possibly with leading batch
        axes matching R, or None for stateless controllers / the tree
        path, which must then fall back to an instantaneous policy)."""
        raise NotImplementedError(type(self).__name__)


CONTROLLERS: dict = {}


def register_controller(cls):
    """Class decorator: add a controller to the registry under its name."""
    CONTROLLERS[cls.name] = cls
    return cls


def registered_controllers() -> tuple:
    return tuple(sorted(CONTROLLERS))


def make_controller(controller, **overrides) -> SparsityController:
    """Resolve a registered name ("fixed", "residual-mass", ...) or pass an
    instance through (the one shared :func:`repro.core.registry.resolve`
    semantics)."""
    if isinstance(controller, str):
        controller = controller.replace("-", "_")
    return resolve("sparsity controller", controller, CONTROLLERS,
                   SparsityController, **overrides)


# ---------------------------------------------------------------------------
# the registered family
# ---------------------------------------------------------------------------


@register_controller
@dataclasses.dataclass(frozen=True)
class FixedController(SparsityController):
    """The static schedule, as a registered no-op marker: the chunked codec
    routes ``controller="fixed"`` through EXACTLY the static fixed-k code
    path (byte-identical params, ledgers and wire log -- the regression
    anchor every adaptive run is compared against)."""

    name: ClassVar[str] = "fixed"
    adapts: ClassVar[bool] = False

    def caps(self, base_ks, valid):
        return np.asarray(base_ks, np.int64)

    def chunk_ks(self, carried, state, *, base_ks, caps):
        R = carried.shape[0]
        ks = jnp.broadcast_to(jnp.asarray(np.asarray(base_ks), jnp.int32),
                              (R, len(base_ks)))
        return ks, state


@register_controller
@dataclasses.dataclass(frozen=True)
class ResidualMassController(SparsityController):
    """Budgeted proportional allocation: chunk c gets
    ``k_c = floor(B * mass_c / sum(mass))`` with ``B = budget * sum(fixed-p
    ks)`` -- coordinates go where the error-feedback mass actually is,
    at a total bit budget ``budget`` x the fixed-p schedule's.  Stateless:
    the policy is a pure function of the carried update, so client/server
    state pytrees keep their fixed-path structure."""

    name: ClassVar[str] = "residual_mass"

    #: total-k budget as a fraction of the fixed-p schedule's sum(ks);
    #: budget < 1 spends strictly fewer coordinates (and so bits) per round
    budget: float = 1.0

    def __post_init__(self):
        super().__post_init__()
        if not (isinstance(self.budget, (int, float))
                and math.isfinite(self.budget) and self.budget > 0.0):
            raise ValueError(
                f"residual_mass: budget must be finite and > 0, got "
                f"{self.budget!r}")

    def chunk_ks(self, carried, state, *, base_ks, caps):
        mass = jnp.sum(jnp.square(carried.astype(jnp.float32)),
                       axis=-1)                                # (R, C)
        total = jnp.sum(mass, axis=-1, keepdims=True)
        frac = mass / jnp.maximum(total, 1e-30)
        B = float(self.budget) * float(np.asarray(base_ks, np.int64).sum())
        ks = jnp.floor(B * frac).astype(jnp.int32)
        ks = jnp.clip(ks, 1, jnp.asarray(np.asarray(caps), jnp.int32)[None])
        return ks, state


@register_controller
@dataclasses.dataclass(frozen=True)
class SnrConstantController(SparsityController):
    """CFedAvg-style SNR-constant sparsification: per chunk, pick the
    smallest k whose selected energy reaches the fraction
    ``f = snr / (1 + snr)`` of the carried energy (selected-vs-discarded
    ratio ``snr``), then smooth with an EMA over rounds so one noisy update
    cannot blow the budget.  The EMA state lives in the codec's state
    pytrees (per client upstream, server-side downstream) and updates
    inside the jitted round; with ``state=None`` (the stateless tree path)
    the instantaneous k is used directly."""

    name: ClassVar[str] = "snr_constant"
    stateful: ClassVar[bool] = True

    #: target selected/discarded energy ratio (higher = denser messages)
    snr: float = 3.0
    #: EMA retention of the running per-chunk k estimate
    ema: float = 0.5

    def __post_init__(self):
        super().__post_init__()
        if not (isinstance(self.snr, (int, float))
                and math.isfinite(self.snr) and self.snr > 0.0):
            raise ValueError(
                f"snr_constant: snr must be finite and > 0, got "
                f"{self.snr!r}")
        if not (isinstance(self.ema, (int, float))
                and math.isfinite(self.ema) and 0.0 <= self.ema < 1.0):
            raise ValueError(
                f"snr_constant: ema must be in [0, 1), got {self.ema!r}")

    def init_state(self, base_ks):
        # seed the running k estimate at the fixed-p schedule
        return jnp.asarray(np.asarray(base_ks), jnp.float32)

    def chunk_ks(self, carried, state, *, base_ks, caps):
        R, C, W = carried.shape
        a2 = jnp.square(carried.astype(jnp.float32)).reshape(R * C, W)
        kcap = min(int(np.asarray(caps, np.int64).max()), W)
        top = jax.lax.top_k(a2, kcap)[0]
        cum = jnp.cumsum(top, axis=1)
        tot = jnp.sum(a2, axis=1, keepdims=True)
        f = float(self.snr) / (1.0 + float(self.snr))
        # smallest k with cum[k-1] >= f * tot (k = kcap when never reached)
        k_inst = 1 + jnp.sum((cum < f * tot).astype(jnp.int32), axis=1)
        k_inst = jnp.minimum(k_inst, kcap).reshape(R, C).astype(jnp.float32)
        if state is None:
            new_state, k_est = None, k_inst
        else:
            upd = k_inst
            if state.ndim == 1:          # server state: (C,), carried (1,C,W)
                upd = jnp.mean(k_inst, axis=0)
            new_state = (float(self.ema) * state
                         + (1.0 - float(self.ema)) * upd)
            k_est = jnp.broadcast_to(
                new_state if new_state.ndim == 2 else new_state[None],
                (R, C))
        caps_j = jnp.asarray(np.asarray(caps), jnp.int32)[None]
        ks = jnp.clip(jnp.round(k_est).astype(jnp.int32), 1, caps_j)
        return ks, new_state
