"""Core contribution of the paper: Sparse Ternary Compression (STC).

Public API:
    compression  -- top-k / ternarize / STC / signSGD operators (jit-able)
    residual     -- error-feedback residual accumulation (Eqs. 9/11/12)
    golomb       -- Eq. 15-17 entropy models + real Golomb bitstream codec
    protocols    -- Protocol objects: baseline / fedavg / signsgd / topk / stc
    caching      -- server partial-sum cache P^(s) for partial participation
"""

from .compression import (
    CompressionStats,
    StcBackend,
    flatten_pytree,
    get_stc_backend,
    majority_vote_sign,
    register_stc_backend,
    sign_compress,
    stc_compress,
    stc_compress_pytree,
    ternarize,
    top_k_mask,
    top_k_sparsify,
    unflatten_pytree,
)
from .golomb import (
    decode_ternary,
    encode_ternary,
    entropy_sparse,
    entropy_sparse_ternary,
    golomb_b_star,
    golomb_position_bits,
    stc_message_bits,
)
from .protocols import PROTOCOLS, Protocol, make_protocol
from .residual import ResidualState, compress_with_feedback, init_residual
from .caching import UpdateCache

__all__ = [
    "CompressionStats", "StcBackend", "get_stc_backend",
    "register_stc_backend", "flatten_pytree", "majority_vote_sign",
    "sign_compress",
    "stc_compress", "stc_compress_pytree", "ternarize", "top_k_mask",
    "top_k_sparsify", "unflatten_pytree", "decode_ternary", "encode_ternary",
    "entropy_sparse", "entropy_sparse_ternary", "golomb_b_star",
    "golomb_position_bits", "stc_message_bits", "PROTOCOLS", "Protocol",
    "make_protocol", "ResidualState", "compress_with_feedback", "init_residual",
    "UpdateCache",
]
