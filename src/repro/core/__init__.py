"""Core contribution of the paper: Sparse Ternary Compression (STC).

Public API:
    compression  -- top-k / ternarize / STC / signSGD operators (jit-able)
    residual     -- error-feedback residual accumulation (Eqs. 9/11/12)
    golomb       -- Eq. 15-17 entropy models + per-bit oracle bitstream codec
    wire         -- vectorized/batched wire-format packer (measured bits)
    protocols    -- Protocol objects: baseline / fedavg / signsgd / topk / stc
    aggregation  -- pluggable server combine rules: mean / median / trimmed
    registry     -- shared name→class resolution for every registry
    chunking     -- ChunkSpec + chunk_codec: per-(layer, chunk) block codecs
    ingest       -- fused decode→aggregate server accumulators (O(numel))
    caching      -- server partial-sum cache P^(s) for partial participation
"""

from .compression import (
    CompressionStats,
    StcBackend,
    flatten_pytree,
    get_stc_backend,
    majority_vote_sign,
    register_stc_backend,
    sign_compress,
    stc_compress,
    stc_compress_blocks,
    stc_compress_pytree,
    ternarize,
    ternary_quantize,
    top_k_mask,
    top_k_sparsify,
    unflatten_pytree,
)
from .golomb import (
    decode_ternary,
    encode_ternary,
    entropy_sparse,
    entropy_sparse_ternary,
    golomb_b_star,
    golomb_position_bits,
    stc_message_bits,
    stc_stream_bound_bits,
    ternary_dense_bits,
)
from .wire import (
    WireBatch,
    WireDecodeError,
    WireMessage,
    decode_ternary_fields,
    decode_ternary_fields_batch,
    decode_ternary_words,
    decode_ternary_words_batch,
    encode_ternary_words,
    encode_ternary_words_batch,
    get_wire_backend,
    pack_sign_words,
    register_wire_backend,
    sign_plane_bits,
    unpack_sign_words,
)
from .ingest import IngestAccumulator
from .aggregation import (
    AggregationRule,
    CoordinateMedianRule,
    MeanRule,
    NormScreenedMeanRule,
    TrimmedMeanRule,
    get_rule_class,
    make_rule,
    register_rule,
    registered_rules,
)
from .protocols import (
    PROTOCOLS,
    Codec,
    Protocol,
    get_protocol_class,
    make_protocol,
    register_protocol,
    registered_protocols,
)
from .adaptive import (
    FixedController,
    ResidualMassController,
    SnrConstantController,
    SparsityController,
    make_controller,
    register_controller,
    registered_controllers,
    validate_sparsity,
)
from .chunking import (
    ChunkedCodec,
    ChunkSpec,
    chunk_codec,
    chunk_spec_from_sizes,
    chunk_spec_from_tree,
    whole_vector_spec,
)
from .residual import (
    ResidualState,
    compress_with_feedback,
    init_residual,
    scatter_states,
    stack_states,
    take_states,
)
from .caching import UpdateCache

__all__ = [
    "CompressionStats", "StcBackend", "get_stc_backend",
    "register_stc_backend", "flatten_pytree", "majority_vote_sign",
    "sign_compress",
    "stc_compress", "stc_compress_blocks", "stc_compress_pytree",
    "ternarize", "ternary_quantize",
    "top_k_mask",
    "top_k_sparsify", "unflatten_pytree", "decode_ternary", "encode_ternary",
    "entropy_sparse", "entropy_sparse_ternary", "golomb_b_star",
    "golomb_position_bits", "stc_message_bits", "stc_stream_bound_bits",
    "ternary_dense_bits",
    "WireMessage", "WireBatch", "WireDecodeError", "encode_ternary_words",
    "encode_ternary_words_batch", "decode_ternary_words",
    "decode_ternary_words_batch", "decode_ternary_fields",
    "decode_ternary_fields_batch", "pack_sign_words", "unpack_sign_words",
    "sign_plane_bits", "get_wire_backend", "register_wire_backend",
    "IngestAccumulator",
    "AggregationRule", "MeanRule", "NormScreenedMeanRule",
    "CoordinateMedianRule", "TrimmedMeanRule", "make_rule", "register_rule",
    "registered_rules", "get_rule_class",
    "PROTOCOLS", "Codec", "Protocol", "make_protocol", "register_protocol",
    "registered_protocols", "get_protocol_class",
    "SparsityController", "FixedController", "ResidualMassController",
    "SnrConstantController", "make_controller", "register_controller",
    "registered_controllers", "validate_sparsity",
    "ChunkSpec", "ChunkedCodec", "chunk_codec", "chunk_spec_from_sizes",
    "chunk_spec_from_tree", "whole_vector_spec",
    "ResidualState", "compress_with_feedback", "init_residual",
    "stack_states", "take_states", "scatter_states",
    "UpdateCache",
]
