"""Golomb position coding for sparse ternary updates (paper Appx. A, Eq. 17).

Two layers:

* **Analytic model** (jit-friendly Python floats): entropy of sparse (Eq. 15)
  and sparse-ternary (Eq. 16) updates, the optimal Golomb parameter
  ``b* = 1 + floor(log2(log(φ-1)/log(1-p)))`` and the expected bits/position
  ``b̄_pos = b* + 1/(1-(1-p)^{2^b*})`` (Eq. 17).  These feed the communication
  ledger used by the federated loop and the benchmarks.

* **Real codec** (host-side numpy, Algorithms 3 & 4): encodes the non-zero
  positions of a flat ternary tensor as unary(q)+binary(r) Golomb codewords
  plus one sign bit per element and a 32-bit float µ, packed MSB-first into
  bytes with an explicit bit length.  Round-trip tested; the measured
  bitstream length is asserted ≈ the analytic model in tests.

This per-bit loop is kept as the reference ORACLE; the production packer is
the vectorized word-stream codec in :mod:`repro.core.wire`, which is asserted
bit-identical to this one.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = [
    "golomb_b_star",
    "golomb_position_bits",
    "entropy_sparse",
    "entropy_sparse_ternary",
    "stc_message_bits",
    "stc_stream_bound_bits",
    "fedavg_message_bits",
    "signsgd_message_bits",
    "ternary_dense_bits",
    "encode_ternary",
    "decode_ternary",
]

_PHI = (math.sqrt(5.0) + 1.0) / 2.0


def golomb_b_star(p: float) -> int:
    """Optimal Golomb parameter for geometric gaps with success prob p."""
    if not 0.0 < p < 1.0:
        raise ValueError(f"sparsity p must be in (0,1), got {p}")
    return max(0, 1 + int(math.floor(math.log2(math.log(_PHI - 1.0) / math.log(1.0 - p)))))


def golomb_position_bits(p: float) -> float:
    """Eq. 17: expected bits per non-zero position."""
    b = golomb_b_star(p)
    return b + 1.0 / (1.0 - (1.0 - p) ** (2**b))


def entropy_sparse(p: float, value_bits: int = 32) -> float:
    """Eq. 15: bits/weight for sparse full-precision updates."""
    return -p * math.log2(p) - (1 - p) * math.log2(1 - p) + value_bits * p


def entropy_sparse_ternary(p: float) -> float:
    """Eq. 16: bits/weight for sparse ternary updates (1 sign bit per nnz)."""
    return -p * math.log2(p) - (1 - p) * math.log2(1 - p) + p


def stc_message_bits(numel: int, p: float) -> float:
    """Size in bits of one Golomb-encoded STC message for a numel-sized tensor."""
    k = max(int(numel * p), 1)
    return k * (golomb_position_bits(p) + 1.0) + 32.0  # +32 for µ


def stc_stream_bound_bits(numel: int, nnz: int, p: float) -> float:
    """Deterministic ceiling on the measured Golomb stream length.

    ``nnz`` distinct positions in ``[0, numel)`` have gaps summing to at most
    ``numel``, so the unary quotients sum to at most ``(numel - nnz) / 2^b*``;
    every non-zero then pays the terminator, ``b*`` remainder bits and one
    sign bit, plus the 32-bit µ header.  Unlike :func:`stc_message_bits`
    (the Eq. 17 *expectation* under the geometric gap model) this holds for
    EVERY realizable mask, so ``measured <= bound`` is assertable round by
    round -- the Eq. 13 / Eq. 15 cross-check of the measured ledger.
    """
    if nnz == 0:
        return 32.0
    b = golomb_b_star(p)
    return float((numel - nnz) // (2 ** b) + nnz * (b + 2) + 32)


def fedavg_message_bits(numel: int, weight_bits: int = 32) -> float:
    """FedAvg communicates the dense update."""
    return float(numel * weight_bits)


def signsgd_message_bits(numel: int) -> float:
    return float(numel)


def ternary_dense_bits(numel: int) -> float:
    """Dense ternary message (T-FedAvg-style, Xu et al. 2020).

    Every weight carries one of {-µ, 0, +µ}: log2(3) bits/weight at the
    entropy bound of an uncoded ternary stream, plus a 32-bit float µ.
    """
    return numel * math.log2(3.0) + 32.0


# ---------------------------------------------------------------------------
# Real bitstream codec (Algorithms 3 and 4) -- host-side numpy.
# ---------------------------------------------------------------------------


class _BitWriter:
    """MSB-first bit sink backed by packed bytes (one bit per BIT, not per
    byte: large models used to blow up 8x through the old uint8-per-bit
    buffer).  ``getvalue`` returns the packed payload; ``len`` is in bits."""

    def __init__(self) -> None:
        self._bytes = bytearray()
        self._acc = 0          # partial byte, MSB-first
        self._nacc = 0         # bits currently in _acc (0..7)

    def write(self, bit: int) -> None:
        self._acc = (self._acc << 1) | (bit & 1)
        self._nacc += 1
        if self._nacc == 8:
            self._bytes.append(self._acc)
            self._acc = 0
            self._nacc = 0

    def write_unary(self, q: int) -> None:
        for _ in range(q):
            self.write(1)
        self.write(0)

    def write_binary(self, value: int, width: int) -> None:
        for shift in range(width - 1, -1, -1):
            self.write((value >> shift) & 1)

    def getvalue(self) -> np.ndarray:
        """Packed payload bytes (zero-padded tail), MSB-first within bytes."""
        tail = ([self._acc << (8 - self._nacc)] if self._nacc else [])
        return np.frombuffer(bytes(self._bytes) + bytes(tail), np.uint8)

    def __len__(self) -> int:
        return 8 * len(self._bytes) + self._nacc


class _BitReader:
    """MSB-first reader over packed payload bytes with an explicit bit count."""

    def __init__(self, payload: np.ndarray, bit_len: int) -> None:
        self._payload = np.asarray(payload, dtype=np.uint8)
        self._bit_len = int(bit_len)
        self._pos = 0

    def eof(self) -> bool:
        return self._pos >= self._bit_len

    def read(self) -> int:
        byte = int(self._payload[self._pos >> 3])
        bit = (byte >> (7 - (self._pos & 7))) & 1
        self._pos += 1
        return bit

    def read_binary(self, width: int) -> int:
        v = 0
        for _ in range(width):
            v = (v << 1) | self.read()
        return v


def encode_ternary(tensor: np.ndarray, p: float) -> tuple[np.ndarray, int, float, int]:
    """Algorithm 3: Golomb-encode a flat ternary tensor ``{-µ,0,µ}``.

    Returns ``(payload, bit_len, µ, n)`` where ``payload`` is the packed
    uint8 byte stream (MSB-first, zero-padded tail) and ``bit_len`` the exact
    number of meaningful bits.  Each nnz is encoded as Golomb(gap) followed
    by one sign bit (1 -> +µ).

    This per-bit host loop is the ORACLE codec: the vectorized packer in
    :mod:`repro.core.wire` must produce bit-identical streams (asserted in
    tests); use the wire module for anything performance-sensitive.
    """
    tensor = np.asarray(tensor).reshape(-1)
    nz = np.flatnonzero(tensor)
    mu = float(np.abs(tensor[nz]).mean()) if nz.size else 0.0
    b_star = golomb_b_star(p)
    w = _BitWriter()
    prev = -1
    for idx in nz:
        d = int(idx) - prev  # gap >= 1
        q, r = divmod(d - 1, 2**b_star)
        w.write_unary(q)
        w.write_binary(r, b_star)
        w.write(1 if tensor[idx] > 0 else 0)
        prev = int(idx)
    return w.getvalue(), len(w), mu, int(tensor.size)


def decode_ternary(
    payload: np.ndarray, bit_len: int, mu: float, n: int, p: float
) -> np.ndarray:
    """Algorithm 4: decode a packed Golomb bitstream back to the flat tensor."""
    b_star = golomb_b_star(p)
    out = np.zeros(n, dtype=np.float32)
    r = _BitReader(payload, bit_len)
    pos = -1
    q = 0
    while not r.eof():
        bit = r.read()
        if bit == 1:
            q += 1
            continue
        # terminator of the unary part -> read b* remainder bits + 1 sign bit
        rem = r.read_binary(b_star)
        sign = 1.0 if r.read() == 1 else -1.0
        pos += q * (2**b_star) + rem + 1
        out[pos] = sign * mu
        q = 0
    return out
