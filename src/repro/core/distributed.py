"""Tree-level STC for the distributed train_step (no flatten, no gathers).

Global top-k over the whole model == per-leaf masking with ONE global
magnitude threshold, and µ == the global mean magnitude of kept entries.
Computing the threshold by bisection over per-leaf counts therefore gives a
result *identical* to flattening-and-sorting, but touches every leaf in place:
no concatenation, no resharding, no all-gather of the parameter vector.
Reductions over the tensor-parallel ("model") axis happen automatically via
GSPMD (jnp.sum of a sharded leaf is a global sum); reductions over manual
(shard_map) axes are explicit via ``lax.psum`` when ``manual_axes`` is given.

This module is the distributed twin of core.compression / kernels.ops, and is
oracle-checked against them in tests.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TreeStats", "tree_numel", "stc_compress_tree",
           "sign_compress_tree", "tree_add", "tree_scale"]


class TreeStats(NamedTuple):
    nnz: jnp.ndarray
    numel: int
    mu: jnp.ndarray
    thresh: jnp.ndarray


def tree_numel(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def _psum(x, manual_axes):
    return jax.lax.psum(x, manual_axes) if manual_axes else x


def _pmax(x, manual_axes):
    return jax.lax.pmax(x, manual_axes) if manual_axes else x


def _count_and_sum(tree, t):
    """(#|x|>=t, Σ|x| over that set) across all leaves."""
    cnt = jnp.zeros((), jnp.int32)
    s = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(tree):
        a = jnp.abs(leaf.astype(jnp.float32))
        m = a >= t
        cnt = cnt + jnp.sum(m.astype(jnp.int32))
        s = s + jnp.sum(jnp.where(m, a, 0.0))
    return cnt, s


def stc_compress_tree(tree, p: float, *, manual_axes=(), iters: int = 32,
                      numel: int | None = None):
    """STC over a pytree: returns (ternary_tree, stats).

    ``manual_axes``: shard_map axis names the leaves are *sharded over* (the
    server stage when state is scattered); () when each caller holds the full
    (possibly GSPMD-sharded) tree.
    """
    numel = numel if numel is not None else tree_numel(tree)
    if manual_axes:
        # numel above counts only the local shard -- scale by the axis size
        # is wrong for uneven shards; callers pass explicit numel instead.
        pass
    k = max(int(numel * p), 1)

    a_max = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(tree):
        a_max = jnp.maximum(a_max, jnp.max(jnp.abs(leaf.astype(jnp.float32))))
    a_max = _pmax(a_max, manual_axes)

    hi0 = a_max * jnp.float32(1.0 + 1e-6) + jnp.float32(1e-30)
    lo0 = jnp.float32(0.0)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt, _ = _count_and_sum(tree, mid)
        cnt = _psum(cnt, manual_axes)
        keep = cnt >= k
        return jnp.where(keep, mid, lo), jnp.where(keep, hi, mid)

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
    cnt, s = _count_and_sum(tree, lo)
    cnt = _psum(cnt, manual_axes)
    s = _psum(s, manual_axes)
    mu = s / jnp.maximum(cnt, 1).astype(jnp.float32)

    def tern_leaf(x):
        xf = x.astype(jnp.float32)
        m = jnp.abs(xf) >= lo
        return jnp.where(m, mu * jnp.sign(xf), 0.0).astype(x.dtype)

    tern = jax.tree.map(tern_leaf, tree)
    return tern, TreeStats(nnz=cnt, numel=numel, mu=mu, thresh=lo)


def sign_compress_tree(tree, step: float):
    return jax.tree.map(
        lambda x: (step * jnp.sign(x.astype(jnp.float32))).astype(x.dtype),
        tree)
