"""Tree-level STC for the distributed train_step (no flatten, no gathers).

Global top-k over the whole model == per-leaf masking with ONE global
magnitude threshold, and µ == the global mean magnitude of kept entries.
The threshold is found by the same single-pass histogram selection as
:mod:`repro.kernels.hist_select`, but applied leaf-by-leaf: ONE sweep over
the leaves accumulates a 256-bin (count, Σ|x|) histogram, a cumulative sum
locates the k-th bin, and one gather pass over the candidate bin reads the
exact k-th magnitude — replacing the old 32-iteration bisection fori_loop
(32 full sweeps over every leaf) with ≤3 sweeps.  The result is *identical*
to flattening-and-sorting, but touches every leaf in place: no concatenation,
no resharding, no all-gather of the parameter vector.

Reductions over the tensor-parallel ("model") axis happen automatically via
GSPMD (jnp.sum of a sharded leaf is a global sum); reductions over manual
(shard_map) axes are explicit: the per-bin histogram vectors are ``psum``-ed
and the (tiny, ≤``cap``) candidate gather is ``all_gather``-ed.  On
pathological inputs that overflow the candidate capacity the old bisection
loop runs as an exactness fallback under ``lax.cond``.

This module is the distributed twin of core.compression / kernels.ops, and is
oracle-checked against them in tests.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import (DEFAULT_CAP, NBINS, PASSES, bin_index,
                                  locate_bin, resolve_interpret)

__all__ = ["TreeStats", "tree_numel", "stc_compress_tree",
           "stc_compress_tree_chunked", "ternary_quantize_tree",
           "sign_compress_tree", "tree_add", "tree_scale"]


class TreeStats(NamedTuple):
    nnz: jnp.ndarray
    numel: int
    mu: jnp.ndarray
    thresh: jnp.ndarray


def tree_numel(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def _psum(x, manual_axes):
    return jax.lax.psum(x, manual_axes) if manual_axes else x


def _pmax(x, manual_axes):
    return jax.lax.pmax(x, manual_axes) if manual_axes else x


def _count_and_sum(tree, t):
    """(#|x|>=t, Σ|x| over that set) across all leaves (one sweep)."""
    cnt = jnp.zeros((), jnp.int32)
    s = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(tree):
        a = jnp.abs(leaf.astype(jnp.float32))
        m = a >= t
        cnt = cnt + jnp.sum(m.astype(jnp.int32))
        s = s + jnp.sum(jnp.where(m, a, 0.0))
    return cnt, s


def _tree_histogram(tree, scale, bins):
    """ONE sweep over the leaves -> per-bin (count, Σ|x|) vectors."""
    cnt = jnp.zeros((bins,), jnp.int32)
    s = jnp.zeros((bins,), jnp.float32)
    for leaf in jax.tree.leaves(tree):
        a = jnp.abs(leaf.astype(jnp.float32)).reshape(-1)
        idx = bin_index(a, scale, bins)
        cnt = cnt + jnp.bincount(idx, length=bins).astype(jnp.int32)
        s = s + jnp.bincount(idx, weights=a, length=bins).astype(jnp.float32)
    return cnt, s


def _direct_tree_select(tree, k, cap, manual_axes):
    """Non-TPU small-k shortcut: per-leaf top-k gathers, one sweep (1-2 total).

    Every element ≥ the global k-th magnitude is inside its leaf's top-
    ``min(cap, size)`` gather (there are at most k ≤ cap of them per leaf), so
    the k-th largest of the concatenated gathers is exact; a per-leaf
    tie-spill (a full gather whose tail ties the threshold) falls back to one
    counting sweep via lax.cond.
    """
    PASSES.record("topk_gather")                               # sweep 1
    cands, full = [], []
    for leaf in jax.tree.leaves(tree):
        a = jnp.abs(leaf.astype(jnp.float32)).reshape(-1)
        cap_leaf = min(cap, a.size)
        cands.append(jax.lax.top_k(a, cap_leaf)[0])
        full.append(a.size > cap_leaf)
    # gathered tail == min (descending); NOT c[-1], whose static slice of a
    # top_k XLA:CPU rewrites into a full sort of the leaf
    tails = jnp.stack([jnp.min(c) for c in cands])
    fulls = jnp.asarray(full)
    cands = jnp.concatenate(cands)
    if manual_axes:
        cands = jax.lax.all_gather(cands, manual_axes).reshape(-1)
        tails = jax.lax.all_gather(tails, manual_axes).reshape(-1)
        fulls = jax.lax.all_gather(fulls, manual_axes).reshape(-1)

    srt = jnp.sort(cands)[::-1]
    v = srt[k - 1]
    spill = jnp.any(fulls & (tails >= v))

    def _from_gather(_):
        ge = cands >= v
        return (v, jnp.sum(ge.astype(jnp.int32)),
                jnp.sum(jnp.where(ge, cands, 0.0)))

    def _tie_spill(_):                                         # rare sweep 2
        cnt, s = _count_and_sum(tree, v)
        return v, _psum(cnt, manual_axes), _psum(s, manual_axes)

    return jax.lax.cond(spill, _tie_spill, _from_gather, None)


def _bisect_threshold(tree, k, a_max, manual_axes, iters):
    """Old 32-sweep bisection; kept as the rare-case exactness fallback."""
    hi0 = a_max * jnp.float32(1.0 + 1e-6) + jnp.float32(1e-30)
    lo0 = jnp.float32(0.0)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt, _ = _count_and_sum(tree, mid)
        cnt = _psum(cnt, manual_axes)
        keep = cnt >= k
        return jnp.where(keep, mid, lo), jnp.where(keep, hi, mid)

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
    cnt, s = _count_and_sum(tree, lo)
    return lo, _psum(cnt, manual_axes), _psum(s, manual_axes)


def stc_compress_tree(tree, p: float, *, manual_axes=(), iters: int = 32,
                      numel: int | None = None, bins: int = NBINS,
                      cap: int = DEFAULT_CAP):
    """STC over a pytree: returns (ternary_tree, stats).

    ``manual_axes``: shard_map axis names the leaves are *sharded over* (the
    server stage when state is scattered); () when each caller holds the full
    (possibly GSPMD-sharded) tree.  ``iters`` only affects the bisection
    fallback taken when the candidate histogram bin overflows ``cap``.
    """
    numel = numel if numel is not None else tree_numel(tree)
    if manual_axes:
        # numel above counts only the local shard -- scale by the axis size
        # is wrong for uneven shards; callers pass explicit numel instead.
        pass
    k = max(int(numel * p), 1)

    if resolve_interpret(None) and k <= cap:
        # non-TPU small-k shortcut (see _direct_tree_select / hist_select)
        thresh, cnt_tot, sum_tot = _direct_tree_select(tree, k, cap,
                                                       manual_axes)
        return _finish_tree(tree, thresh, cnt_tot, sum_tot, numel)

    PASSES.record("max")                                        # sweep 1
    a_max = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(tree):
        a_max = jnp.maximum(a_max, jnp.max(jnp.abs(leaf.astype(jnp.float32))))
    a_max = _pmax(a_max, manual_axes)
    scale = jnp.where(a_max > 0, jnp.float32(bins) / a_max, jnp.float32(0.0))

    PASSES.record("histogram")                                  # sweep 2
    cnt, s = _tree_histogram(tree, scale, bins)
    cnt = _psum(cnt, manual_axes)
    s = _psum(s, manual_axes)
    b, cnt_gt, sum_gt, cnt_b = locate_bin(cnt, s, k, bins)
    r = k - cnt_gt                                              # 1 <= r <= cnt_b

    PASSES.record("refine")                                     # sweep 3
    cands = []
    for leaf in jax.tree.leaves(tree):
        a = jnp.abs(leaf.astype(jnp.float32)).reshape(-1)
        in_bin = bin_index(a, scale, bins) == b
        masked = jnp.where(in_bin, a, jnp.float32(-1.0))
        cands.append(jax.lax.top_k(masked, min(cap, a.size))[0])
    cands = jnp.concatenate(cands)
    if manual_axes:
        cands = jax.lax.all_gather(cands, manual_axes).reshape(-1)

    def _exact(_):
        srt = jnp.sort(cands)[::-1]              # descending, ≤ L·cap values
        v = jnp.take(srt, r - 1, mode="clip")
        ge = (cands >= 0.0) & (cands >= v)
        return (v, cnt_gt + jnp.sum(ge.astype(jnp.int32)),
                sum_gt + jnp.sum(jnp.where(ge, cands, 0.0)))

    def _fallback(_):
        return _bisect_threshold(tree, k, a_max, manual_axes, iters)

    thresh, cnt_tot, sum_tot = jax.lax.cond(cnt_b > cap, _fallback, _exact,
                                            None)
    return _finish_tree(tree, thresh, cnt_tot, sum_tot, numel)


def _finish_tree(tree, thresh, cnt_tot, sum_tot, numel):
    """µ + per-leaf ternarization from the selected (thresh, count, sum)."""
    mu = sum_tot / jnp.maximum(cnt_tot, 1).astype(jnp.float32)

    def tern_leaf(x):
        xf = x.astype(jnp.float32)
        m = jnp.abs(xf) >= thresh
        return jnp.where(m, mu * jnp.sign(xf), 0.0).astype(x.dtype)

    tern = jax.tree.map(tern_leaf, tree)
    return tern, TreeStats(nnz=cnt_tot, numel=numel, mu=mu, thresh=thresh)


def stc_compress_tree_chunked(tree, p: float, chunk_size: int, *,
                              p_fn=None, backend: str = "jnp",
                              controller=None):
    """Per-``(leaf, chunk)`` STC: independent selection + µ per block.

    The chunked twin of :func:`stc_compress_tree`: instead of ONE global
    threshold (which serializes every leaf behind a collective selection),
    each leaf is cut into ``ceil(size / chunk_size)`` blocks and every block
    gets its own exact k-selection and ternary magnitude through the STC
    backend registry (``"jnp"`` top-k gather / ``"kernel"`` = the batched
    Pallas histogram selector, one launch per leaf covering all its chunks).
    No collectives anywhere: under shard_map each shard selects over its own
    blocks only, so the sweeps pipeline across the mesh.

    ``p_fn(layer_name, depth) -> p | None`` is the per-layer sparsity
    schedule hook (None keeps ``p``; every schedule-produced p is validated
    -- finite, in (0, 1] -- with a ValueError naming the layer).
    ``controller`` (a :mod:`repro.core.adaptive` name or instance) switches
    per-chunk k from the static schedule to the controller's in-jit policy;
    the tree path is stateless, so stateful controllers run their
    instantaneous rule (``state=None``).  Returns ``(ternary_tree, stats)``
    with aggregate nnz/µ across all blocks.
    """
    from repro.core.adaptive import make_controller, validate_sparsity
    from repro.core.compression import stc_compress_blocks

    if chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
    ctrl = make_controller(controller) if controller is not None else None
    if ctrl is not None and not ctrl.adapts:
        ctrl = None                      # "fixed": exactly the static path
    flat_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out_leaves = []
    nnz_tot = jnp.zeros((), jnp.int32)
    mu_num = jnp.zeros((), jnp.float32)     # Σ per-block µ·count (global µ)
    numel = 0
    for depth, (path, leaf) in enumerate(flat_leaves):
        numel += leaf.size
        if leaf.size == 0:
            out_leaves.append(leaf)
            continue
        lname = jax.tree_util.keystr(path)
        p_leaf = None if p_fn is None else p_fn(lname, depth)
        p_leaf = p if p_leaf is None \
            else validate_sparsity(p_leaf, lname, depth)
        flat = leaf.astype(jnp.float32).reshape(-1)
        w = min(chunk_size, flat.size)
        n_chunks = -(-flat.size // w)
        pad = n_chunks * w - flat.size
        blocks = jnp.pad(flat, (0, pad)).reshape(n_chunks, w)
        valid = np.full(n_chunks, w, np.int64)
        valid[-1] = flat.size - (n_chunks - 1) * w
        ks = np.maximum((valid * p_leaf).astype(np.int64), 1)
        if ctrl is not None:
            caps = ctrl.caps(ks, valid)
            dyn_ks, _ = ctrl.chunk_ks(blocks[None], None, base_ks=ks,
                                      caps=caps)
            tern, cnt, mu = stc_compress_blocks(
                blocks, jnp.asarray(dyn_ks).reshape(n_chunks),
                backend=backend, k_cap=int(caps.max()))
        else:
            tern, cnt, mu = stc_compress_blocks(blocks, ks, backend=backend)
        out_leaves.append(
            tern.reshape(-1)[: flat.size].reshape(leaf.shape)
            .astype(leaf.dtype))
        nnz_tot = nnz_tot + jnp.sum(cnt)
        mu_num = mu_num + jnp.sum(mu * cnt.astype(jnp.float32))
    out = jax.tree_util.tree_unflatten(treedef, out_leaves)
    mu = mu_num / jnp.maximum(nnz_tot, 1).astype(jnp.float32)
    return out, TreeStats(nnz=nnz_tot, numel=numel, mu=mu,
                          thresh=jnp.zeros((), jnp.float32))


def ternary_quantize_tree(tree, theta: float, *, manual_axes=(),
                          numel: int | None = None):
    """Dense ternary quantization over a pytree (tree twin of
    ``compression.ternary_quantize``): Δ = θ·mean|x| globally across leaves,
    µ = mean kept magnitude.  Two sweeps, no gathers."""
    numel = numel if numel is not None else tree_numel(tree)
    s_all = jnp.zeros((), jnp.float32)                          # sweep 1
    for leaf in jax.tree.leaves(tree):
        s_all = s_all + jnp.sum(jnp.abs(leaf.astype(jnp.float32)))
    s_all = _psum(s_all, manual_axes)
    delta = theta * s_all / jnp.float32(numel)

    cnt = jnp.zeros((), jnp.int32)                              # sweep 2
    s_kept = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(tree):
        a = jnp.abs(leaf.astype(jnp.float32))
        m = a > delta
        cnt = cnt + jnp.sum(m.astype(jnp.int32))
        s_kept = s_kept + jnp.sum(jnp.where(m, a, 0.0))
    cnt = _psum(cnt, manual_axes)
    s_kept = _psum(s_kept, manual_axes)
    mu = s_kept / jnp.maximum(cnt, 1).astype(jnp.float32)

    def tern_leaf(x):
        xf = x.astype(jnp.float32)
        return jnp.where(jnp.abs(xf) > delta, mu * jnp.sign(xf), 0.0
                         ).astype(x.dtype)

    tern = jax.tree.map(tern_leaf, tree)
    return tern, TreeStats(nnz=cnt, numel=numel, mu=mu, thresh=delta)


def sign_compress_tree(tree, step: float):
    return jax.tree.map(
        lambda x: (step * jnp.sign(x.astype(jnp.float32))).astype(x.dtype),
        tree)
