"""Chunked per-layer codec states: compress ``(layer, chunk)`` blocks.

The paper applies STC to ONE flat parameter vector per client, but its Eq. 1
bit accounting and the residual-accumulation mechanics (Eqs. 9-12) hold
equally per block.  This module turns any registered :class:`Codec` into a
chunked codec whose selection, Golomb parameter µ and residuals are
INDEPENDENT per ``(layer, chunk)`` block -- which is what lets selection
sweeps shard and pipeline across a mesh instead of serializing on one flat
top-k, and what makes per-layer sparsity schedules (T-FedAvg-style tuned
ranges, Xu et al. 2020) expressible.

Two pieces:

* :class:`ChunkSpec` -- static chunk geometry computed from the model pytree
  (layer boundaries + a chunk size): which flat slice each chunk covers,
  zero-padded ``split``/``merge`` between the flat ``(P, numel)`` trainer
  view and the ``(P, n_chunks, chunk_numel)`` block view.  Chunks never
  cross layer boundaries (except the degenerate ``whole_vector_spec``); the
  last chunk of a layer may be ragged and empty layers contribute none.

* :func:`chunk_codec` -- wraps a base codec into a :class:`ChunkedCodec`
  implementing the full flat :class:`Codec` interface (so both trainers run
  it unchanged), with per-chunk states, per-chunk analytic/measured bit
  ledgers and per-chunk wire framing.  A ``p_fn(layer_name, depth)`` hook
  rescales the sparsity per layer for codecs that declare ``sparsity_up`` /
  ``sparsity_down``.

Semantics contract: the chunked result is EXACTLY the base codec applied to
every chunk's unpadded slice independently (the "per-chunk flat oracle",
property-tested in tests/test_chunked.py for every registry codec), and a
``whole_vector_spec`` reproduces today's flat path bit for bit -- params,
measured + analytic ledgers and wire_log (the trainer regression).

Codecs with a genuinely batched block path opt in via
``Codec.chunk_blocks = True`` + ``encode_chunk_blocks`` /
``aggregate_chunk_blocks`` (STC: one backend ``select_batch`` launch over
every ``(client, chunk)`` row); everything else runs the generic grouped
path, which calls the base codec's own ``encode_batch``/``aggregate`` per
(chunk-width, layer-codec) group.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import wire
from .adaptive import (SparsityController, make_controller,
                       validate_sparsity)
from .compression import CompressionStats
from .protocols import Codec

__all__ = [
    "ChunkSpec",
    "chunk_spec_from_sizes",
    "chunk_spec_from_tree",
    "whole_vector_spec",
    "ChunkedCodec",
    "chunk_codec",
]


class ChunkSpec(NamedTuple):
    """Static ``(layer, chunk)`` geometry over a flat parameter vector.

    All fields are plain tuples so a spec is hashable (codecs carrying one
    stay usable as jit-closure constants and cache keys).  ``chunk_numel``
    is the uniform padded block width; chunk ``c`` covers the flat slice
    ``[chunk_start[c], chunk_start[c] + chunk_valid[c])`` of layer
    ``chunk_layer[c]``.
    """

    numel: int
    chunk_numel: int
    layer_names: tuple
    layer_sizes: tuple
    chunk_layer: tuple
    chunk_start: tuple
    chunk_valid: tuple

    @property
    def n_chunks(self) -> int:
        return len(self.chunk_start)

    def is_whole_vector(self) -> bool:
        return self.n_chunks == 1 and self.chunk_valid[0] == self.numel

    # -- flat <-> block views -------------------------------------------------
    def split(self, x):
        """``(..., numel)`` -> zero-padded ``(..., n_chunks, chunk_numel)``.

        Works on jnp and numpy arrays alike (pad-one-then-gather)."""
        idx = _gather_index(self)
        if isinstance(x, np.ndarray):
            pad = np.zeros(x.shape[:-1] + (1,), x.dtype)
            return np.concatenate([x, pad], axis=-1)[..., idx]
        pad = jnp.zeros(x.shape[:-1] + (1,), x.dtype)
        return jnp.concatenate([x, pad], axis=-1)[..., idx]

    def merge(self, blocks):
        """``(..., n_chunks, chunk_numel)`` -> ``(..., numel)`` (drops pad)."""
        inv = _merge_index(self)
        flat = blocks.reshape(blocks.shape[:-2] + (-1,))
        return flat[..., inv]

    def valid_mask(self) -> np.ndarray:
        """(n_chunks, chunk_numel) bool: True where a block element is real."""
        return (np.arange(self.chunk_numel)[None, :]
                < np.asarray(self.chunk_valid)[:, None])

    # -- per-chunk hyperparameters -------------------------------------------
    def chunk_ks(self, ps) -> np.ndarray:
        """Per-chunk ``k = max(int(valid * p), 1)`` (Algorithm 1 line 3,
        applied to each block's UNPADDED length)."""
        ps = np.broadcast_to(np.asarray(ps, np.float64), (self.n_chunks,))
        valid = np.asarray(self.chunk_valid, np.int64)
        return np.maximum((valid.astype(np.float64) * ps).astype(np.int64), 1)


@functools.lru_cache(maxsize=128)
def _gather_index(spec: ChunkSpec) -> np.ndarray:
    """(n_chunks, chunk_numel) flat-position gather; padding points at the
    sentinel column ``numel`` (a zero appended by ``split``)."""
    idx = np.full((spec.n_chunks, spec.chunk_numel), spec.numel, np.int64)
    for c, (start, valid) in enumerate(zip(spec.chunk_start,
                                           spec.chunk_valid)):
        idx[c, :valid] = np.arange(start, start + valid)
    return idx


@functools.lru_cache(maxsize=128)
def _merge_index(spec: ChunkSpec) -> np.ndarray:
    """(numel,) index into the flattened (n_chunks*chunk_numel,) block view."""
    inv = np.empty(spec.numel, np.int64)
    for c, (start, valid) in enumerate(zip(spec.chunk_start,
                                           spec.chunk_valid)):
        inv[start : start + valid] = c * spec.chunk_numel + np.arange(valid)
    return inv


def chunk_spec_from_sizes(sizes, names=None,
                          chunk_size: Optional[int] = None) -> ChunkSpec:
    """Spec from per-layer flat sizes.  ``chunk_size=None`` = one chunk per
    (non-empty) layer; otherwise each layer splits into ``ceil(size /
    chunk_size)`` chunks with a ragged tail.  Empty layers contribute no
    chunks but keep their name/size slot (the flat offsets stay aligned)."""
    sizes = [int(s) for s in sizes]
    if names is None:
        names = [f"layer{i}" for i in range(len(sizes))]
    if chunk_size is not None and chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
    chunk_layer, chunk_start, chunk_valid = [], [], []
    off = 0
    for li, size in enumerate(sizes):
        step = size if chunk_size is None else min(chunk_size, max(size, 1))
        pos = 0
        while pos < size:
            valid = min(step, size - pos)
            chunk_layer.append(li)
            chunk_start.append(off + pos)
            chunk_valid.append(valid)
            pos += valid
        off += size
    if not chunk_start:
        raise ValueError(f"no non-empty layers in {sizes}")
    return ChunkSpec(
        numel=off, chunk_numel=max(chunk_valid),
        layer_names=tuple(names), layer_sizes=tuple(sizes),
        chunk_layer=tuple(chunk_layer), chunk_start=tuple(chunk_start),
        chunk_valid=tuple(chunk_valid))


def chunk_spec_from_tree(tree, chunk_size: Optional[int] = None) -> ChunkSpec:
    """Spec whose layers are the pytree's leaves, in flat-concatenation
    order (matching :func:`repro.core.compression.flatten_pytree`)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = [jax.tree_util.keystr(path) for path, _ in flat]
    sizes = [leaf.size for _, leaf in flat]
    return chunk_spec_from_sizes(sizes, names, chunk_size)


def whole_vector_spec(numel: int) -> ChunkSpec:
    """The degenerate spec: ONE chunk spanning the whole flat vector (crossing
    layer boundaries) -- the flat-path bit-identity regression point."""
    return chunk_spec_from_sizes([numel], names=["all"], chunk_size=None)


# ---------------------------------------------------------------------------
# the chunked codec wrapper
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=128)
def _chunk_groups(spec: ChunkSpec, layer_codecs: tuple):
    """Chunks grouped by (unpadded width, layer codec): every group can run
    the base codec's own batched path on one stacked unpadded slice.  The
    group count is tiny and static (<= 2 per distinct layer codec)."""
    groups: dict = {}
    for c in range(spec.n_chunks):
        key = (spec.chunk_valid[c], layer_codecs[spec.chunk_layer[c]])
        groups.setdefault(key, []).append(c)
    return tuple((valid, codec, tuple(idxs))
                 for (valid, codec), idxs in groups.items())


@functools.lru_cache(maxsize=1024)
def _analytic_bits(spec: ChunkSpec, layer_codecs: tuple, direction: str,
                   n_participating: int) -> float:
    """Eq. 1 summed over every chunk's UNPADDED length (cached: constant
    per frozen codec, but evaluated by the trainers every round)."""
    per_chunk = (layer_codecs[li] for li in spec.chunk_layer)
    if direction == "up":
        return float(sum(c.upload_bits(v)
                         for c, v in zip(per_chunk, spec.chunk_valid)))
    return float(sum(c.download_bits(v, n_participating=n_participating)
                     for c, v in zip(per_chunk, spec.chunk_valid)))


def _state_index(idxs, valid, leaf_ndim, lead: int):
    """Index tuple selecting chunks ``idxs`` (truncated to ``valid`` on a
    trailing block axis) out of a state leaf with ``lead`` leading axes
    before the chunk axis."""
    ix = (slice(None),) * lead + (np.asarray(idxs),)
    if leaf_ndim > lead + 1:
        ix = ix + (Ellipsis, slice(0, valid))
    return ix


def _take_chunks(state, idxs, valid, lead):
    return jax.tree.map(
        lambda x: x[_state_index(idxs, valid, x.ndim, lead)], state)


def _put_chunks(full, upd, idxs, valid, lead):
    return jax.tree.map(
        lambda f, u: f.at[_state_index(idxs, valid, f.ndim, lead)].set(u),
        full, upd)


@dataclasses.dataclass(frozen=True)
class ChunkedCodec(Codec):
    """A base :class:`Codec` applied independently per ``(layer, chunk)``.

    Implements the flat codec interface over the full ``numel`` vector, so
    both trainers carry it with zero changes; internally every chunk has its
    own k-selection, µ, residual state, wire sub-stream and ledger entry.
    Build via :func:`chunk_codec` (which applies the per-layer sparsity
    schedule hook and forwards the base codec's trainer-visible fields).
    """

    name = "chunked"

    base: Codec = None
    spec: ChunkSpec = None
    layer_codecs: tuple = ()
    #: adaptive per-chunk sparsity controller (repro.core.adaptive); None
    #: or a non-adapting controller ("fixed") runs the static path
    #: byte-identically
    controller: Optional[SparsityController] = None

    # -- forwarded base behaviour (properties shadow the base-class
    #    ClassVars: a wrapper is whatever its base is) ------------------------
    @property
    def error_feedback(self):                                  # noqa: D401
        return self.base.error_feedback

    @property
    def wire_format(self):
        return self.base.wire_format

    @property
    def wire_static_size(self):
        return self.base.wire_static_size

    @property
    def supports_ingest(self):
        return self.base.supports_ingest

    def _chunk_codecs(self):
        """Per-chunk codec (the layer's, after the p_fn schedule)."""
        return tuple(self.layer_codecs[li] for li in self.spec.chunk_layer)

    def _chunk_ps(self, direction: str) -> np.ndarray:
        field = "sparsity_up" if direction == "up" else "sparsity_down"
        return np.asarray([getattr(c, field) for c in self._chunk_codecs()],
                          np.float64)

    def _groups(self):
        return _chunk_groups(self.spec, self.layer_codecs)

    # -- adaptive-controller geometry ----------------------------------------
    def _adapts(self) -> bool:
        return self.controller is not None and self.controller.adapts

    def _ctrl_stateful(self) -> bool:
        return self._adapts() and self.controller.stateful

    def _ctrl_geometry(self, direction: str):
        """Static (base_ks, caps) for the controller: the fixed-p schedule's
        per-chunk k budget and the controller's selection ceilings."""
        base_ks = self.spec.chunk_ks(self._chunk_ps(direction))
        valid = np.asarray(self.spec.chunk_valid, np.int64)
        return base_ks, self.controller.caps(base_ks, valid)

    def _split_ctrl(self, state):
        """Unwrap ``{"base": codec_state, "ctrl": controller_state}`` (the
        wrap exists only for stateful controllers)."""
        if not self._ctrl_stateful():
            return state, None
        return state["base"], state["ctrl"]

    def _join_ctrl(self, base_state, ctrl_state):
        if not self._ctrl_stateful():
            return base_state
        return {"base": base_state, "ctrl": ctrl_state}

    # -- state ----------------------------------------------------------------
    def _stacked_state(self, one):
        if one is None:
            return None
        n = self.spec.n_chunks
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), one)

    def init_client_state(self, numel: int):
        base = self._stacked_state(
            self.base.init_client_state(self.spec.chunk_numel))
        if not self._ctrl_stateful():
            return base
        return {"base": base,
                "ctrl": self.controller.init_state(self._ctrl_geometry(
                    "up")[0])}

    def init_server_state(self, numel: int):
        base = self._stacked_state(
            self.base.init_server_state(self.spec.chunk_numel))
        if not self._ctrl_stateful():
            return base
        return {"base": base,
                "ctrl": self.controller.init_state(self._ctrl_geometry(
                    "down")[0])}

    # -- client side ----------------------------------------------------------
    def encode(self, delta, state):
        msgs, states, stats = self.encode_batch(
            delta[None], jax.tree.map(lambda x: x[None], state))
        return (msgs[0], jax.tree.map(lambda x: x[0], states),
                jax.tree.map(lambda x: x[0], stats))

    def encode_batch(self, deltas, states):
        spec = self.spec
        blocks = spec.split(deltas)            # (P, C, W)
        if self._adapts():
            base_st, ctrl_st = self._split_ctrl(states)
            base_ks, caps = self._ctrl_geometry("up")
            msg_blocks, base_st, ctrl_st, _ = \
                self.base.encode_chunk_blocks_adaptive(
                    blocks, base_st, self.controller, ctrl_st,
                    base_ks=base_ks, caps=caps)
            states = self._join_ctrl(base_st, ctrl_st)
        elif self.base.chunk_blocks:
            ks = spec.chunk_ks(self._chunk_ps("up"))
            msg_blocks, states, _ = self.base.encode_chunk_blocks(
                blocks, states, ks=ks)
        else:
            msg_blocks = jnp.zeros_like(blocks)
            for valid, codec, idxs in self._groups():
                sub = blocks[:, np.asarray(idxs), :valid]      # (P, G, valid)
                st_g = _take_chunks(states, idxs, valid, lead=1)
                m_g, st_g, _ = jax.vmap(codec.encode_batch,
                                        in_axes=(1, 1), out_axes=1)(sub, st_g)
                msg_blocks = msg_blocks.at[:, np.asarray(idxs),
                                           :valid].set(m_g)
                states = _put_chunks(states, st_g, idxs, valid, lead=1)
        msgs = spec.merge(msg_blocks)
        stats = CompressionStats(
            nnz=jnp.sum(msgs != 0, axis=-1),
            numel=jnp.full(msgs.shape[0], spec.numel),
            mu=jnp.zeros(msgs.shape[0], jnp.float32))
        return msgs, states, stats

    # -- server side ----------------------------------------------------------
    def aggregate(self, msgs, server_state, mask=None, staleness=None):
        spec = self.spec
        blocks = spec.split(msgs)              # (P, C, W)
        if self._adapts():
            base_st, ctrl_st = self._split_ctrl(server_state)
            base_ks, caps = self._ctrl_geometry("down")
            out_blocks, base_st, ctrl_st, _ = \
                self.base.aggregate_chunk_blocks_adaptive(
                    blocks, base_st, self.controller, ctrl_st,
                    base_ks=base_ks, caps=caps, mask=mask,
                    staleness=staleness)
            server_state = self._join_ctrl(base_st, ctrl_st)
        elif self.base.chunk_blocks:
            ks = spec.chunk_ks(self._chunk_ps("down"))
            out_blocks, server_state, _ = self.base.aggregate_chunk_blocks(
                blocks, server_state, ks=ks, mask=mask, staleness=staleness)
        else:
            out_blocks = jnp.zeros(blocks.shape[1:], jnp.float32)
            for valid, codec, idxs in self._groups():
                sub = blocks[:, np.asarray(idxs), :valid]
                st_g = _take_chunks(server_state, idxs, valid, lead=0)
                o_g, st_g, _ = jax.vmap(
                    lambda m, s, c=codec: c.aggregate(
                        m, s, mask=mask, staleness=staleness),
                    in_axes=(1, 0), out_axes=0)(sub, st_g)
                out_blocks = out_blocks.at[np.asarray(idxs), :valid].set(o_g)
                server_state = _put_chunks(server_state, st_g, idxs, valid,
                                           lead=0)
        out = spec.merge(out_blocks)
        stats = CompressionStats(nnz=jnp.sum(out != 0),
                                 numel=jnp.asarray(spec.numel),
                                 mu=jnp.asarray(0.0))
        return out, server_state, stats

    # -- analytic bit ledger (Eq. 1 summed over chunks) -----------------------
    # cached: the codec is frozen/hashable and the trainers evaluate these
    # host-side every round (a fine-chunked big model has 10k+ chunks)
    def upload_bits(self, numel: int) -> float:
        return _analytic_bits(self.spec, self.layer_codecs, "up", 1)

    def download_bits(self, numel: int, n_participating: int = 1) -> float:
        return _analytic_bits(self.spec, self.layer_codecs, "down",
                              n_participating)

    # -- wire format: one sub-stream + header per chunk -----------------------
    def encode_wire_batch(self, msgs, *,
                          direction: str = "up") -> wire.ChunkedWireBatch:
        spec = self.spec
        x = np.ascontiguousarray(np.asarray(msgs, np.float32))
        if x.ndim == 1:
            x = x[None]
        P = x.shape[0]
        blocks = spec.split(x)                                  # np (P, C, W)
        batches, group_ids, group_valid = [], [], []
        bit_len = np.zeros(P, np.int64)
        nnz = np.zeros(P, np.int64)
        for valid, codec, idxs in self._groups():
            G = len(idxs)
            rows = np.ascontiguousarray(
                blocks[:, np.asarray(idxs), :valid]).reshape(P * G, valid)
            wb = codec.encode_wire_batch(rows, direction=direction)
            batches.append(wb)
            group_ids.append(idxs)
            group_valid.append(valid)
            bit_len += np.asarray(wb.bit_len).reshape(P, G).sum(axis=1)
            nnz += np.asarray(wb.nnz).reshape(P, G).sum(axis=1)
        return wire.ChunkedWireBatch(
            batches=tuple(batches), chunk_ids=tuple(group_ids),
            chunk_valid=tuple(group_valid), bit_len=bit_len, nnz=nnz,
            n_msgs=P, numel=spec.numel, n_chunks=spec.n_chunks)

    def encode_wire(self, msg, *, direction: str = "up"):
        batch = self.encode_wire_batch(np.asarray(msg)[None],
                                       direction=direction)
        return wire.ChunkedWireMessage(batch)

    def decode_wire_batch(self, batch: wire.ChunkedWireBatch, *,
                          direction: str = "up") -> np.ndarray:
        spec = self.spec
        blocks = np.zeros((batch.n_msgs, spec.n_chunks, spec.chunk_numel),
                          np.float32)
        # group order is deterministic: batches[g] parallels _groups()[g]
        for (valid, codec, idxs), wb in zip(self._groups(), batch.batches):
            G = len(idxs)
            for p in range(batch.n_msgs):
                for j, ci in enumerate(idxs):
                    blocks[p, ci, :valid] = codec.decode_wire(
                        wb.message(p * G + j), direction=direction)
        return spec.merge(blocks)

    def decode_wire(self, msg, *, direction: str = "up") -> np.ndarray:
        if isinstance(msg, wire.ChunkedWireMessage):
            msg = msg.batch
        return self.decode_wire_batch(msg, direction=direction)[0]

    # -- fused ingest: every chunk sub-stream scatters into its flat slice --
    def ingest_wire(self, acc, msg, weight, *, direction: str = "up"):
        if isinstance(msg, wire.ChunkedWireMessage):
            msg = msg.batch
        self.ingest_wire_batch(acc, msg, np.asarray([weight], np.float64),
                               direction=direction)

    def ingest_wire_batch(self, acc, batch: wire.ChunkedWireBatch, weights,
                          *, direction: str = "up"):
        spec = self.spec
        w = np.asarray(weights, np.float64)
        groups = self._groups()
        for i in range(batch.n_msgs):
            acc.begin_message(float(w[i]), bits=float(batch.bit_len[i])
                              + self._header_bits_per_msg())
            # chunks of one message cover disjoint flat slices, so the
            # scatter order within the message cannot change any coordinate
            for (valid, codec, idxs), wb in zip(groups, batch.batches):
                G = len(idxs)
                for j, ci in enumerate(idxs):
                    codec.ingest_wire_chunk(
                        acc, wb.message(i * G + j), float(w[i]),
                        direction=direction, offset=spec.chunk_start[ci])

    def finalize_ingest(self, combined, server_state):
        spec = self.spec
        if self._adapts():
            blocks = jnp.asarray(spec.split(np.asarray(combined)))
            base_st, ctrl_st = self._split_ctrl(server_state)
            base_ks, caps = self._ctrl_geometry("down")
            # P=1 block tensor: the fused path's plain mean is the identity
            out_blocks, base_st, ctrl_st, _ = \
                self.base.aggregate_chunk_blocks_adaptive(
                    blocks[None], base_st, self.controller, ctrl_st,
                    base_ks=base_ks, caps=caps)
            server_state = self._join_ctrl(base_st, ctrl_st)
        elif self.base.chunk_blocks:
            blocks = jnp.asarray(spec.split(np.asarray(combined)))
            ks = spec.chunk_ks(self._chunk_ps("down"))
            # P=1 block tensor: the fused path's plain mean is the identity
            out_blocks, server_state, _ = self.base.aggregate_chunk_blocks(
                blocks[None], server_state, ks=ks)
        elif self.base.init_server_state(1) is None:
            # stateless elementwise base (signsgd): chunking is a no-op
            return self.base.finalize_ingest(combined, server_state)
        else:
            blocks = spec.split(np.asarray(combined))
            out_blocks = jnp.zeros((spec.n_chunks, spec.chunk_numel),
                                   jnp.float32)
            for valid, codec, idxs in self._groups():
                sub = jnp.asarray(blocks[np.asarray(idxs), :valid])
                st_g = _take_chunks(server_state, idxs, valid, lead=0)
                o_g, st_g, _ = jax.vmap(codec.finalize_ingest)(sub, st_g)
                out_blocks = out_blocks.at[np.asarray(idxs), :valid].set(o_g)
                server_state = _put_chunks(server_state, st_g, idxs, valid,
                                           lead=0)
        out = spec.merge(out_blocks)
        stats = CompressionStats(nnz=jnp.sum(out != 0),
                                 numel=jnp.asarray(spec.numel),
                                 mu=jnp.asarray(0.0))
        return out, server_state, stats

    def _header_bits_per_msg(self) -> float:
        # every chunk carries the base codec's side information independently
        return self.spec.n_chunks * self.base.wire_header_bits

    def measured_batch_bits(self, batch) -> float:
        return batch.total_bits() + batch.n_msgs * self._header_bits_per_msg()

    def measured_message_bits(self, msg) -> float:
        return msg.bit_len + self._header_bits_per_msg()

    def wire_bound_bits(self, numel, nnz, direction="up"):
        # Each chunk's bound is monotone in its nnz, so charging every chunk
        # min(nnz, valid) ceilings ANY split of nnz across chunks; at
        # whole-vector this reduces exactly to the base codec's bound.
        per_chunk = [c.wire_bound_bits(v, min(int(nnz), v), direction)
                     for c, v in zip(self._chunk_codecs(),
                                     self.spec.chunk_valid)]
        if any(b is None for b in per_chunk):
            return None
        return float(sum(per_chunk))

    # -- tree path: delegate to the base codec (the mesh trainer chunks
    #    per leaf through the codec's own chunk_size field instead) ----------
    def tree_encode(self, delta, residual, *, numel, iters=32):
        return self.base.tree_encode(delta, residual, numel=numel,
                                     iters=iters)

    def tree_reduce(self, msgs, axes, n_clients, mask=None, staleness=None):
        return self.base.tree_reduce(msgs, axes, n_clients, mask=mask,
                                     staleness=staleness)

    def tree_decode(self, combined, residual, *, numel, iters=32):
        return self.base.tree_decode(combined, residual, numel=numel,
                                     iters=iters)


def chunk_codec(base: Codec, spec: ChunkSpec,
                p_fn: Optional[Callable] = None,
                controller=None) -> ChunkedCodec:
    """Wrap ``base`` into a :class:`ChunkedCodec` over ``spec``.

    ``p_fn(layer_name, depth) -> p | None`` rescales the sparsity of layers
    whose codec declares ``sparsity_up``/``sparsity_down`` (None keeps the
    base value); other codecs ignore the hook.  Every schedule-produced p
    is validated at wrap time (finite, 0 < p <= 1) with a ``ValueError``
    naming the offending layer -- a silent k=0 or full-dense chunk would
    corrupt the bit ledger downstream.

    ``controller`` is a registered :class:`repro.core.adaptive.
    SparsityController` name or instance; ``"fixed"``/None keep the static
    path byte-identically, adaptive controllers require a base codec with
    the fused chunk-blocks path.  The wrapper forwards the base codec's
    trainer-visible knobs (``local_iters``, staleness decay, the
    aggregation ``rule``).  (Codecs predating the masked aggregate API
    cannot exist anymore -- ``Codec.__init_subclass__`` rejects them at
    class-definition time.)
    """
    if isinstance(base, ChunkedCodec):
        raise TypeError("chunk_codec over an already-chunked codec")
    ctrl = make_controller(controller) if controller is not None else None
    if ctrl is not None and ctrl.adapts and not base.chunk_blocks:
        raise TypeError(
            f"adaptive sparsity controller {ctrl.name!r} requires a codec "
            f"with the fused chunk-blocks path (chunk_blocks=True); "
            f"{type(base).__name__} has none")
    fields = {f.name for f in dataclasses.fields(type(base))}
    layer_codecs = []
    for depth, lname in enumerate(spec.layer_names):
        c = base
        p = p_fn(lname, depth) if p_fn is not None else None
        if p is not None:
            p = validate_sparsity(p, lname, depth)
            repl = {k: float(p) for k in ("sparsity_up", "sparsity_down")
                    if k in fields}
            if repl:
                c = dataclasses.replace(base, **repl)
        layer_codecs.append(c)
    return ChunkedCodec(base=base, spec=spec, layer_codecs=tuple(layer_codecs),
                        controller=ctrl,
                        local_iters=base.local_iters,
                        staleness_decay=base.staleness_decay,
                        rule=base.rule)
