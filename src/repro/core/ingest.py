"""Fused decode→aggregate server ingestion: no dense ``(P, numel)`` block.

The paper's fleet regime (many clients, participation ``1/400`` and below,
Fig. 7) makes the server the bottleneck: a round's uploads decoded into a
dense ``(P, numel)`` batch cost ``P * numel`` floats of peak memory before a
single aggregate FLOP.  This module replaces that block with ONE
``numel``-sized accumulator pair that every arriving wire stream scatters
into directly:

* ``sum``          -- fp64 weighted coordinate sums (the only O(numel) state)
* ``weight_mass``  -- arrived participation-weight total (the denominator of
  the masked/staleness-weighted mean, accumulated in ARRIVAL order)

so peak ingest memory is independent of how many clients report, and decode
fuses with aggregation: the Golomb field decoder
(:func:`repro.core.wire.decode_ternary_fields_batch`) yields ``(segment,
position, sign)`` triples that scatter straight into ``sum`` -- the dense
per-client tensor never exists.

Bit-exactness contract (property-tested in tests/test_ingest.py): the fused
wire scatter and the dense decode→``add_dense`` oracle perform THE SAME fp64
products in THE SAME order -- ``(sign * fp32(µ)) -> fp64 * fp64(w)`` per
coordinate, message-major -- and untouched coordinates differ only by adding
``w * (+/-0.0)``, which is a bitwise no-op on an fp64 accumulator.  Both
paths therefore share one ``combined()`` and one codec ``finalize_ingest``,
and agree bit for bit, not just to tolerance.

``weight_mass`` is summed by a sequential scalar loop on the codec side (NOT
``np.sum``, whose pairwise tree would re-order the adds) so arrival-order
identity holds for the denominator too.
"""

from __future__ import annotations

import numpy as np

__all__ = ["IngestAccumulator"]


class IngestAccumulator:
    """Streaming server-side accumulator for one round's uploads.

    O(numel) state; every method is O(touched coordinates).  ``offset``
    arguments let chunked codecs scatter each chunk sub-stream into its flat
    slice of the merged vector (``ChunkSpec.chunk_start``).
    """

    __slots__ = ("numel", "sum", "weight_mass", "n_msgs", "nnz",
                 "stream_bits", "n_screened")

    def __init__(self, numel: int):
        self.numel = int(numel)
        self.sum = np.zeros(self.numel, np.float64)
        self.weight_mass = 0.0
        self.n_msgs = 0
        self.nnz = 0
        self.stream_bits = 0.0
        self.n_screened = 0

    # -- per-message bookkeeping ---------------------------------------------
    def begin_message(self, weight: float, *, bits: float = 0.0) -> None:
        """Account one arrival: its aggregation weight (mask × staleness
        decay, already resolved by the caller) and its measured wire bits."""
        self.n_msgs += 1
        self.weight_mass += float(weight)
        self.stream_bits += float(bits)

    def note_screened(self) -> None:
        """Record one message rejected by a screening aggregation rule
        (``norm_screened_mean`` with ``policy="reject"``): it was counted
        by :meth:`begin_message` with zero weight -- bits billed, zero
        aggregate contribution."""
        self.n_screened += 1

    # -- scatter paths (weight_mass is NOT touched here) ---------------------
    def scatter_ternary(self, positions: np.ndarray, signs: np.ndarray,
                        mu: float, weight: float, *, offset: int = 0) -> None:
        """One message's decoded ternary fields -> weighted coordinate adds.

        ``positions`` are unique within a message, so a plain fancy-index
        ``+=`` is exact (no lost duplicate updates)."""
        if positions.size == 0:
            return
        self.nnz += int(positions.size)
        contrib = (signs * np.float32(mu)).astype(np.float64) \
            * np.float64(weight)
        self.sum[offset + positions] += contrib

    def scatter_ternary_batch(self, seg: np.ndarray, positions: np.ndarray,
                              signs: np.ndarray, mus: np.ndarray,
                              weights: np.ndarray) -> None:
        """A whole batch's fields in ONE scatter.

        ``np.add.at`` applies element-order, and the fields are message-major
        in stream order, so this is bitwise the sequential per-message
        :meth:`scatter_ternary` loop."""
        if positions.size == 0:
            return
        self.nnz += int(positions.size)
        mu32 = np.asarray(mus, np.float64).astype(np.float32)
        w64 = np.asarray(weights, np.float64)
        contrib = (signs * mu32[seg]).astype(np.float64) * w64[seg]
        np.add.at(self.sum, positions, contrib)

    def add_sign_plane(self, bits01: np.ndarray, step: float, weight: float,
                       *, offset: int = 0) -> None:
        """A dense 1-bit sign plane: every coordinate lands ``±step``."""
        n = int(bits01.size)
        if n == 0:
            return
        self.nnz += n
        vals = np.where(bits01 == 1, np.float32(step), np.float32(-step))
        self.sum[offset : offset + n] += vals.astype(np.float64) \
            * np.float64(weight)

    def add_dense(self, vec: np.ndarray, weight: float, *,
                  offset: int = 0) -> None:
        """A decoded dense fp32 message (the oracle path, and the ingest
        route for codecs without a wire format)."""
        v = np.asarray(vec, np.float32)
        self.nnz += int(np.count_nonzero(v))
        self.sum[offset : offset + v.size] += v.astype(np.float64) \
            * np.float64(weight)

    # -- read-out ------------------------------------------------------------
    def combined(self) -> np.ndarray:
        """Weighted mean over arrived mass, fp32.

        The denominator guard matches :meth:`Codec.combine` exactly
        (``total if total > 0 else 1.0``, NOT ``max(total, 1)``), so an
        all-masked round degrades identically on both aggregate paths."""
        total = self.weight_mass
        denom = total if total > 0 else 1.0
        return (self.sum / np.float64(denom)).astype(np.float32)
