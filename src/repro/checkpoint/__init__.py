"""Checkpointing: msgpack-serialized pytrees (no orbax in this container).

    save_checkpoint(path, {"params": ..., "step": ...})
    tree = restore_checkpoint(path, like=template_tree)

Arrays are stored as (dtype, shape, raw bytes); bfloat16 round-trips via a
uint16 view.  The federated trainer and the distributed train_step state are
both plain pytrees, so one pair of functions covers the whole framework.

``save_state`` / ``restore_state`` are the template-free tagged variants for
composite trainer state whose shape is data-dependent (the event-driven
trainer's crash-consistent checkpoints: event clock, in-flight buffer, RNG
states, logs).
"""

from .msgpack_ckpt import (restore_checkpoint, restore_state,
                           save_checkpoint, save_state)

__all__ = ["save_checkpoint", "restore_checkpoint",
           "save_state", "restore_state"]
