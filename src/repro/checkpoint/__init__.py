"""Checkpointing: msgpack-serialized pytrees (no orbax in this container).

    save_checkpoint(path, {"params": ..., "step": ...})
    tree = restore_checkpoint(path, like=template_tree)

Arrays are stored as (dtype, shape, raw bytes); bfloat16 round-trips via a
uint16 view.  The federated trainer and the distributed train_step state are
both plain pytrees, so one pair of functions covers the whole framework.
"""

from .msgpack_ckpt import restore_checkpoint, save_checkpoint

__all__ = ["save_checkpoint", "restore_checkpoint"]
