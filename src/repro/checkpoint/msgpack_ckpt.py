"""Msgpack pytree checkpointing with zstd (or stdlib zlib) compression.

``zstandard`` is an *optional* dependency: when it is missing we fall back to
stdlib ``zlib``.  The codec is sniffed on restore via the zstd frame magic, so
checkpoints written with either codec restore correctly whenever the matching
decompressor is importable.
"""

from __future__ import annotations

import os
import tempfile
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional: prefer zstd when available (better ratio + speed)
    import zstandard
except ImportError:  # pragma: no cover - exercised on minimal images
    zstandard = None

__all__ = ["save_checkpoint", "restore_checkpoint",
           "save_state", "restore_state"]

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"  # little-endian 0xFD2FB528 frame header

_BF16 = "bfloat16"


def _pack_leaf(x):
    arr = np.asarray(x)
    if str(arr.dtype) == _BF16:
        return {"d": _BF16, "s": list(arr.shape),
                "b": arr.view(np.uint16).tobytes()}
    return {"d": str(arr.dtype), "s": list(arr.shape), "b": arr.tobytes()}


def _unpack_leaf(rec):
    if rec["d"] == _BF16:
        arr = np.frombuffer(rec["b"], np.uint16).reshape(rec["s"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    arr = np.frombuffer(rec["b"], np.dtype(rec["d"])).reshape(rec["s"])
    return jnp.asarray(arr)


def save_checkpoint(path: str, tree) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = msgpack.packb({
        "treedef": str(treedef),  # structural fingerprint for validation
        "leaves": [_pack_leaf(x) for x in leaves],
    })
    if zstandard is not None:
        comp = zstandard.ZstdCompressor(level=3).compress(payload)
    else:
        comp = zlib.compress(payload, 6)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic write
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(comp)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def restore_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (validates leaf count +
    treedef fingerprint)."""
    with open(path, "rb") as f:
        raw = f.read()
    if raw[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ImportError(
                f"{path} is zstd-compressed but the 'zstandard' package is "
                "not installed")
        payload = zstandard.ZstdDecompressor().decompress(raw)
    else:
        payload = zlib.decompress(raw)
    obj = msgpack.unpackb(payload)
    leaves, treedef = jax.tree.flatten(like)
    if len(obj["leaves"]) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(obj['leaves'])} leaves, template has "
            f"{len(leaves)}")
    if obj["treedef"] != str(treedef):
        raise ValueError("checkpoint tree structure mismatch")
    return jax.tree.unflatten(treedef, [_unpack_leaf(r)
                                        for r in obj["leaves"]])


# ---------------------------------------------------------------------------
# template-free tagged state serialization
# ---------------------------------------------------------------------------
# ``save_checkpoint`` needs a matching template pytree on restore, which the
# event-driven trainer's crash-resume path cannot supply (the in-flight
# buffer, event logs and RNG states have data-dependent shape).  The tagged
# codec below round-trips an arbitrary composite of Python scalars, numpy
# arrays, lists/tuples/sets/dicts and registered NamedTuples without a
# template.  Scalars small enough for msgpack pass through raw; everything
# else is a ``[tag, ...]`` list:
#
#   "I"  big int (hex string -- PCG64 carries 128-bit state words)
#   "a"  ndarray      "a0" numpy scalar     "l" list     "t" tuple
#   "nt" NamedTuple (by registered class name)           "s" set (sorted)
#   "d"  dict with non-string or tagged keys
#
# Restore returns numpy arrays (callers re-device with jnp.asarray where
# needed): bit-exactness of the resumed trainer must not depend on any
# device round-trip.

_MSGPACK_INT_MAX = (1 << 64) - 1
_MSGPACK_INT_MIN = -(1 << 63)


def _tag_state(x, classes: dict):
    if x is None or isinstance(x, (bool, float, str, bytes)):
        return x
    if isinstance(x, int):
        if _MSGPACK_INT_MIN <= x <= _MSGPACK_INT_MAX:
            return x
        return ["I", hex(x)]
    if isinstance(x, np.ndarray):
        return ["a", _pack_leaf(x)]
    if isinstance(x, np.generic):
        return ["a0", _pack_leaf(x)]
    if isinstance(x, tuple) and hasattr(x, "_fields"):
        cname = type(x).__name__
        classes.setdefault(cname, type(x))
        return ["nt", cname, [_tag_state(v, classes) for v in x]]
    if isinstance(x, tuple):
        return ["t", [_tag_state(v, classes) for v in x]]
    if isinstance(x, list):
        return ["l", [_tag_state(v, classes) for v in x]]
    if isinstance(x, (set, frozenset)):
        return ["s", [_tag_state(v, classes) for v in sorted(x)]]
    if isinstance(x, dict):
        if all(isinstance(k, str) and k not in ("I", "a", "a0", "nt", "t",
                                                "l", "s", "d")
               for k in x):
            return {k: _tag_state(v, classes) for k, v in x.items()}
        return ["d", [[_tag_state(k, classes), _tag_state(v, classes)]
                      for k, v in x.items()]]
    raise TypeError(f"save_state cannot serialize {type(x).__name__}")


def _untag_state(x, classes: dict):
    if isinstance(x, dict):
        return {k: _untag_state(v, classes) for k, v in x.items()}
    if not isinstance(x, list):
        return x
    tag = x[0]
    if tag == "I":
        return int(x[1], 16)
    if tag == "a":
        return np.asarray(_unpack_leaf(x[1]))
    if tag == "a0":
        return np.asarray(_unpack_leaf(x[1])).reshape(())[()]
    if tag == "nt":
        cls = classes.get(x[1])
        if cls is None:
            raise KeyError(
                f"restore_state needs the NamedTuple class {x[1]!r} in "
                "`classes` to rebuild this checkpoint")
        return cls(*[_untag_state(v, classes) for v in x[2]])
    if tag == "t":
        return tuple(_untag_state(v, classes) for v in x[1])
    if tag == "l":
        return [_untag_state(v, classes) for v in x[1]]
    if tag == "s":
        return set(_untag_state(v, classes) for v in x[1])
    if tag == "d":
        return {_untag_state(k, classes): _untag_state(v, classes)
                for k, v in x[1]}
    raise ValueError(f"unknown state tag {tag!r}")


def _write_compressed(path: str, payload: bytes) -> None:
    if zstandard is not None:
        comp = zstandard.ZstdCompressor(level=3).compress(payload)
    else:
        comp = zlib.compress(payload, 6)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(comp)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def save_state(path: str, obj) -> None:
    """Serialize an arbitrary tagged-codec state object (see above) with
    the same compression + atomic-replace discipline as
    :func:`save_checkpoint`."""
    classes: dict = {}
    _write_compressed(path, msgpack.packb(_tag_state(obj, classes)))


def restore_state(path: str, classes: Optional[dict] = None):
    """Inverse of :func:`save_state`.  ``classes`` maps NamedTuple class
    names to their classes (needed to rebuild "nt" records)."""
    with open(path, "rb") as f:
        raw = f.read()
    if raw[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ImportError(
                f"{path} is zstd-compressed but the 'zstandard' package is "
                "not installed")
        payload = zstandard.ZstdDecompressor().decompress(raw)
    else:
        payload = zlib.decompress(raw)
    return _untag_state(msgpack.unpackb(payload, strict_map_key=False),
                        classes or {})
