"""Msgpack pytree checkpointing with zstd (or stdlib zlib) compression.

``zstandard`` is an *optional* dependency: when it is missing we fall back to
stdlib ``zlib``.  The codec is sniffed on restore via the zstd frame magic, so
checkpoints written with either codec restore correctly whenever the matching
decompressor is importable.
"""

from __future__ import annotations

import os
import tempfile
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional: prefer zstd when available (better ratio + speed)
    import zstandard
except ImportError:  # pragma: no cover - exercised on minimal images
    zstandard = None

__all__ = ["save_checkpoint", "restore_checkpoint"]

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"  # little-endian 0xFD2FB528 frame header

_BF16 = "bfloat16"


def _pack_leaf(x):
    arr = np.asarray(x)
    if str(arr.dtype) == _BF16:
        return {"d": _BF16, "s": list(arr.shape),
                "b": arr.view(np.uint16).tobytes()}
    return {"d": str(arr.dtype), "s": list(arr.shape), "b": arr.tobytes()}


def _unpack_leaf(rec):
    if rec["d"] == _BF16:
        arr = np.frombuffer(rec["b"], np.uint16).reshape(rec["s"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    arr = np.frombuffer(rec["b"], np.dtype(rec["d"])).reshape(rec["s"])
    return jnp.asarray(arr)


def save_checkpoint(path: str, tree) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = msgpack.packb({
        "treedef": str(treedef),  # structural fingerprint for validation
        "leaves": [_pack_leaf(x) for x in leaves],
    })
    if zstandard is not None:
        comp = zstandard.ZstdCompressor(level=3).compress(payload)
    else:
        comp = zlib.compress(payload, 6)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic write
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(comp)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def restore_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (validates leaf count +
    treedef fingerprint)."""
    with open(path, "rb") as f:
        raw = f.read()
    if raw[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ImportError(
                f"{path} is zstd-compressed but the 'zstandard' package is "
                "not installed")
        payload = zstandard.ZstdDecompressor().decompress(raw)
    else:
        payload = zlib.decompress(raw)
    obj = msgpack.unpackb(payload)
    leaves, treedef = jax.tree.flatten(like)
    if len(obj["leaves"]) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(obj['leaves'])} leaves, template has "
            f"{len(leaves)}")
    if obj["treedef"] != str(treedef):
        raise ValueError("checkpoint tree structure mismatch")
    return jax.tree.unflatten(treedef, [_unpack_leaf(r)
                                        for r in obj["leaves"]])
