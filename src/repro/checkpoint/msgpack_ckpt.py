"""Msgpack pytree checkpointing with zstd compression."""

from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zstandard

__all__ = ["save_checkpoint", "restore_checkpoint"]

_BF16 = "bfloat16"


def _pack_leaf(x):
    arr = np.asarray(x)
    if str(arr.dtype) == _BF16:
        return {"d": _BF16, "s": list(arr.shape),
                "b": arr.view(np.uint16).tobytes()}
    return {"d": str(arr.dtype), "s": list(arr.shape), "b": arr.tobytes()}


def _unpack_leaf(rec):
    if rec["d"] == _BF16:
        arr = np.frombuffer(rec["b"], np.uint16).reshape(rec["s"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    arr = np.frombuffer(rec["b"], np.dtype(rec["d"])).reshape(rec["s"])
    return jnp.asarray(arr)


def save_checkpoint(path: str, tree) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = msgpack.packb({
        "treedef": str(treedef),  # structural fingerprint for validation
        "leaves": [_pack_leaf(x) for x in leaves],
    })
    comp = zstandard.ZstdCompressor(level=3).compress(payload)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic write
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(comp)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def restore_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (validates leaf count +
    treedef fingerprint)."""
    with open(path, "rb") as f:
        payload = zstandard.ZstdDecompressor().decompress(f.read())
    obj = msgpack.unpackb(payload)
    leaves, treedef = jax.tree.flatten(like)
    if len(obj["leaves"]) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(obj['leaves'])} leaves, template has "
            f"{len(leaves)}")
    if obj["treedef"] != str(treedef):
        raise ValueError("checkpoint tree structure mismatch")
    return jax.tree.unflatten(treedef, [_unpack_leaf(r)
                                        for r in obj["leaves"]])
