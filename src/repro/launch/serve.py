"""Serving steps: prefill (full forward over the prompt) and decode (one new
token against a KV/state cache), with production-mesh shardings.

Batch is sharded over the client axes ("pod","data"); heads / latent / expert
dims over "model".  long_500k decode uses each arch's LONG_CONFIG: ring-buffer
sliding-window caches for full-attention archs, O(1) recurrent state for
SSM/hybrid (see DESIGN.md §Decode-shape coverage).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.models import decode_step, forward, init_cache, init_model
from repro.models.config import ModelConfig
from repro.sharding.rules import cache_specs, fit_spec, param_shardings

__all__ = ["make_prefill_step", "make_decode_step", "serve_state_structs"]


def make_prefill_step(cfg: ModelConfig, mesh, compute_dtype=jnp.bfloat16):
    """jit'd ``prefill(params, batch) -> logits`` (batch: dict of inputs)."""

    def prefill(params, batch):
        # hidden states for every position, logits only for the LAST one --
        # the realistic serving prefill (the full (B,S,V) logits tensor would
        # be 0.5 TB for recurrentgemma's 256k vocab at 32k prompt).
        hidden, _ = forward(params, cfg, batch["tokens"],
                            prefix=batch.get("prefix"),
                            frames=batch.get("frames"),
                            compute_dtype=compute_dtype, return_hidden=True)
        head = params.get("lm_head", params["embed"])
        return hidden[:, -1:, :] @ head.T.astype(hidden.dtype)

    return jax.jit(prefill)


def make_decode_step(cfg: ModelConfig, mesh, compute_dtype=jnp.bfloat16,
                     cache_mode: str = "heads"):
    """jit'd ``decode(params, token, caches[, memory]) -> (logits, caches)``.

    ``cache_mode="batch"`` (§Perf lever) pins KV caches to batch-only sharding
    with in-function constraints: every model-axis device holds its batch
    shard's FULL cache and computes attention locally -- this removes the
    per-layer attention-score all-reduce that GSPMD otherwise inserts when the
    KV-head count can't fill the model axis (measured 176 MB/step on
    qwen2 decode_32k).  Cost: model-redundant score compute (negligible at
    decode) and KV HBM not divided by the model axis (still
    batch-sharded)."""
    from jax.sharding import PartitionSpec as P
    from repro.models import attention as attn_mod
    from repro.models.attention import KVCache

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    if cache_mode in ("local", "seq"):
        # q/k/v of the NEW token replicated over "model": the scores einsum
        # then needs no head/hd collective (head counts often don't divide the
        # model axis -- qwen2 has 14/2).
        rep = NamedSharding(mesh, P(dp, None, None, None))
        attn_mod.DECODE_SHARD_HINT = (
            lambda t: jax.lax.with_sharding_constraint(t, rep))
    else:
        attn_mod.DECODE_SHARD_HINT = None

    # cache layout per mode:
    #   "batch": replicated over model (each device scans the full cache)
    #   "seq":   SEQUENCE-sharded over model (flash-decoding style) -- scores
    #            are computed locally per S-shard; the softmax/value
    #            contraction combines via tiny (B,H,hd) partial all-reduces.
    _cache_spec = {
        "batch": P(dp, None, None, None),
        "local": P(dp, None, None, None),
        "seq": P(dp, "model", None, None),
    }.get(cache_mode)

    def _pin(caches):
        if _cache_spec is None:
            return caches
        out = []
        for c in caches:
            if isinstance(c, KVCache):
                sh = NamedSharding(mesh, _cache_spec)
                out.append(c._replace(
                    k=jax.lax.with_sharding_constraint(c.k, sh),
                    v=jax.lax.with_sharding_constraint(c.v, sh)))
            else:
                out.append(c)
        return out

    def decode(params, token, caches, memory=None):
        logits, new = decode_step(params, cfg, token, _pin(caches),
                                  memory=memory, compute_dtype=compute_dtype)
        return logits, _pin(new)

    return jax.jit(decode)


def serve_state_structs(cfg: ModelConfig, mesh, batch: int, s_cache: int,
                        cache_dtype=jnp.bfloat16):
    """(params_struct, caches_struct) as sharded ShapeDtypeStructs -- used by
    the dry-run to lower serve steps without allocating anything."""
    key = jax.random.PRNGKey(0)
    params_struct = jax.eval_shape(functools.partial(init_model, cfg), key)
    p_shardings = param_shardings(params_struct, mesh)
    params_struct = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params_struct, p_shardings)

    caches = jax.eval_shape(
        functools.partial(init_cache, cfg, batch, s_cache, cache_dtype))
    # eval_shape keeps NamedTuple structure; attach shardings per field
    caches_concrete = init_cache(cfg, 1, 2, cache_dtype)  # tiny, for specs only
    specs = cache_specs(caches_concrete, mesh, batch)
    caches_struct = jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(mesh, fit_spec(spec, s.shape, mesh)))
        if hasattr(s, "shape") and len(getattr(s, "shape", ())) > 0
        else s,
        caches, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct) or not hasattr(x, "_fields"))
    return params_struct, caches_struct
