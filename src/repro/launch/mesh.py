"""Production meshes.

Single pod:  (16, 16)      axes ("data", "model")  -- 256 chips (v5e pod)
Multi pod:   (2, 16, 16)   axes ("pod", "data", "model") -- 512 chips

``data`` (x ``pod``) carries the federated clients: one data-parallel group
per client cohort.  ``model`` is tensor parallelism inside a client replica.
Defined as functions so importing this module never touches jax device state.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.4.38; older versions have no axis types (everything is Auto)
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

__all__ = ["make_production_mesh", "make_debug_mesh"]


def _mesh(shape, axes):
    # Auto axis types: GSPMD propagates the "model" axis; shard_map takes the
    # client axes manual.  (Explicit pinning is left to a future jax.)
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    >= data*model*(pod or 1))."""
    if pod:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))
