import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, WITHOUT allocating any real arrays.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

For each combination this prints/records:
  * compiled.memory_analysis()  -- proves the working set fits per device
  * compiled.cost_analysis()    -- HLO FLOPs / bytes for the roofline
  * the collective schedule parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute operand
    bytes) -- the collective roofline term

Results go to artifacts/dryrun/<arch>__<shape>__<mesh>.json; benchmarks/
roofline.py turns them into the EXPERIMENTS.md tables.

NOTE the XLA_FLAGS line above MUST run before any other import that touches
jax -- jax locks the device count on first backend init.  This env var is set
only here, never globally (smoke tests and benches see 1 device).
"""

import argparse
import functools
import json
import re
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import (make_decode_step, make_prefill_step,
                                serve_state_structs)
from repro.launch.train import (TrainConfig, WireLedger, batch_shardings,
                                codec_for, init_train_state, make_train_step,
                                state_shardings)
from repro.sharding.rules import batch_spec

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")

_COLL_RE = re.compile(
    r"(\w[\w\.\-]*)\s*=\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective in the optimized HLO.

    Returns {kind: {"count": n, "bytes": total_result_bytes}}.  The roofline
    converts result bytes to wire bytes with the standard ring-algorithm
    factors (see benchmarks/roofline.py).
    """
    out: dict = {}
    for m in _COLL_RE.finditer(hlo_text):
        _, dtype, dims, kind = m.groups()
        nel = 1
        if dims:
            for d in dims.split(","):
                if d:
                    nel *= int(d)
        b = nel * _DTYPE_BYTES.get(dtype, 4)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


def measured_ingest_bytes(tc: TrainConfig, numel: int, n_clients: int,
                          sample_cap: int = 1 << 22, seed: int = 0) -> dict:
    """Measured server ingest/broadcast bytes per round via the WireLedger.

    Encodes ONE sampled client update through the codec's actual wire format
    (the same measurement path the mesh trainer's ledger uses) and scales to
    the full parameter count and cohort -- measured bits per coded position
    are position-invariant up to the Golomb gap statistics, so a >= 2^22
    sample pins the per-round figure without materializing a model-sized
    round on the dry-run host.  Codecs without a wire format report the
    ledger's analytic column in both fields.
    """
    import numpy as np
    codec = codec_for(tc)
    n_s = min(numel, sample_cap)
    rng = np.random.default_rng(seed)
    k = max(int(n_s * getattr(codec, "sparsity_up", 1.0)), 1)
    up = np.zeros(n_s, np.float32)
    up[rng.choice(n_s, size=k, replace=False)] = \
        rng.choice((-1.0, 1.0), size=k) * 0.01
    kd = max(int(n_s * getattr(codec, "sparsity_down", 1.0)), 1)
    down = np.zeros(n_s, np.float32)
    down[rng.choice(n_s, size=kd, replace=False)] = \
        rng.choice((-1.0, 1.0), size=kd) * 0.01
    ledger = WireLedger(codec, n_s)
    ledger.record_round({"m": up[None]}, {"g": down})
    scale = numel / n_s
    return {
        "bytes_up_round": ledger.bits_up / 8.0 * scale * n_clients,
        "bytes_down_round": ledger.bits_down / 8.0 * scale,
        "analytic_bytes_up_round":
            ledger.bits_up_analytic / 8.0 * scale * n_clients,
        "analytic_bytes_down_round":
            ledger.bits_down_analytic / 8.0 * scale,
        "sampled_numel": n_s,
        "n_clients": n_clients,
    }


def fleet_event_stats(n_clients: int, seed: int = 0) -> dict:
    """Per-scenario event statistics for the dry-run record.

    One model-free :func:`repro.fed.events.simulate_scenario` pass (pure
    numpy -- no lowering, no arrays) per registered fleet scenario, sized to
    the mesh's client count: how often the K-arrival trigger fires and what
    fraction of uploads the fleet loses BEFORE anyone burns pod time on the
    real run.
    """
    from repro.fed.events import simulate_scenario
    from repro.fed.scenarios import registered_scenarios
    cohort = max(n_clients // 8, 1)
    out = {}
    for name in registered_scenarios():
        st = simulate_scenario(name, n_clients=n_clients, cohort=cohort,
                               concurrency=2 * cohort, max_staleness=2,
                               aggregations=6, seed=seed)
        out[name] = {k: (round(v, 4) if isinstance(v, float) else v)
                     for k, v in st.items() if k != "scenario"}
    return out


def _attach(struct_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
        if hasattr(s, "shape") else s,
        struct_tree, sharding_tree)


def _mesh_tag(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                tc: TrainConfig | None = None, verbose: bool = True,
                logit_chunk: int = 0, cache_shard: str = "heads",
                moe_dispatch: str = "", flash_bf16: bool = False) -> dict:
    """Lower + compile one (arch, shape, mesh). Returns the result record.

    ``logit_chunk``/``cache_shard`` are §Perf levers (0/"heads" = baseline).
    """
    import dataclasses
    from repro.sharding import rules as sharding_rules
    if flash_bf16:
        from repro.models import flash as flash_mod
        flash_mod.P_BLOCK_DTYPE = jnp.bfloat16
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch, shape_name)
    if logit_chunk:
        cfg = dataclasses.replace(cfg, logit_chunk=logit_chunk)
    if moe_dispatch and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=moe_dispatch))
    sharding_rules.CACHE_SHARD_MODE = cache_shard
    shape = INPUT_SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    tc = tc or TrainConfig(protocol="stc")

    n_clients = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_clients *= mesh.shape[a]

    t0 = time.time()
    if shape.kind == "train":
        state_struct = jax.eval_shape(
            functools.partial(init_train_state, cfg, tc, n_clients),
            jax.random.PRNGKey(0))
        st_sh = state_shardings(state_struct, mesh)
        state_struct = _attach(state_struct, st_sh)
        b_sh = batch_shardings(specs, mesh, shape.global_batch)
        batch_struct = _attach(specs, b_sh)
        step = make_train_step(cfg, mesh, tc)
        lowered = step.lower(state_struct, batch_struct)
    elif shape.kind == "prefill":
        params_struct, _ = serve_state_structs(cfg, mesh, shape.global_batch,
                                               2)
        b_sh = batch_shardings(specs, mesh, shape.global_batch)
        batch_struct = _attach(specs, b_sh)
        step = make_prefill_step(cfg, mesh)
        lowered = step.lower(params_struct, batch_struct)
    else:  # decode
        params_struct, caches_struct = serve_state_structs(
            cfg, mesh, shape.global_batch, shape.seq_len)
        bs = batch_spec(mesh, shape.global_batch)
        token_struct = jax.ShapeDtypeStruct(
            (shape.global_batch, 1), jnp.int32,
            sharding=NamedSharding(mesh, bs))
        step = make_decode_step(cfg, mesh, cache_mode=cache_shard
                                if cache_shard in ("batch", "local", "seq")
                                else "heads")
        if "memory" in specs:
            mem = specs["memory"]
            mem_struct = jax.ShapeDtypeStruct(
                mem.shape, mem.dtype, sharding=NamedSharding(mesh, bs))
            lowered = step.lower(params_struct, token_struct, caches_struct,
                                 mem_struct)
        else:
            lowered = step.lower(params_struct, token_struct, caches_struct)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    mem_rec = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_rec[k] = int(v)
    colls = parse_collectives(compiled.as_text())

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": _mesh_tag(multi_pod),
        "kind": shape.kind,
        "protocol": tc.protocol if shape.kind == "train" else "serve",
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "memory": mem_rec,
        "collectives": colls,
        "params": cfg.param_count(),
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
    }
    if shape.kind == "train":
        rec["server_ingest"] = measured_ingest_bytes(
            tc, cfg.param_count(), n_clients)
        rec["fleet_scenarios"] = fleet_event_stats(max(n_clients, 8))
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
              f"flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
              f"colls={ {k: v['count'] for k, v in colls.items()} } "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
        if mem_rec:
            print(f"         memory_analysis: { {k: f'{v/2**30:.2f}GiB' for k, v in mem_rec.items()} }")
        if "server_ingest" in rec:
            si = rec["server_ingest"]
            print(f"         server_ingest: up={si['bytes_up_round']/2**20:.2f}"
                  f"MiB/round down={si['bytes_down_round']/2**20:.2f}MiB/round "
                  f"(measured, {si['n_clients']} clients)")
        if "fleet_scenarios" in rec:
            worst = max(rec["fleet_scenarios"].items(),
                        key=lambda kv: kv[1]["drop_rate"])
            print(f"         fleet_scenarios: {len(rec['fleet_scenarios'])} "
                  f"simulated; worst drop_rate={worst[1]['drop_rate']:.3f} "
                  f"({worst[0]})")
    return rec


def save_record(rec: dict, out_dir: str = None):
    out_dir = out_dir or os.path.abspath(ARTIFACTS)
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
    if rec.get("variant"):
        fname += f"__{rec['variant']}"
    path = os.path.join(out_dir, fname + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return path


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) combination")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 = 512-chip mesh")
    from repro.core.protocols import registered_protocols
    ap.add_argument("--protocol", default="stc",
                    choices=registered_protocols())
    ap.add_argument("--variant", default="",
                    help="tag appended to the artifact filename (perf iters)")
    ap.add_argument("--logit-chunk", type=int, default=0,
                    help="chunked LM head size (§Perf lever; 0 = baseline)")
    ap.add_argument("--stc-iters", type=int, default=32,
                    help="k-selection bisection rounds (§Perf lever)")
    ap.add_argument("--flash-bf16", action="store_true",
                    help="bf16 probability blocks in flash attention "
                         "(§Perf lever A4)")
    ap.add_argument("--moe-dispatch", default="",
                    choices=("", "ragged", "capacity"),
                    help="MoE dispatch impl (§Perf lever)")
    ap.add_argument("--cache-shard", default="heads",
                    choices=("heads", "hd", "batch", "local", "seq"),
                    help="decode-cache sharding (§Perf lever; batch = pin "
                         "caches batch-only inside the step)")
    args = ap.parse_args()

    tc = TrainConfig(protocol=args.protocol, stc_iters=args.stc_iters)
    combos = []
    if args.all:
        combos = [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("need --arch and --shape (or --all)")
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        try:
            rec = lower_combo(arch, shape, multi_pod=args.multi_pod, tc=tc,
                              logit_chunk=args.logit_chunk,
                              cache_shard=args.cache_shard,
                              moe_dispatch=args.moe_dispatch,
                              flash_bf16=args.flash_bf16)
            if args.variant:
                rec["variant"] = args.variant
            save_record(rec)
        except Exception as e:  # noqa: BLE001 -- report and continue
            failures.append((arch, shape, repr(e)[:500]))
            print(f"[dryrun] FAIL {arch} x {shape}: {repr(e)[:300]}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for a, s, e in failures:
            print(f"  {a} x {s}: {e}")
        raise SystemExit(1)
    print(f"\nall {len(combos)} combinations lowered + compiled OK "
          f"on mesh {_mesh_tag(args.multi_pod)}")


if __name__ == "__main__":
    main()
