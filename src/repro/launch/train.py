"""Distributed federated train_step for the production mesh.

Mapping of the paper's protocol onto the pod (DESIGN.md §2):

* manual mesh axes ("pod", "data") carry the CLIENTS -- one client cohort per
  data-parallel block, via ``jax.shard_map`` (auto axis "model" = tensor
  parallelism inside a client, handled by GSPMD);
* each client computes grads on its own batch shard ONLY (no gradient psum --
  that is the point of federated learning);
* upstream: the codec's ``tree_encode`` (per-client, with error feedback
  where the codec keeps one -- Eqs. 8-11);
* aggregation + downstream: the codec's ``tree_reduce`` collective over the
  client axes (the only protocol-level collective), then ``tree_decode`` with
  the server residual (Eqs. 10/12) -- computed identically on every block, so
  the broadcast is implicit;
* supported protocols: every codec registered in
  :mod:`repro.core.protocols` (stc / topk / signsgd / fedavg / baseline /
  ternquant / any third-party registration) -- there is no protocol dispatch
  in this module.

Momentum defaults OFF per the paper's lesson (6) (stale client momentum harms
non-iid + partial-participation training); pass momentum>0 to enable
per-client buffers.

Run as a script for a CPU demo on a debug mesh:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.train --arch smollm-135m
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.protocols import Codec, get_protocol_class
from repro.models import init_model, lm_loss
from repro.models.config import ModelConfig
from repro.sharding.rules import batch_spec, fit_spec, param_specs

__all__ = ["TrainConfig", "WireLedger", "codec_for", "init_train_state",
           "make_train_step", "state_shardings", "batch_shardings"]


class WireLedger:
    """Host-side measured-bits accounting for the mesh trainer.

    Feed it the ``(msgs_tree, global_delta_tree)`` extra output of a
    ``measure_wire=True`` train step; it serializes every client's message
    and the downstream update through the codec's wire format
    (:mod:`repro.core.wire`) and accumulates EXACT bits, alongside the
    analytic Eq. 1 model as a cross-check.  Codecs without a wire format
    fall back to analytic in both columns.
    """

    def __init__(self, codec: Codec, numel: int):
        self.codec, self.numel = codec, numel
        self.rounds = 0
        self.bits_up = self.bits_down = 0.0
        self.bits_up_analytic = self.bits_down_analytic = 0.0

    def record_round(self, msgs_tree, global_delta_tree, mask=None) -> None:
        """Account one round.  ``mask`` (per-client 0/1, masked/async mode)
        keeps the ledger honest under dropped shards: only messages that
        actually reached the server count as upstream bits."""
        import numpy as np
        leaves = [np.asarray(leaf) for leaf in jax.tree.leaves(msgs_tree)]
        n_clients = leaves[0].shape[0]
        msgs = np.concatenate(
            [leaf.reshape(n_clients, -1).astype(np.float32)
             for leaf in leaves], axis=1)
        if mask is not None:
            keep = np.asarray(mask, dtype=bool).reshape(-1)
            msgs = msgs[keep]
            n_clients = int(keep.sum())
        gd = np.concatenate(
            [np.asarray(leaf).reshape(-1).astype(np.float32)
             for leaf in jax.tree.leaves(global_delta_tree)])
        if n_clients:
            self.bits_up += self.codec.measured_upload_bits(msgs)
        self.bits_down += self.codec.measured_download_bits(
            gd, n_participating=max(n_clients, 1))
        self.bits_up_analytic += n_clients * self.codec.upload_bits(self.numel)
        self.bits_down_analytic += self.codec.download_bits(
            self.numel, n_participating=max(n_clients, 1))
        self.rounds += 1

    def summary(self) -> dict:
        return {"rounds": self.rounds, "bits_up": self.bits_up,
                "bits_down": self.bits_down,
                "bits_up_analytic": self.bits_up_analytic,
                "bits_down_analytic": self.bits_down_analytic}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    protocol: str = "stc"           # any codec registered in core.protocols
    lr: float = 0.1
    momentum: float = 0.0           # paper lesson (6): keep 0 in fed settings
    sparsity_up: float = 1 / 400
    sparsity_down: float = 1 / 400
    sign_step: float = 2e-4
    local_iters: int = 1            # fedavg delay period n
    compute_dtype: Any = jnp.bfloat16
    stc_iters: int = 32             # k-selection bisection rounds (§Perf lever)
    chunks: int | None = None       # chunked (leaf, chunk) selection: each
                                    # leaf splits into ceil(size/chunks)
                                    # blocks with independent k-selection/µ,
                                    # all through the STC backend registry --
                                    # no global collective, so the selection
                                    # sweeps shard + pipeline across the mesh
    p_fn: Any = None                # per-layer sparsity schedule hook:
                                    # p_fn(layer_name, depth) -> p | None
    controller: Any = None          # adaptive per-chunk sparsity controller
                                    # (repro.core.adaptive name or instance)
                                    # for the chunked tree path
    measure_wire: bool = False      # also return (msgs, global_delta) trees
                                    # so a host WireLedger can account the
                                    # REAL serialized bits per round
    rule: Any = None                # server AggregationRule (name or
                                    # instance, core.aggregation); None =
                                    # the codec default ("mean")
    masked: bool = False            # async mode: train_step takes per-client
                                    # (mask, staleness) vectors; a masked-out
                                    # client's message gets zero weight in the
                                    # tree_reduce collective and its residual/
                                    # momentum stay frozen -- a dropped shard
                                    # no longer stalls (or skews) the step


def codec_for(tc: TrainConfig) -> Codec:
    """Instantiate the registered codec named by ``tc.protocol``, forwarding
    exactly the TrainConfig hyperparameters the codec declares as fields."""
    cls = get_protocol_class(tc.protocol)
    fields = {f.name for f in dataclasses.fields(cls)}
    kw = dict(sparsity_up=tc.sparsity_up, sparsity_down=tc.sparsity_down,
              sign_step=tc.sign_step, local_iters=tc.local_iters,
              chunk_size=tc.chunks, p_fn=tc.p_fn, controller=tc.controller)
    kw = {k: v for k, v in kw.items() if k in fields}
    if tc.rule is not None:
        kw["rule"] = tc.rule
    return cls(**kw)


def init_train_state(cfg: ModelConfig, tc: TrainConfig, n_clients: int, key):
    """TrainState pytree. Residuals/momentum are fp32, client-major."""
    codec = codec_for(tc)
    params = init_model(cfg, key)
    state = {"params": params, "step": jnp.zeros((), jnp.int32)}
    f32_like = lambda p: jnp.zeros(p.shape, jnp.float32)
    stacked = lambda p: jnp.zeros((n_clients,) + p.shape, jnp.float32)
    if codec.has_client_state():
        state["client_res"] = jax.tree.map(stacked, params)
    if codec.has_server_state():
        state["server_res"] = jax.tree.map(f32_like, params)
    if tc.momentum > 0:
        state["momentum"] = jax.tree.map(stacked, params)
    return state


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------


def _client_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def state_shardings(state, mesh):
    """NamedShardings for the TrainState: params/server_res model-sharded,
    client-major buffers additionally split over the client axes."""
    ca = _client_axes(mesh)
    pspecs = param_specs(state["params"])

    def stack_spec(s: P) -> P:
        return P(ca, *s)

    def shard(leaf, s):
        return NamedSharding(mesh, fit_spec(s, leaf.shape, mesh))

    def shard_stacked(leaf, s):
        return NamedSharding(mesh, fit_spec(stack_spec(s), leaf.shape, mesh))

    sh = {
        "params": jax.tree.map(shard, state["params"], pspecs),
        "step": NamedSharding(mesh, P()),
    }
    if "client_res" in state:
        sh["client_res"] = jax.tree.map(shard_stacked, state["client_res"],
                                        pspecs)
    if "server_res" in state:
        sh["server_res"] = jax.tree.map(shard, state["server_res"], pspecs)
    if "momentum" in state:
        sh["momentum"] = jax.tree.map(shard_stacked, state["momentum"],
                                      pspecs)
    return sh


def batch_shardings(batch, mesh, global_batch: int):
    bs = batch_spec(mesh, global_batch)
    return jax.tree.map(lambda _: NamedSharding(mesh, bs), batch)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, mesh, tc: TrainConfig):
    """Returns ``train_step(state, batch) -> (state, metrics)`` wrapped in
    shard_map over the client axes (auto axis: "model")."""
    ca = _client_axes(mesh)
    n_clients = math.prod(mesh.shape[a] for a in ca) if ca else 1
    numel = cfg.param_count()
    codec = codec_for(tc)

    def loss_of(params, batch):
        return lm_loss(params, cfg, batch["tokens"], batch["labels"],
                       prefix=batch.get("prefix"), frames=batch.get("frames"),
                       compute_dtype=tc.compute_dtype)

    def local_delta(params, mom, batch):
        """One client's update ΔW (and new momentum). A codec with a
        communication-delay period runs ``local_iters`` sequential SGD steps
        over microbatches."""
        if codec.local_iters > 1:
            n = tc.local_iters
            b_local = batch["tokens"].shape[0]
            assert b_local % n == 0, (b_local, n)
            micro = {k: v.reshape((n, b_local // n) + v.shape[1:])
                     for k, v in batch.items()}

            def step(carry, mb):
                p, v = carry
                loss, g = jax.value_and_grad(loss_of)(p, mb)
                if tc.momentum > 0:
                    v = jax.tree.map(
                        lambda vv, gg: tc.momentum * vv +
                        gg.astype(jnp.float32), v, g)
                    upd = v
                else:
                    upd = g
                p = jax.tree.map(
                    lambda pp, uu: (pp.astype(jnp.float32) -
                                    tc.lr * uu.astype(jnp.float32)
                                    ).astype(pp.dtype), p, upd)
                return (p, v), loss

            (p_end, mom), losses = jax.lax.scan(step, (params, mom), micro)
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                p_end, params)
            return delta, mom, jnp.mean(losses)

        loss, g = jax.value_and_grad(loss_of)(params, batch)
        if tc.momentum > 0:
            mom = jax.tree.map(
                lambda vv, gg: tc.momentum * vv + gg.astype(jnp.float32),
                mom, g)
            upd = mom
        else:
            upd = g
        delta = jax.tree.map(lambda u: -tc.lr * u.astype(jnp.float32), upd)
        return delta, mom, loss

    def step_fn(state, batch, mask=None, staleness=None):
        params = state["params"]
        mom = None
        if "momentum" in state:
            mom = jax.tree.map(lambda x: x[0], state["momentum"])

        delta, mom, loss = local_delta(params, mom, batch)
        metrics = {"loss": jax.lax.pmean(loss, ca) if ca else loss}
        new_state = dict(state)
        new_state["step"] = state["step"] + 1
        # a masked-out (dropped) client's local state must not advance: its
        # message never reached the server, so momentum/residual stay frozen
        # until it participates again (mirrors the buffered fed trainer)
        arrived = None if mask is None else jnp.sum(mask) > 0
        if mom is not None:
            if arrived is not None:
                mom = jax.tree.map(
                    lambda new, old: jnp.where(arrived, new, old[0]),
                    mom, state["momentum"])
            new_state["momentum"] = jax.tree.map(lambda x: x[None], mom)

        # ---- the entire protocol: three codec calls, zero dispatch ---------
        cres = (jax.tree.map(lambda x: x[0], state["client_res"])
                if "client_res" in state else None)
        msg, new_cres, m_up = codec.tree_encode(delta, cres, numel=numel,
                                                iters=tc.stc_iters)
        if "client_res" in state:
            if arrived is not None:
                new_cres = jax.tree.map(
                    lambda new, old: jnp.where(arrived, new, old[0]),
                    new_cres, state["client_res"])
            new_state["client_res"] = jax.tree.map(lambda x: x[None], new_cres)
        # ---- upload: the ONLY protocol-level collective --------------------
        combined = codec.tree_reduce(msg, ca, n_clients, mask=mask,
                                     staleness=staleness)
        global_delta, new_sres, m_down = codec.tree_decode(
            combined, state.get("server_res"), numel=numel, iters=tc.stc_iters)
        if mask is not None:
            # zero-arrival step: the server must not move either -- without
            # this gate a stateful codec (stc) would still drain its server
            # residual into a parameter update off the all-zero combined tree
            total = jnp.sum(mask)
            if ca:
                total = jax.lax.psum(total, ca)
            any_arrived = total > 0
            global_delta = jax.tree.map(
                lambda d: jnp.where(any_arrived, d, 0.0), global_delta)
            if new_sres is not None:
                new_sres = jax.tree.map(
                    lambda new, old: jnp.where(any_arrived, new, old),
                    new_sres, state.get("server_res"))
        if "server_res" in state:
            new_state["server_res"] = new_sres
        metrics.update(m_up)
        metrics.update(m_down)

        new_state["params"] = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) +
                          d.astype(jnp.float32)).astype(p.dtype),
            params, global_delta)
        if tc.measure_wire:
            # per-client message (leading client axis) + the replicated
            # downstream update, for host-side WireLedger accounting
            wire_out = (jax.tree.map(lambda x: x[None], msg), global_delta)
            return new_state, metrics, wire_out
        return new_state, metrics

    if not ca:
        def single(state, batch, mask=None, staleness=None):
            if not tc.masked and (mask is not None or staleness is not None):
                raise ValueError(
                    "train_step got mask/staleness but TrainConfig.masked is "
                    "False; rebuild the step with TrainConfig(masked=True)")
            return step_fn(state, batch, mask, staleness)
        return single

    state_specs_in = {
        "params": P(), "step": P(),
    }
    out_specs_state = {"params": P(), "step": P()}
    if codec.has_client_state():
        state_specs_in["client_res"] = P(ca)
        out_specs_state["client_res"] = P(ca)
    if codec.has_server_state():
        state_specs_in["server_res"] = P()
        out_specs_state["server_res"] = P()
    # momentum specs are added dynamically at call time (same prefix trick)

    def wrapped(state, batch, mask=None, staleness=None):
        if not tc.masked and (mask is not None or staleness is not None):
            raise ValueError(
                "train_step got mask/staleness but TrainConfig.masked is "
                "False; rebuild the step with TrainConfig(masked=True)")
        specs_in = dict(state_specs_in)
        specs_out = dict(out_specs_state)
        if "momentum" in state:
            specs_in["momentum"] = P(ca)
            specs_out["momentum"] = P(ca)
        outs = ((specs_out, P(), (P(ca), P())) if tc.measure_wire
                else (specs_out, P()))
        # masked/async mode: the per-client participation mask + staleness
        # vectors ride in split over the client axes, one slice per shard
        in_specs = ((specs_in, P(ca), P(ca), P(ca)) if tc.masked
                    else (specs_in, P(ca)))
        args = (state, batch, mask, staleness) if tc.masked \
            else (state, batch)
        # NOTE: partial-manual shard_map must run through jit (the eager impl
        # path mishandles check_vma=False with auto axes in jax 0.8).
        if hasattr(jax, "shard_map"):
            f = jax.shard_map(step_fn, mesh=mesh, in_specs=in_specs,
                              out_specs=outs,
                              axis_names=set(ca), check_vma=False)
        else:  # jax <= 0.4.x spelling: manual axes via the auto-complement
            from jax.experimental.shard_map import shard_map
            auto = frozenset(mesh.axis_names) - set(ca)
            f = shard_map(step_fn, mesh=mesh, in_specs=in_specs,
                          out_specs=outs, check_rep=False,
                          auto=auto)
        return f(*args)

    return jax.jit(wrapped)


# ---------------------------------------------------------------------------
# CPU demo driver
# ---------------------------------------------------------------------------


def main():
    import argparse
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.data import make_lm_tokens
    from repro.launch.mesh import make_debug_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--protocol", default="stc")
    ap.add_argument("--measure-wire", action="store_true",
                    help="serialize every message through the real wire "
                         "format and print measured vs analytic bits")
    ap.add_argument("--chunks", type=int, default=None,
                    help="chunked per-(leaf, chunk) selection block size "
                         "(default: one global flat selection)")
    args = ap.parse_args()

    if len(jax.devices()) < 4:
        raise SystemExit("run with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=8 for the debug mesh")
    mesh = make_debug_mesh(data=2, model=2)
    cfg = get_smoke_config(args.arch)
    tc = TrainConfig(protocol=args.protocol, lr=0.05, sparsity_up=1 / 50,
                     sparsity_down=1 / 50, measure_wire=args.measure_wire,
                     chunks=args.chunks)
    state = init_train_state(cfg, tc, n_clients=2, key=jax.random.PRNGKey(0))

    toks = make_lm_tokens(n_tokens=4 * 128 + 1, vocab=cfg.vocab_size)
    batch = {"tokens": jnp.asarray(toks[:-1].reshape(4, 128)),
             "labels": jnp.asarray(toks[1:].reshape(4, 128))}
    if cfg.encoder is not None:
        batch["frames"] = jnp.zeros((4, cfg.encoder.n_frames, cfg.d_model),
                                    jnp.float32)
    if cfg.n_prefix_tokens:
        batch["prefix"] = jnp.zeros((4, cfg.n_prefix_tokens, cfg.d_model),
                                    jnp.float32)

    ledger = WireLedger(codec_for(tc), cfg.param_count())
    # jax >= 0.8 spells the ambient mesh jax.set_mesh; 0.4.x enters the Mesh
    set_mesh = getattr(jax, "set_mesh", None)
    with (set_mesh(mesh) if set_mesh is not None else mesh):
        step = make_train_step(cfg, mesh, tc)
        for i in range(args.steps):
            if tc.measure_wire:
                state, metrics, (msgs, gd) = step(state, batch)
                ledger.record_round(msgs, gd)
            else:
                state, metrics = step(state, batch)
            print(f"step {i}: loss={float(metrics['loss']):.4f}",
                  {k: int(v) for k, v in metrics.items() if k != "loss"})
    if tc.measure_wire:
        s = ledger.summary()
        print(f"wire ledger over {s['rounds']} rounds: "
              f"up {s['bits_up']/8e6:.3f} MB (analytic "
              f"{s['bits_up_analytic']/8e6:.3f}), down "
              f"{s['bits_down']/8e6:.3f} MB (analytic "
              f"{s['bits_down_analytic']/8e6:.3f})")


if __name__ == "__main__":
    main()
