"""PartitionSpec rules: parameter trees, activation constraints, KV caches.

Model axis ("model") carries tensor parallelism: attention heads, FFN hidden,
expert hidden, vocab.  Client/batch axes ("pod", "data") carry the federated
clients (train) or the request batch (serve).  GSPMD pads non-divisible dims
(e.g. phi3's 40 heads on a 16-way model axis), so the rules below never need
divisibility.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.attention import KVCache
from repro.models.mla import MLACache
from repro.models.rglru import RGLRUCache
from repro.models.ssm import SSMCache

__all__ = ["param_specs", "param_shardings", "cache_specs", "batch_spec",
           "tree_shardings"]

# §Perf lever: how decode caches shard over the "model" axis.
#   "heads" (baseline): shard the KV-head dim -- GSPMD pads non-divisible
#                       head counts (e.g. qwen2's kv=2 -> 16), wasting HBM.
#   "hd":               shard head_dim (always 64/128/256 -> divides 16).
CACHE_SHARD_MODE = "heads"


def _leaf_spec(path: tuple, leaf) -> P:
    """PartitionSpec for one parameter leaf, keyed on its tree path."""
    keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = keys[-1] if keys else None
    ndim = leaf.ndim

    # --- embeddings / head: vocab sharded ---------------------------------
    if name in ("embed", "lm_head"):
        return P("model", None)
    if name == "prefix_proj":
        return P(None, "model")

    # --- MoE stacked experts ----------------------------------------------
    if name in ("w_gate", "w_up") and ndim == 3:   # (E, d, f)
        return P(None, None, "model")
    if name == "w_down" and ndim == 3:             # (E, f, d)
        return P(None, "model", None)
    if name == "router":
        return P(None, None)

    # --- attention / MLA ----------------------------------------------------
    if name in ("wq", "wk", "wv", "w_uk", "w_uv"):
        return P(None, "model")
    if name == "wo":
        return P("model", None)
    if name in ("bq", "bk", "bv"):
        return P("model")
    if name in ("w_dkv", "w_kr"):                  # small latent projections
        return P(None, None)

    # --- dense MLP ----------------------------------------------------------
    if name in ("w_gate", "w_up"):                 # (d, f)
        return P(None, "model")
    if name == "w_down":                           # (f, d)
        return P("model", None)

    # --- SSD / RG-LRU --------------------------------------------------------
    if name == "w_in":                             # (d, d_proj)
        return P(None, "model")
    if name == "w_out":                            # (d_in, d)
        return P("model", None)
    if name == "conv_w":                           # (k, channels)
        return P(None, "model")
    if name in ("w_a", "w_x"):                     # (w, w) RG-LRU gates
        return P(None, "model")
    if name in ("A_log", "D", "dt_bias", "lam", "norm_g"):
        return P(None)

    # --- norms, biases, scalars: replicated ----------------------------------
    return P(*([None] * ndim))


def fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharded axes that do not divide the dimension size.

    jax 0.8 rejects input shardings whose tiling does not evenly divide the
    array (e.g. whisper's 51865 vocab over a 16-way model axis, or a 2-KV-head
    cache).  A production system would pad such dims; here the rule falls back
    to replication for that dim (recorded in DESIGN.md §Changed assumptions).
    """
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        factor = 1
        for n in names:
            factor *= mesh.shape[n]
        out.append(entry if shape[i] % factor == 0 else None)
    return P(*out)


def param_specs(params) -> Any:
    """Tree of PartitionSpecs matching the parameter tree."""
    return jax.tree_util.tree_map_with_path(_leaf_spec, params)


def param_shardings(params, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda p, s: NamedSharding(mesh, fit_spec(s, p.shape, mesh)),
        params, param_specs(params))


def batch_spec(mesh: Mesh, global_batch: int) -> P:
    """Batch dim over the client axes; falls back to fewer axes when the
    batch is too small to shard (long_500k has batch 1)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    size = 1
    used = []
    for a in axes:
        size *= mesh.shape[a]
        used.append(a)
    if global_batch % size == 0:
        return P(tuple(used))
    if "data" in mesh.axis_names and global_batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


def _cache_leaf_spec(cache, field: str, dp) -> P:
    if isinstance(cache, KVCache):
        if CACHE_SHARD_MODE == "hd":
            return {"k": P(dp, None, None, "model"),
                    "v": P(dp, None, None, "model")}.get(field, P())
        return {"k": P(dp, None, "model", None),
                "v": P(dp, None, "model", None)}.get(field, P())
    if isinstance(cache, MLACache):
        return {"c_kv": P(dp, None, None),
                "k_rope": P(dp, None, None)}.get(field, P())
    if isinstance(cache, SSMCache):
        return {"state": P(dp, "model", None, None),
                "conv": P(dp, None, "model")}.get(field, P())
    if isinstance(cache, RGLRUCache):
        return {"h": P(dp, "model"),
                "conv": P(dp, None, "model")}.get(field, P())
    raise TypeError(type(cache))


def cache_specs(caches: list, mesh: Mesh, global_batch: int) -> list:
    """Per-layer cache PartitionSpec trees (same structure as the caches)."""
    dp = batch_spec(mesh, global_batch)
    dp_name = None
    if len(dp) and dp[0] is not None:
        dp_name = dp[0]
    out = []
    for c in caches:
        fields = c._fields
        out.append(type(c)(*[
            _cache_leaf_spec(c, f, dp_name) if getattr(c, f) is not None
            and hasattr(getattr(c, f), "ndim") and getattr(c, f).ndim > 0
            else P()
            for f in fields
        ]))
    return out


def tree_shardings(tree_of_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))
