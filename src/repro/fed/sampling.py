"""Pluggable client samplers: WHO participates, orthogonal to the codec.

Sampling which clients join a round matters as much as compressing what
they send (Grudzień et al. 2023): a fleet server re-dispatches clients as
buffer slots free up, and the policy it uses shapes both convergence and
fairness under non-IID splits.  This module is the sampler registry --
mirroring ``repro.core.protocols.register_protocol`` -- that the
event-driven trainer (:mod:`repro.fed.events`) consults every time it
refills its in-flight pool.

A sampler sees a :class:`SamplerView` (the server's per-client bookkeeping:
current round, last participation round, in-flight flags) plus the
trainer's own ``numpy`` Generator, and returns a duplicate-free cohort.
``UniformSampler`` draws ``rng.choice(n, size, replace=False)`` -- exactly
the synchronous trainer's selection, which is what keeps the event trainer's
K = cohort configuration bit-identical to :class:`FederatedTrainer`.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np

from repro.core import registry as _registry

__all__ = ["SamplerView", "ClientSampler", "UniformSampler",
           "StalenessAwareSampler", "register_sampler", "make_sampler",
           "registered_samplers"]


class SamplerView(NamedTuple):
    """What the server knows per client when it picks the next cohort."""

    round: int              # current aggregation round
    last_seen: np.ndarray   # (n_clients,) round of last dispatch
    inflight: np.ndarray    # (n_clients,) bool: an update is in the air
    # (n_clients,) bool: client has been dispatched at least once.  A
    # zero in ``last_seen`` is ambiguous -- "sampled at round 0" and
    # "never sampled" collide -- so age-aware samplers need this to give
    # never-seen clients maximal weight.  None (legacy callers) falls
    # back to the ambiguous reading.
    seen: Optional[np.ndarray] = None


_REGISTRY: dict[str, type["ClientSampler"]] = {}


def register_sampler(cls=None, *, name: Optional[str] = None):
    """Class decorator adding a sampler to the registry under ``cls.name``."""
    def _register(c):
        key = name or getattr(c, "name", None)
        if not key:
            raise ValueError(f"sampler {c.__name__} needs a `name`")
        _REGISTRY[key] = c
        return c
    return _register(cls) if cls is not None else _register


def registered_samplers() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_sampler(sampler, **overrides) -> "ClientSampler":
    """Instantiate a registered sampler by name (loud on unknown names),
    or pass a :class:`ClientSampler` instance through untouched."""
    return _registry.resolve("client sampler", sampler, _REGISTRY,
                             ClientSampler, **overrides)


@dataclasses.dataclass(frozen=True)
class ClientSampler:
    """Base sampler; subclasses override :meth:`select`."""

    name = "base"

    def select(self, rng: np.random.Generator, view: SamplerView,
               cohort: int) -> np.ndarray:
        """A duplicate-free (cohort,) int64 array of client ids."""
        raise NotImplementedError(type(self).__name__)


@register_sampler
@dataclasses.dataclass(frozen=True)
class UniformSampler(ClientSampler):
    """Uniform without replacement -- the synchronous trainer's draw,
    byte-for-byte (same ``rng.choice`` call on the same generator)."""

    name = "uniform"

    def select(self, rng, view, cohort):
        return rng.choice(view.last_seen.size, size=cohort, replace=False)


@register_sampler
@dataclasses.dataclass(frozen=True)
class StalenessAwareSampler(ClientSampler):
    """Prefer clients the server has not heard from recently.

    Selection weight of client ``i`` is ``(1 + round - last_seen_i)^bias``,
    zeroed while an update of theirs is still in flight (no duplicate
    in-flight work) -- unless that would starve the cohort, in which case
    in-flight clients are readmitted at the minimum weight.

    Never-yet-seen clients (``view.seen`` False) get ``age = round + 1`` --
    strictly older than any client sampled at round 0 -- so no client can
    starve behind a zero-initialized ``last_seen``: at bias > 0 an unseen
    client always carries the maximal weight until its first dispatch.
    """

    name = "staleness"
    bias: float = 1.0

    def __post_init__(self):
        if self.bias < 0.0:
            raise ValueError(
                f"StalenessAwareSampler.bias must be >= 0, got {self.bias}")

    def select(self, rng, view, cohort):
        n = view.last_seen.size
        age = (view.round - view.last_seen).astype(np.float64)
        if view.seen is not None:
            age = np.where(np.asarray(view.seen, bool), age,
                           float(view.round) + 1.0)
        w = (1.0 + np.maximum(age, 0.0)) ** self.bias
        free = ~np.asarray(view.inflight, bool)
        if int(free.sum()) >= cohort:
            w = np.where(free, w, 0.0)
        return rng.choice(n, size=cohort, replace=False, p=w / w.sum())
