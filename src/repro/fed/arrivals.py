"""Client arrival simulation for deadline-based buffered aggregation.

The synchronous trainer assumes every sampled client reports before the
server moves -- exactly the assumption that breaks in the paper's favored
regime (many clients, low participation, §V).  This module simulates the
missing piece: per-client network/compute latency, a server round deadline,
and the buffer that carries late updates into later rounds.

Time model: latencies are abstract time units; the server closes its
aggregation window every ``deadline`` units.  An update dispatched in round
``t`` with sampled latency ``L`` arrives ``floor(L / deadline)`` rounds
later, i.e. staleness ``s = floor(L / deadline)`` (0 = on time).  With
``deadline = inf`` every update is on time and the buffered trainer
reproduces the synchronous one bit for bit.

:class:`LatencyModel` is a lognormal latency distribution with optional
per-client heterogeneity (persistent fast/slow clients) and a chronic
straggler population; :class:`ArrivalSimulator` owns the in-flight buffer.
Payloads are opaque to the simulator -- the trainer hands it already-encoded
client messages and gets them back, tagged with their dispatch round, when
they "reach" the server.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, NamedTuple

import numpy as np

__all__ = ["Arrival", "LatencyModel", "ArrivalSimulator"]


class Arrival(NamedTuple):
    """One client update reaching the server."""

    client: int
    sent_round: int     # round the client was dispatched (staleness = now - this)
    payload: object     # the encoded message (opaque to the simulator)


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Per-client round-trip latency distribution.

    Latency of client ``i`` is ``scale_i * LogNormal(log(mean), sigma)``
    where ``scale_i`` is a persistent per-client multiplier:
    ``exp(hetero * N(0,1))``, further multiplied by ``straggler_scale`` for a
    ``straggler_frac`` fraction of chronically slow clients.  All defaults
    give a homogeneous fleet that is on time for any ``deadline >= ~1``.
    """

    mean: float = 0.5               # median latency, in deadline time units
    sigma: float = 0.25             # lognormal shape of the per-draw noise
    hetero: float = 0.0             # persistent per-client speed spread
    straggler_frac: float = 0.0     # fraction of chronically slow clients
    straggler_scale: float = 8.0    # their latency multiplier

    def __post_init__(self):
        # typed, field-named errors instead of a math-domain error deep in
        # ``sample`` (log(mean)) or silently nonsensical populations
        if not self.mean > 0.0:
            raise ValueError(f"LatencyModel.mean must be > 0, got {self.mean}")
        if self.sigma < 0.0:
            raise ValueError(
                f"LatencyModel.sigma must be >= 0, got {self.sigma}")
        if self.hetero < 0.0:
            raise ValueError(
                f"LatencyModel.hetero must be >= 0, got {self.hetero}")
        if not 0.0 <= self.straggler_frac <= 1.0:
            raise ValueError("LatencyModel.straggler_frac must be in [0, 1], "
                             f"got {self.straggler_frac}")
        if not self.straggler_scale > 0.0:
            raise ValueError("LatencyModel.straggler_scale must be > 0, "
                             f"got {self.straggler_scale}")

    def client_scales(self, n_clients: int, seed: int = 0) -> np.ndarray:
        """Deterministic persistent per-client latency multipliers."""
        rng = np.random.default_rng(seed)
        scales = np.exp(self.hetero * rng.standard_normal(n_clients))
        if self.straggler_frac > 0.0:
            slow = rng.random(n_clients) < self.straggler_frac
            scales = np.where(slow, scales * self.straggler_scale, scales)
        return scales.astype(np.float64)

    def sample(self, client_ids, scales: np.ndarray,
               rng: np.random.Generator) -> np.ndarray:
        """Latency draws for one dispatched cohort."""
        ids = np.asarray(client_ids, dtype=np.int64)
        noise = rng.lognormal(mean=math.log(self.mean), sigma=self.sigma,
                              size=ids.size)
        return noise * scales[ids]


class ArrivalSimulator:
    """Deadline-bucketed in-flight buffer between clients and the server.

    ``dispatch(round, client_ids, payloads)`` samples each client's latency
    and files its payload under the round in which it will arrive;
    ``collect(round)`` drains everything that has arrived by that round's
    deadline (including updates dispatched the same round, when fast enough).
    Arrivals come back oldest dispatch first, then in dispatch order, so the
    drain is deterministic given the seed.
    """

    def __init__(self, latency: LatencyModel, n_clients: int,
                 deadline: float = math.inf, seed: int = 0) -> None:
        if not deadline > 0.0:
            raise ValueError(f"deadline must be positive, got {deadline}")
        self.latency = latency
        self.deadline = float(deadline)
        self.rng = np.random.default_rng(seed)
        self.scales = latency.client_scales(n_clients, seed=seed + 1)
        self._pending: Dict[int, List[Arrival]] = {}

    def rounds_late(self, latencies: np.ndarray) -> np.ndarray:
        """How many deadlines elapse before each update lands (its staleness).

        ``floor(L / deadline)`` with the quotient snapped to the nearest
        integer when it is within one part in 10^9: a latency that is an
        EXACT multiple of the deadline always buckets as ``L/deadline``
        rounds late, whatever rounding the platform's division produced
        (e.g. ``0.3 / 0.1 == 2.999...96`` must not bucket one round early).
        """
        lat = np.asarray(latencies, dtype=np.float64)
        if math.isinf(self.deadline):
            return np.zeros(lat.shape, dtype=np.int64)
        q = lat / self.deadline
        nearest = np.rint(q)
        q = np.where(np.isclose(q, nearest, rtol=1e-9, atol=1e-12), nearest, q)
        return np.floor(q).astype(np.int64)

    def dispatch(self, rnd: int, client_ids, payloads) -> np.ndarray:
        """File one cohort's payloads; returns the sampled latencies."""
        ids = np.asarray(client_ids, dtype=np.int64)
        if len(payloads) != ids.size:
            raise ValueError(f"{ids.size} clients but {len(payloads)} payloads")
        lats = self.latency.sample(ids, self.scales, self.rng)
        self.dispatch_with_latencies(rnd, ids, payloads, lats)
        return lats

    def dispatch_with_latencies(self, rnd: int, client_ids, payloads,
                                latencies) -> None:
        """File one cohort's payloads under externally sampled latencies.

        This is the hook the scenario library drives: a
        :class:`repro.fed.scenarios.Scenario` samples time-varying latencies
        (and loss masks) itself and files only the surviving payloads here,
        reusing the simulator's deadline bucketing and buffer.
        """
        ids = np.asarray(client_ids, dtype=np.int64)
        lats = np.asarray(latencies, dtype=np.float64)
        if not (len(payloads) == ids.size == lats.size):
            raise ValueError(f"{ids.size} clients but {len(payloads)} "
                             f"payloads / {lats.size} latencies")
        late = self.rounds_late(lats)
        for cid, extra, payload in zip(ids, late, payloads):
            self._pending.setdefault(rnd + int(extra), []).append(
                Arrival(int(cid), rnd, payload))

    def collect(self, rnd: int) -> List[Arrival]:
        """Drain every update that arrived by round ``rnd``'s deadline."""
        due = sorted(r for r in self._pending if r <= rnd)
        out: List[Arrival] = []
        for r in due:
            out.extend(self._pending.pop(r))
        out.sort(key=lambda a: a.sent_round)   # oldest first; stable in dispatch order
        return out

    def pending_count(self) -> int:
        """Updates still in flight (the buffer the next rounds will drain)."""
        return sum(len(v) for v in self._pending.values())
