"""Federated training loops -- Algorithm 2 of the paper, end to end.

Two trainers share one round machinery, split into two jitted phases so the
synchronous and buffered/async modes are the *same compiled computation*:

* ``encode`` -- local SGD on every dispatched client + upstream compression
  with error feedback (one vmapped jit);
* ``apply`` -- server aggregation (the codec's masked, staleness-weighted
  ``aggregate``), downstream compression and the global parameter update.

:class:`FederatedTrainer` runs them back to back with an all-ones mask --
every sampled client reports before the server moves.
:class:`BufferedFederatedTrainer` puts the :mod:`repro.fed.arrivals`
simulator between them: clients encode against the model at dispatch time,
the server aggregates whatever landed by the round deadline (on-time updates
plus buffered stragglers, staleness-weighted), and messages staler than the
buffer horizon are dropped.  With ``deadline=inf`` the buffered trainer
reproduces the synchronous one bit for bit (same jitted phases, same
inputs) -- regression-tested in tests/test_async.py.

Partial participation, the server-side update cache (Sec. V-B) and the bit
ledger live in the host driver.  When the codec has a wire format the ledger
is MEASURED -- every message is serialized through :mod:`repro.core.wire`
when it reaches the server and the exact stream lengths accumulated -- with
the analytic Eq. 1 model kept in the ``*_analytic`` columns as a
cross-check.

The trainers are protocol-agnostic: they talk to the codec ONLY through the
:class:`repro.core.protocols.Codec` interface (``init_*_state`` /
``encode_batch`` / ``aggregate`` / ``upload_bits`` / ``download_bits``), so
any codec registered via ``register_protocol`` runs here unchanged.  A codec
whose ``aggregate`` predates the mask/staleness kwargs still works in the
synchronous trainer; buffered aggregation requires the masked API.

``TrainerConfig(chunks=...)`` wraps the codec into per-``(layer, chunk)``
block states (:mod:`repro.core.chunking`): independent k-selection, µ,
residuals and wire sub-streams per chunk, with ``p_fn(layer_name, depth)``
as the per-layer sparsity schedule hook; ``chunks="whole"`` runs the
chunked machinery over one whole-vector chunk, bit-identical to the flat
path.

Works with any model from ``repro.models.paper_models`` (or any
(init_fn, apply_fn) pair with ``apply(params, x) -> logits``).
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.caching import UpdateCache
from repro.core.chunking import (chunk_codec, chunk_spec_from_tree,
                                 whole_vector_spec)
from repro.core.compression import flatten_pytree, unflatten_pytree
from repro.core.protocols import Codec
from repro.core.residual import scatter_states, stack_states, take_states
from repro.data.synthetic import Dataset
from repro.fed.arrivals import ArrivalSimulator, LatencyModel
from repro.fed.environment import FedEnvironment, split_data

__all__ = ["FederatedTrainer", "BufferedFederatedTrainer", "TrainerConfig",
           "build_encode_phase", "build_apply_phase"]


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    lr: float = 0.04
    momentum: float = 0.0
    seed: int = 0
    eval_batch: int = 512
    # Measure real wire bits whenever the codec has a wire format (the
    # analytic Eq. 1 ledger is always kept alongside as a cross-check);
    # False forces analytic-only accounting (no per-round host transfer).
    # Codecs without a wire format are always analytic.
    measure_bits: bool | None = None
    # Chunked (layer, chunk) codec states: an int chunk size splits every
    # layer of the model pytree into independent blocks (own k-selection,
    # Golomb µ, residuals and wire sub-stream per chunk); the string
    # "whole" runs the chunked machinery over ONE whole-vector chunk
    # (bit-identical to the flat path -- the regression point); None = the
    # plain flat codec.  ``p_fn(layer_name, depth) -> p | None`` is the
    # per-layer sparsity schedule hook (codecs without sparsity fields
    # ignore it).
    chunks: int | str | None = None
    p_fn: Optional[Callable] = None
    # Adaptive per-chunk sparsity controller (repro.core.adaptive): a
    # registered name ("fixed" / "residual_mass" / "snr_constant") or a
    # SparsityController instance; requires ``chunks``.  "fixed" (or None)
    # keeps the static schedule byte-identically.
    controller: object = None
    # Fused decode→aggregate server ingestion (repro.core.ingest): arriving
    # messages scatter straight into ONE O(numel) accumulator (wire codecs
    # through their decoded Golomb/sign-plane fields, others densely) and
    # the round finalizes from the accumulator -- the server never stacks
    # the dense (P, numel) message block.  Opt-in: the default dense path
    # keeps the buffered==synchronous bit-identity regression, while the
    # ingest path is property-tested against its own dense oracle.
    ingest: bool = False


def _cross_entropy(logits, y):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# The two shared jitted phases.  Every trainer in this repo -- synchronous,
# deadline-buffered, event-driven (repro.fed.events) -- is host machinery
# around these SAME two compiled computations, which is what makes the
# bit-identity regressions (buffered@deadline=inf == sync, event@K=cohort ==
# sync) statements about scheduling alone, not numerics.
# ---------------------------------------------------------------------------


def build_encode_phase(codec: Codec, apply_fn: Callable, spec,
                       lr: float, momentum: float):
    """Client phase: local SGD on the dispatched cohort + upstream
    compression, one vmapped jit.

    Returns a jitted ``(params_vec, mom_sel, cstate_sel, xs, ys) ->
    (msgs, new_mom, new_cstate)`` with ``xs: (P, iters, b, ...)``.
    """
    # momentum stays an fp32 pytree inside the scan (no per-step
    # flatten/unflatten round-trip); it is flattened once per round to
    # slot back into the stacked (n_clients, numel) state.
    treedef, shapes = spec
    spec_f32 = (treedef, [(shape, jnp.float32) for shape, _ in shapes])

    def local_update(params_vec, mom_vec, xs, ys):
        """One client: ``local_iters`` SGD steps. xs: (n, b, ...)."""
        params = unflatten_pytree(params_vec, spec)
        mom_tree = unflatten_pytree(mom_vec, spec_f32)

        def loss(p, x, y):
            return _cross_entropy(apply_fn(p, x), y)

        def step(carry, batch):
            p, v = carry
            x, y = batch
            g = jax.grad(loss)(p, x, y)
            v = jax.tree.map(
                lambda vi, gi: momentum * vi + gi.astype(jnp.float32), v, g)
            # update math in fp32, round once per step at the cast back
            p = jax.tree.map(
                lambda pi, vi: (pi.astype(jnp.float32) - lr * vi)
                .astype(pi.dtype), p, v)
            return (p, v), None

        (p_final, v_final), _ = jax.lax.scan(step, (params, mom_tree),
                                             (xs, ys))
        delta = flatten_pytree(p_final)[0] - params_vec
        return delta, flatten_pytree(v_final)[0]

    def encode_fn(params_vec, mom_sel, cstate_sel, xs, ys):
        deltas, new_mom = jax.vmap(
            lambda m, x, y: local_update(params_vec, m, x, y)
        )(mom_sel, xs, ys)
        msgs, new_cstate, _ = codec.encode_batch(deltas, cstate_sel)
        return msgs, new_mom, new_cstate

    return jax.jit(encode_fn)


def build_apply_phase(codec: Codec):
    """Server phase: masked staleness-weighted aggregation + downstream
    compression + the global parameter update, one jit.  (Every codec
    implements the masked signature -- the legacy 2-arg detection path is
    gone; ``Codec.__init_subclass__`` rejects pre-mask codecs at
    class-definition time.)

    Returns a jitted ``(params_vec, server_state, msgs, mask, staleness) ->
    (new_params_vec, new_server_state, global_delta)``.
    """
    def apply_fn(params_vec, server_state, msgs, mask, staleness):
        global_delta, server_state, _ = codec.aggregate(
            msgs, server_state, mask=mask, staleness=staleness)
        return params_vec + global_delta, server_state, global_delta

    return jax.jit(apply_fn)


class FederatedTrainer:
    """Simulates Algorithm 2 on one host (fully synchronous rounds)."""

    def __init__(self, model: tuple[Callable, Callable], train: Dataset,
                 test: Dataset, env: FedEnvironment, protocol: Codec,
                 tcfg: TrainerConfig = TrainerConfig()):
        self.apply_fn = model[1]
        self.env = env
        self.tcfg = tcfg
        self.train = train
        self.test = test

        key = jax.random.PRNGKey(tcfg.seed)
        params = model[0](key)
        vec, self.spec = flatten_pytree(params)
        self.params_vec = vec
        self.numel = int(vec.size)

        if tcfg.chunks is not None:
            cspec = (whole_vector_spec(self.numel) if tcfg.chunks == "whole"
                     else chunk_spec_from_tree(params, int(tcfg.chunks)))
            protocol = chunk_codec(protocol, cspec, p_fn=tcfg.p_fn,
                                   controller=tcfg.controller)
        elif tcfg.controller is not None:
            raise ValueError(
                "TrainerConfig(controller=...) needs per-chunk states; set "
                "TrainerConfig(chunks=...) (e.g. chunks='whole')")
        self.protocol = protocol
        self.ingest = bool(tcfg.ingest)
        if self.ingest and not protocol.supports_ingest:
            raise ValueError(
                f"codec {protocol.name!r} has no ingest path "
                "(supports_ingest=False); drop TrainerConfig(ingest=True)")
        if self.ingest and not protocol.rule.supports_streaming:
            # order-statistic rules need every client's coordinates at
            # once: the O(numel) streaming accumulator cannot express them,
            # so the round aggregates dense -- loudly, and ledger-honest
            # (bits bill the wire either way)
            warnings.warn(
                f"aggregation rule {protocol.rule.name!r} cannot stream "
                "(supports_streaming=False); TrainerConfig(ingest=True) "
                "falls back to the dense combine for this codec",
                RuntimeWarning, stacklevel=2)
            self.ingest = False

        self.splits = split_data(train.y, env, seed=tcfg.seed)
        self.rng = np.random.default_rng(tcfg.seed + 1)

        # stacked per-client optimizer state (fp32) + codec state pytrees
        c = env.n_clients
        self.client_mom = jnp.zeros((c, self.numel), jnp.float32)
        self.client_state = stack_states(
            protocol.init_client_state(self.numel), c)
        self.server_state = protocol.init_server_state(self.numel)
        self.last_seen = np.zeros(c, dtype=np.int64)  # round of last participation
        self.seen_mask = np.zeros(c, dtype=bool)      # dispatched at least once
        self.cache = UpdateCache(self.numel, max_rounds=64)

        self.round = 0
        # ``bits_up``/``bits_down`` are MEASURED wire bits when the codec has
        # a wire format (and measuring is not disabled), analytic otherwise;
        # the ``*_analytic`` columns always carry the Eq. 1 model.  A codec
        # without a wire format cannot be measured, whatever the config says;
        # one whose wire size is statically known (measured == analytic by
        # construction, e.g. signsgd's dense sign plane) only serializes when
        # measuring is explicitly requested.
        self.measure_bits = protocol.wire_format and (
            tcfg.measure_bits if tcfg.measure_bits is not None
            else not protocol.wire_static_size)
        self.bits_up = 0.0
        self.bits_down = 0.0
        self.bits_up_analytic = 0.0
        self.bits_down_analytic = 0.0
        self.wire_log: list[dict] = []   # per-round measured-vs-bound rows
        self.history: list[dict] = []

        self._encode_fn = self._build_encode_fn()
        self._apply_fn = self._build_apply_fn()
        self._eval_fn = jax.jit(self._eval_batch)

    # ------------------------------------------------------------------ jit
    def _build_encode_fn(self):
        return build_encode_phase(self.protocol, self.apply_fn, self.spec,
                                  self.tcfg.lr, self.tcfg.momentum)

    def _build_apply_fn(self):
        return build_apply_phase(self.protocol)

    def _eval_batch(self, params_vec, x, y):
        params = unflatten_pytree(params_vec, self.spec)
        logits = self.apply_fn(params, x)
        return jnp.sum(jnp.argmax(logits, -1) == y)

    # ----------------------------------------------------------------- host
    def _sample_batches(self, client_ids, local_iters):
        b = self.env.batch_size
        xs, ys = [], []
        for cid in client_ids:
            idx_pool = self.splits[cid]
            need = local_iters * b
            idx = self.rng.choice(idx_pool, size=need,
                                  replace=len(idx_pool) < need)
            xs.append(self.train.x[idx].reshape((local_iters, b) +
                                                self.train.x.shape[1:]))
            ys.append(self.train.y[idx].reshape(local_iters, b))
        return jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys))

    def _dispatch(self, sel, xs, ys):
        """Run the cohort's local updates + encoding against the CURRENT
        model; client-side state (momentum, residuals) commits at dispatch."""
        mom_sel = self.client_mom[sel]
        cstate_sel = take_states(self.client_state, sel)
        msgs, new_mom, new_cstate = self._encode_fn(
            self.params_vec, mom_sel, cstate_sel, xs, ys)
        self.client_mom = self.client_mom.at[sel].set(new_mom)
        self.client_state = scatter_states(self.client_state, sel, new_cstate)
        return msgs

    def _apply_update(self, msgs, mask, staleness):
        """Aggregate + apply; returns the global delta (device array)."""
        (self.params_vec, self.server_state,
         global_delta) = self._apply_fn(self.params_vec, self.server_state,
                                        msgs, jnp.asarray(mask, jnp.float32),
                                        jnp.asarray(staleness, jnp.float32))
        return global_delta

    def _participation_weights_np(self, mask, staleness) -> np.ndarray:
        """The codec's jnp combining weights, resolved host-side (fp32-exact,
        so the ingest denominator matches the jitted combine's weights)."""
        return np.asarray(self.protocol.participation_weights(
            jnp.asarray(mask, jnp.float32),
            jnp.asarray(staleness, jnp.float32)), np.float64)

    def _ingest_round(self, msgs_np, mask, staleness):
        """Fused streaming aggregation: the round's messages scatter into an
        O(numel) accumulator (wire codecs via their decoded fields) instead
        of aggregating a dense (P, numel) device block.  Returns the applied
        global delta plus the encoded batch (None for wire-less codecs) so
        the measured ledger reuses it without re-encoding."""
        proto = self.protocol
        w = self._participation_weights_np(mask, staleness)
        acc = proto.make_ingest(self.numel)
        batch = None
        if proto.wire_format:
            batch = proto.encode_wire_batch(msgs_np, direction="up")
            proto.ingest_wire_batch(acc, batch, w, direction="up")
        else:
            for i in range(msgs_np.shape[0]):
                proto.ingest_dense(acc, msgs_np[i], float(w[i]))
        gd, self.server_state, _ = proto.aggregate_ingest(acc,
                                                          self.server_state)
        gd = jnp.asarray(gd)
        self.params_vec = self.params_vec + gd
        return gd, batch

    def run_round(self):
        env, proto = self.env, self.protocol
        p = env.participants_per_round
        sel = self.rng.choice(env.n_clients, size=p, replace=False)
        xs, ys = self._sample_batches(sel, proto.local_iters)

        msgs = self._dispatch(sel, xs, ys)
        batch = None
        if self.ingest:
            global_delta, batch = self._ingest_round(
                np.asarray(msgs), np.ones(p, np.float32),
                np.zeros(p, np.float32))
        else:
            global_delta = self._apply_update(
                msgs, np.ones(p, np.float32), np.zeros(p, np.float32))
        gd_np = np.asarray(global_delta)

        # ---- bit ledger + partial-participation sync cost ------------------
        # analytic (Eq. 1) columns always accumulate as the cross-check
        up_analytic = p * proto.upload_bits(self.numel)
        per_update_analytic = proto.download_bits(self.numel,
                                                  n_participating=p)
        model_bits = 32.0 * self.numel
        if self.measure_bits:
            if batch is None:   # the ingest path already encoded the round
                batch = proto.encode_wire_batch(np.asarray(msgs),
                                                direction="up")
            up = proto.measured_batch_bits(batch)
            down_msg = proto.encode_wire(gd_np, direction="down")
            per_update = proto.measured_message_bits(down_msg)
            self._log_wire_round(np.asarray(batch.nnz), down_msg, up,
                                 per_update)
        else:
            up, per_update = up_analytic, per_update_analytic
        self.bits_up += up
        self.bits_up_analytic += up_analytic
        # vectorized over the cohort: sel is duplicate-free, so the batched
        # ledger update is exactly the old per-client loop
        skipped = self.round - self.last_seen[sel]
        self.bits_down += self.cache.sync_bits_batch(skipped, per_update,
                                                     model_bits)
        self.bits_down_analytic += self.cache.sync_bits_batch(
            skipped, per_update_analytic, model_bits)
        self.last_seen[sel] = self.round
        self.cache.push(gd_np)
        self.round += 1

    def _log_wire_round(self, nnz_up, down_msg, up, per_update):
        """Per-round measured-vs-ceiling row (Eq. 13 / Eq. 15 cross-check).

        ``nnz_up`` is the per-message coded-position count of the just-
        encoded upstream streams -- no extra O(P*numel) scan.
        """
        proto = self.protocol
        up_bound = None
        dn_bound = proto.wire_bound_bits(self.numel, down_msg.nnz, "down")
        bounds = [proto.wire_bound_bits(self.numel, int(z), "up")
                  for z in nnz_up]
        if bounds and all(b is not None for b in bounds):
            up_bound = float(sum(bounds))   # bounds cover header bits too
        self.wire_log.append({
            "round": self.round, "bits_up": up, "bits_up_bound": up_bound,
            "bits_down_per_update": per_update,
            "bits_down_per_update_bound": dn_bound,
        })

    def _history_extra(self) -> dict:
        """Trainer-specific columns appended to every history record."""
        return {}

    # ------------------------------------------------- crash-consistent state
    def _base_state(self) -> dict:
        """Every mutable field of the base trainer as a host-side composite
        (for ``checkpoint.save_state``): params, per-client optimizer + codec
        states, server codec state, RNG stream, update cache, ledgers, logs.
        Jitted functions are rebuilt from config on restore, not captured."""
        return {
            "round": self.round,
            "params_vec": np.asarray(self.params_vec),
            "client_mom": np.asarray(self.client_mom),
            "client_state": jax.tree.map(np.asarray, self.client_state),
            "server_state": jax.tree.map(np.asarray, self.server_state),
            "last_seen": self.last_seen.copy(),
            "seen_mask": self.seen_mask.copy(),
            "rng": self.rng.bit_generator.state,
            "cache": {"round": self.cache.round,
                      "updates": list(self.cache._updates)},  # newest first
            "bits": [self.bits_up, self.bits_down,
                     self.bits_up_analytic, self.bits_down_analytic],
            "wire_log": list(self.wire_log),
            "history": list(self.history),
        }

    def _load_base_state(self, st: dict) -> None:
        """Inverse of :meth:`_base_state` -- restores bit-exact trainer
        state into an identically-configured instance."""
        self.round = int(st["round"])
        self.params_vec = jnp.asarray(st["params_vec"])
        self.client_mom = jnp.asarray(st["client_mom"])
        self.client_state = jax.tree.map(jnp.asarray, st["client_state"])
        self.server_state = jax.tree.map(jnp.asarray, st["server_state"])
        self.last_seen = np.asarray(st["last_seen"], np.int64).copy()
        # pre-fix checkpoints have no seen_mask; last_seen > 0 recovers all
        # but the round-0 cohort (the legacy ambiguity this field removes)
        self.seen_mask = (np.asarray(st["seen_mask"], bool).copy()
                          if "seen_mask" in st
                          else self.last_seen > 0)
        self.rng.bit_generator.state = st["rng"]
        self.cache = UpdateCache(self.numel, max_rounds=self.cache.max_rounds)
        self.cache.round = int(st["cache"]["round"])
        for u in reversed(st["cache"]["updates"]):
            self.cache._updates.appendleft(np.asarray(u, np.float32))
        self.cache._cum = None
        (self.bits_up, self.bits_down,
         self.bits_up_analytic, self.bits_down_analytic) = \
            [float(b) for b in st["bits"]]
        self.wire_log = list(st["wire_log"])
        self.history = list(st["history"])

    def evaluate(self) -> float:
        n = len(self.test.y)
        bs = self.tcfg.eval_batch
        correct = 0
        for i in range(0, n, bs):
            x = jnp.asarray(self.test.x[i : i + bs])
            y = jnp.asarray(self.test.y[i : i + bs])
            correct += int(self._eval_fn(self.params_vec, x, y))
        return correct / n

    def run(self, n_rounds: int, eval_every: int = 10, verbose: bool = False):
        for r in range(n_rounds):
            self.run_round()
            if (r + 1) % eval_every == 0 or r == n_rounds - 1:
                acc = self.evaluate()
                rec = {
                    "round": self.round,
                    "iterations": self.round * self.protocol.local_iters,
                    "acc": acc,
                    "bits_up": self.bits_up,
                    "bits_down": self.bits_down,
                    "bits_up_analytic": self.bits_up_analytic,
                    "bits_down_analytic": self.bits_down_analytic,
                    "measured": self.measure_bits,
                }
                rec.update(self._history_extra())
                self.history.append(rec)
                if verbose:
                    print(f"round {self.round:5d} acc={acc:.4f} "
                          f"upMB={self.bits_up/8e6:.1f}")
        return self.history


class BufferedFederatedTrainer(FederatedTrainer):
    """Deadline-based buffered (async) aggregation -- the low-participation
    scaling mode the paper's §V regime calls for.

    Per round: a fresh cohort is dispatched (downloading the current model:
    downstream sync cost accounted here, through the ``UpdateCache``
    staleness machinery), computes + encodes against the model *at dispatch
    time*, and hands its messages to the :class:`ArrivalSimulator`.  The
    server then aggregates everything that landed by this round's deadline
    -- on-time updates plus stragglers buffered from earlier rounds -- via
    the codec's masked ``aggregate``, each message weighted by the codec's
    staleness decay.  Messages staler than ``max_staleness`` rounds are
    dropped (their upload bits are still accounted: the bytes did reach the
    server).  A round where nothing arrives leaves the model and the server
    codec state untouched and uploads zero bits.

    ``deadline=math.inf`` makes every update punctual: the trainer then
    reproduces the synchronous :class:`FederatedTrainer` bit for bit (same
    compiled phases, same inputs -- regression-tested).

    Note the same client may be re-dispatched while a previous update is
    still in flight (real buffered-FL systems usually forbid this; the
    simulator allows it and error feedback simply evolves at each dispatch).
    """

    def __init__(self, model, train: Dataset, test: Dataset,
                 env: FedEnvironment, protocol: Codec,
                 tcfg: TrainerConfig = TrainerConfig(),
                 latency: Optional[LatencyModel] = None,
                 deadline: float = math.inf, max_staleness: int = 8):
        super().__init__(model, train, test, env, protocol, tcfg)
        self.deadline = float(deadline)
        self.max_staleness = int(max_staleness)
        self.sim = ArrivalSimulator(latency or LatencyModel(),
                                    n_clients=env.n_clients,
                                    deadline=deadline, seed=tcfg.seed + 2)
        self.n_dropped = 0               # arrivals past the buffer horizon
        self.arrival_log: list[dict] = []

    def run_round(self):
        env, proto = self.env, self.protocol
        p = env.participants_per_round
        sel = self.rng.choice(env.n_clients, size=p, replace=False)
        xs, ys = self._sample_batches(sel, proto.local_iters)

        msgs = self._dispatch(sel, xs, ys)
        wire_payloads = self.ingest and proto.wire_format
        if wire_payloads:
            # streaming ingest mode ships the WIRE messages through the
            # arrival simulator (what a fleet server actually receives);
            # each arrival then scatters into the accumulator on landing
            dispatch_batch = proto.encode_wire_batch(np.asarray(msgs),
                                                     direction="up")
            payloads = [dispatch_batch.message(i)
                        for i in range(dispatch_batch.n_msgs)]
        else:
            payloads = list(np.asarray(msgs))
        self.sim.dispatch(self.round, sel, payloads)
        arrivals = self.sim.collect(self.round)
        kept = [a for a in arrivals
                if self.round - a.sent_round <= self.max_staleness]
        dropped = len(arrivals) - len(kept)
        self.n_dropped += dropped

        if kept and self.ingest:
            mask = np.ones(len(kept), np.float32)
            staleness = np.asarray([self.round - a.sent_round for a in kept],
                                   np.float32)
            w = self._participation_weights_np(mask, staleness)
            acc = proto.make_ingest(self.numel)
            for a, wi in zip(kept, w):
                if wire_payloads:
                    proto.ingest_wire(acc, a.payload, float(wi),
                                      direction="up")
                else:
                    proto.ingest_dense(acc, np.asarray(a.payload), float(wi))
            gd, self.server_state, _ = proto.aggregate_ingest(
                acc, self.server_state)
            gd = jnp.asarray(gd)
            self.params_vec = self.params_vec + gd
            gd_np = np.asarray(gd)
        elif kept:
            # pad the aggregation buffer to a multiple of the cohort size:
            # stable jit shapes (== p when everyone is on time), zero-weight
            # padding rows are invisible to the masked aggregate
            kpad = p * math.ceil(len(kept) / p)
            buf = np.zeros((kpad, self.numel), np.float32)
            mask = np.zeros(kpad, np.float32)
            staleness = np.zeros(kpad, np.float32)
            for i, a in enumerate(kept):
                buf[i] = np.asarray(a.payload)
                mask[i] = 1.0
                staleness[i] = self.round - a.sent_round
            global_delta = self._apply_update(jnp.asarray(buf), mask,
                                              staleness)
            gd_np = np.asarray(global_delta)
        else:
            # nothing reached the server: params + server codec state frozen
            gd_np = np.zeros(self.numel, np.float32)

        # ---- bit ledger ----------------------------------------------------
        # upstream bits are accounted when the bytes REACH the server
        # (including dropped stragglers); downstream sync cost at dispatch,
        # when the cohort pulled the current model through the UpdateCache.
        up_analytic = len(arrivals) * proto.upload_bits(self.numel)
        per_update_analytic = proto.download_bits(self.numel,
                                                  n_participating=p)
        model_bits = 32.0 * self.numel
        if self.measure_bits and arrivals and wire_payloads:
            # arrivals already carry their encoded streams: measure as-is
            up = float(sum(proto.measured_message_bits(a.payload)
                           for a in arrivals))
            down_msg = proto.encode_wire(gd_np, direction="down")
            per_update = proto.measured_message_bits(down_msg)
            self._log_wire_round([a.payload.nnz for a in arrivals],
                                 down_msg, up, per_update)
        elif self.measure_bits and arrivals:
            arr = np.stack([np.asarray(a.payload) for a in arrivals])
            batch = proto.encode_wire_batch(arr, direction="up")
            up = proto.measured_batch_bits(batch)
            down_msg = proto.encode_wire(gd_np, direction="down")
            per_update = proto.measured_message_bits(down_msg)
            self._log_wire_round(np.asarray(batch.nnz), down_msg, up,
                                 per_update)
        elif self.measure_bits:
            up = 0.0        # zero arrivals -> zero upstream bits, no wire row
            down_msg = proto.encode_wire(gd_np, direction="down")
            per_update = proto.measured_message_bits(down_msg)
        else:
            up, per_update = up_analytic, per_update_analytic
        self.bits_up += up
        self.bits_up_analytic += up_analytic
        skipped = self.round - self.last_seen[sel]
        self.bits_down += self.cache.sync_bits_batch(skipped, per_update,
                                                     model_bits)
        self.bits_down_analytic += self.cache.sync_bits_batch(
            skipped, per_update_analytic, model_bits)
        self.last_seen[sel] = self.round
        self.cache.push(gd_np)
        self.arrival_log.append({
            "round": self.round, "dispatched": p, "arrived": len(arrivals),
            "aggregated": len(kept), "dropped": dropped,
            "staleness_max": max(
                (self.round - a.sent_round for a in kept), default=0),
            "pending": self.sim.pending_count(),
        })
        self.round += 1

    def _history_extra(self) -> dict:
        last = self.arrival_log[-1] if self.arrival_log else {}
        return {"n_dropped": self.n_dropped,
                "pending": self.sim.pending_count(),
                "aggregated": last.get("aggregated", 0)}
