"""Federated training loop -- Algorithm 2 of the paper, end to end.

The round computation (local SGD on every participating client, upstream
compression with error feedback, server aggregation, downstream compression,
global apply) is ONE jit'd function, vmapped over the participating clients.
Partial participation, the server-side update cache (Sec. V-B) and the bit
ledger (Eq. 1) live in the host driver.

Works with any model from ``repro.models.paper_models`` (or any
(init_fn, apply_fn) pair with ``apply(params, x) -> logits``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import golomb
from repro.core.caching import UpdateCache
from repro.core.compression import (flatten_pytree, get_stc_backend,
                                    majority_vote_sign, sign_compress,
                                    top_k_sparsify, unflatten_pytree)
from repro.core.protocols import Protocol
from repro.data.synthetic import Dataset
from repro.fed.environment import FedEnvironment, split_data

__all__ = ["FederatedTrainer", "TrainerConfig"]


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    lr: float = 0.04
    momentum: float = 0.0
    seed: int = 0
    eval_batch: int = 512


def _cross_entropy(logits, y):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


class FederatedTrainer:
    """Simulates Algorithm 2 on one host."""

    def __init__(self, model: tuple[Callable, Callable], train: Dataset,
                 test: Dataset, env: FedEnvironment, protocol: Protocol,
                 tcfg: TrainerConfig = TrainerConfig()):
        self.apply_fn = model[1]
        self.env = env
        self.protocol = protocol
        self.tcfg = tcfg
        self.train = train
        self.test = test

        key = jax.random.PRNGKey(tcfg.seed)
        params = model[0](key)
        vec, self.spec = flatten_pytree(params)
        self.params_vec = vec
        self.numel = int(vec.size)

        self.splits = split_data(train.y, env, seed=tcfg.seed)
        self.rng = np.random.default_rng(tcfg.seed + 1)

        # stacked per-client optimizer/compressor state (fp32)
        c = env.n_clients
        self.client_mom = jnp.zeros((c, self.numel), jnp.float32)
        self.client_res = jnp.zeros((c, self.numel), jnp.float32)
        self.server_res = jnp.zeros((self.numel,), jnp.float32)
        self.last_seen = np.zeros(c, dtype=np.int64)  # round of last participation
        self.cache = UpdateCache(self.numel, max_rounds=64)

        self.round = 0
        self.bits_up = 0.0
        self.bits_down = 0.0
        self.history: list[dict] = []

        self._round_fn = self._build_round_fn()
        self._eval_fn = jax.jit(self._eval_batch)

    # ------------------------------------------------------------------ jit
    def _build_round_fn(self):
        proto = self.protocol
        lr = self.tcfg.lr
        mom = self.tcfg.momentum
        spec = self.spec
        # momentum stays an fp32 pytree inside the scan (no per-step
        # flatten/unflatten round-trip); it is flattened once per round to
        # slot back into the stacked (n_clients, numel) state.
        treedef, shapes = spec
        spec_f32 = (treedef, [(shape, jnp.float32) for shape, _ in shapes])
        apply_fn = self.apply_fn
        # compressor registry: the protocol's backend flag picks the STC
        # implementation ("jnp" operator vs Pallas histogram kernels).
        stc_backend = get_stc_backend(proto.backend) \
            if proto.name == "stc" else None

        def local_update(params_vec, mom_vec, xs, ys):
            """One client: ``local_iters`` SGD steps. xs: (n, b, ...)."""
            params = unflatten_pytree(params_vec, spec)
            mom_tree = unflatten_pytree(mom_vec, spec_f32)

            def loss(p, x, y):
                return _cross_entropy(apply_fn(p, x), y)

            def step(carry, batch):
                p, v = carry
                x, y = batch
                g = jax.grad(loss)(p, x, y)
                v = jax.tree.map(
                    lambda vi, gi: mom * vi + gi.astype(jnp.float32), v, g)
                # update math in fp32, round once per step at the cast back
                p = jax.tree.map(
                    lambda pi, vi: (pi.astype(jnp.float32) - lr * vi)
                    .astype(pi.dtype), p, v)
                return (p, v), None

            (p_final, v_final), _ = jax.lax.scan(step, (params, mom_tree),
                                                 (xs, ys))
            delta = flatten_pytree(p_final)[0] - params_vec
            return delta, flatten_pytree(v_final)[0]

        def compress_clients(deltas, res_sel):
            """Upstream compression of the whole (P, numel) round at once."""
            if proto.name in ("baseline", "fedavg"):
                return deltas, res_sel
            if proto.name == "signsgd":
                msgs = jax.vmap(
                    lambda d: sign_compress(d, proto.sign_step)[0])(deltas)
                return msgs, res_sel
            if proto.name == "topk":
                carried = deltas + res_sel
                msgs = jax.vmap(
                    lambda c: top_k_sparsify(c, proto.sparsity_up)[0])(carried)
                return msgs, carried - msgs
            # stc: one batched backend call (a single kernel launch per stage
            # on the "kernel" backend) instead of a vmap of selections
            msgs, new_res, _ = stc_backend.compress_with_residual_batch(
                deltas, res_sel, proto.sparsity_up)
            return msgs, new_res

        def round_fn(params_vec, server_res, mom_sel, res_sel, xs, ys):
            """xs: (P, iters, b, ...); ys: (P, iters, b)."""
            deltas, new_mom = jax.vmap(
                lambda m, x, y: local_update(params_vec, m, x, y)
            )(mom_sel, xs, ys)
            msgs, new_res = compress_clients(deltas, res_sel)

            if proto.name == "signsgd":
                global_delta = majority_vote_sign(msgs, proto.sign_step)
            else:
                mean = jnp.mean(msgs, axis=0)
                if proto.name == "stc":
                    global_delta, server_res, _ = \
                        stc_backend.compress_with_residual(
                            mean, server_res, proto.sparsity_down)
                else:
                    global_delta = mean
            new_params = params_vec + global_delta
            return new_params, server_res, new_mom, new_res, global_delta

        return jax.jit(round_fn)

    def _eval_batch(self, params_vec, x, y):
        params = unflatten_pytree(params_vec, self.spec)
        logits = self.apply_fn(params, x)
        return jnp.sum(jnp.argmax(logits, -1) == y)

    # ----------------------------------------------------------------- host
    def _sample_batches(self, client_ids, local_iters):
        b = self.env.batch_size
        xs, ys = [], []
        for cid in client_ids:
            idx_pool = self.splits[cid]
            need = local_iters * b
            idx = self.rng.choice(idx_pool, size=need,
                                  replace=len(idx_pool) < need)
            xs.append(self.train.x[idx].reshape((local_iters, b) +
                                                self.train.x.shape[1:]))
            ys.append(self.train.y[idx].reshape(local_iters, b))
        return jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys))

    def run_round(self):
        env, proto = self.env, self.protocol
        p = env.participants_per_round
        sel = self.rng.choice(env.n_clients, size=p, replace=False)
        xs, ys = self._sample_batches(sel, proto.local_iters)

        mom_sel = self.client_mom[sel]
        res_sel = self.client_res[sel]
        (self.params_vec, self.server_res, new_mom, new_res,
         global_delta) = self._round_fn(self.params_vec, self.server_res,
                                        mom_sel, res_sel, xs, ys)
        self.client_mom = self.client_mom.at[sel].set(new_mom)
        self.client_res = self.client_res.at[sel].set(new_res)

        # ---- bit ledger (Eq. 1) + partial-participation sync cost ----------
        self.bits_up += p * proto.upload_bits(self.numel)
        per_update = proto.download_bits(self.numel, n_participating=p)
        model_bits = 32.0 * self.numel
        for cid in sel:
            skipped = self.round - self.last_seen[cid]
            self.bits_down += self.cache.sync_bits(int(skipped), per_update,
                                                   model_bits)
            self.last_seen[cid] = self.round
        self.cache.push(np.asarray(global_delta))
        self.round += 1

    def evaluate(self) -> float:
        n = len(self.test.y)
        bs = self.tcfg.eval_batch
        correct = 0
        for i in range(0, n, bs):
            x = jnp.asarray(self.test.x[i : i + bs])
            y = jnp.asarray(self.test.y[i : i + bs])
            correct += int(self._eval_fn(self.params_vec, x, y))
        return correct / n

    def run(self, n_rounds: int, eval_every: int = 10, verbose: bool = False):
        for r in range(n_rounds):
            self.run_round()
            if (r + 1) % eval_every == 0 or r == n_rounds - 1:
                acc = self.evaluate()
                rec = {
                    "round": self.round,
                    "iterations": self.round * self.protocol.local_iters,
                    "acc": acc,
                    "bits_up": self.bits_up,
                    "bits_down": self.bits_down,
                }
                self.history.append(rec)
                if verbose:
                    print(f"round {self.round:5d} acc={acc:.4f} "
                          f"upMB={self.bits_up/8e6:.1f}")
        return self.history
