"""Federated runtime: environment (Alg. 5 splits), trainers (Alg. 2 loop,
synchronous + deadline-buffered async), client arrival simulation."""

from .arrivals import Arrival, ArrivalSimulator, LatencyModel
from .environment import FedEnvironment, split_data, volume_fractions
from .loop import BufferedFederatedTrainer, FederatedTrainer, TrainerConfig

__all__ = ["FedEnvironment", "split_data", "volume_fractions",
           "FederatedTrainer", "BufferedFederatedTrainer", "TrainerConfig",
           "Arrival", "ArrivalSimulator", "LatencyModel"]
