"""Federated runtime: environment (Alg. 5 splits), trainers (Alg. 2 loop,
synchronous + deadline-buffered async + event-driven), client arrival
simulation, fleet scenarios, fault injection + server hardening, pluggable
client samplers."""

from .arrivals import Arrival, ArrivalSimulator, LatencyModel
from .environment import FedEnvironment, split_data, volume_fractions
from .events import (EventClock, EventDrivenTrainer, EventLoop, EventRecord,
                     simulate_scenario)
from .faults import (ByzantineFault, CollusionFault, CorruptPayload,
                     FaultModel, ScaleAttackFault, ServerKilled,
                     SignFlipFault, make_fault, register_fault,
                     registered_faults)
from .loop import (BufferedFederatedTrainer, FederatedTrainer, TrainerConfig,
                   build_apply_phase, build_encode_phase)
from .sampling import (ClientSampler, SamplerView, make_sampler,
                       register_sampler, registered_samplers)
from .scenarios import (ComposedScenario, FlashOutageScenario, Scenario,
                        make_scenario, register_scenario,
                        registered_scenarios)

__all__ = ["FedEnvironment", "split_data", "volume_fractions",
           "FederatedTrainer", "BufferedFederatedTrainer", "TrainerConfig",
           "build_encode_phase", "build_apply_phase",
           "Arrival", "ArrivalSimulator", "LatencyModel",
           "EventClock", "EventLoop", "EventRecord", "EventDrivenTrainer",
           "simulate_scenario",
           "Scenario", "ComposedScenario", "FlashOutageScenario",
           "make_scenario", "register_scenario", "registered_scenarios",
           "FaultModel", "ServerKilled", "CorruptPayload", "make_fault",
           "register_fault", "registered_faults",
           "ByzantineFault", "SignFlipFault", "ScaleAttackFault",
           "CollusionFault",
           "ClientSampler", "SamplerView", "make_sampler", "register_sampler",
           "registered_samplers"]
