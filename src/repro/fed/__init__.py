"""Federated runtime: environment (Alg. 5 splits), trainer (Alg. 2 loop)."""

from .environment import FedEnvironment, split_data, volume_fractions
from .loop import FederatedTrainer, TrainerConfig

__all__ = ["FedEnvironment", "split_data", "volume_fractions",
           "FederatedTrainer", "TrainerConfig"]
