"""Event-driven federated serving: aggregate on K arrivals, not on a clock.

The deadline-buffered trainer (:mod:`repro.fed.loop`) still thinks in
rounds: the server closes a window every ``deadline`` time units whatever
has landed.  A fleet server does the opposite -- it reacts to events.  This
module replaces the round clock with a deterministic, seeded event queue of
in-flight updates (dispatch / arrival / drop / lost events) and a
FedBuff-style count trigger: the server aggregates the moment its buffer
holds ``k_arrivals`` updates, bumps the model version, and re-dispatches
clients (chosen by a pluggable :mod:`repro.fed.sampling` sampler) as
in-flight slots free up.  Which fleet the events come from is a registered
:mod:`repro.fed.scenarios` scenario -- diurnal load, flash crowds, regional
outages, straggler drift, adaptive client deadlines.

Three layers:

* :class:`EventClock` -- a priority queue of timestamped entries with a
  strict (time, push-sequence) order, so equal-time events pop in push
  order on every platform: the determinism invariant everything else
  leans on.
* :class:`EventLoop` -- the payload-agnostic server mechanics: in-flight
  tracking, staleness (model versions behind, FedBuff's measure) drops at
  the buffer horizon, scenario-driven latency/loss sampling, per-event
  counters.  :func:`simulate_scenario` drives it model-free (pure numpy --
  no jax) for scenario smoke stats; the trainer drives it with real
  encoded payloads.
* :class:`EventDrivenTrainer` -- :class:`FederatedTrainer` host machinery
  over the event loop, reusing the SAME two jitted phases (encode at
  dispatch, masked aggregate at trigger) and the fused ingest path
  (``TrainerConfig(ingest=True)``).  With ``k_arrivals`` = cohort size and
  the default concurrency, the buffer fills with exactly one cohort per
  aggregation (oldest dispatch first) and the trainer reproduces the
  synchronous :class:`FederatedTrainer` bit for bit -- params, measured +
  analytic ledgers and ``wire_log`` (regression-tested in
  tests/test_events.py).

Bits are billed per event, when the bytes reach the server: arrivals and
staleness-drops count (the transmission happened), network-lost and
client-aborted updates bill zero.  ``event_log`` carries one row per
arrival/drop/lost event; measured wire totals flush into the ledger at each
aggregation exactly as the synchronous trainer accounts them.
"""

from __future__ import annotations

import heapq
import math
from typing import List, NamedTuple, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.fed.environment import FedEnvironment
from repro.fed.loop import FederatedTrainer, TrainerConfig
from repro.fed.sampling import ClientSampler, SamplerView, make_sampler
from repro.fed.scenarios import Scenario, make_scenario

__all__ = ["EventClock", "EventLoop", "EventRecord", "EventDrivenTrainer",
           "simulate_scenario"]

# Safety valve: a scenario that starves the buffer (e.g. everything lost)
# must fail loudly, not dispatch forever.
_MAX_COHORTS_PER_AGG = 256


class EventClock:
    """Deterministic priority queue of timestamped entries.

    Entries pop in ``(time, push-sequence)`` order: pushes at the SAME
    simulation time drain in push order, and payloads are never compared --
    the heap-tie-breaking invariant that makes every event trace
    reproducible from the seed alone.
    """

    def __init__(self) -> None:
        self._heap: list = []
        self._seq = 0
        self.now = 0.0          # time of the latest popped entry

    def push(self, t: float, item) -> None:
        if not (math.isfinite(t) and t >= 0.0):
            raise ValueError(f"event time must be finite and >= 0, got {t}")
        heapq.heappush(self._heap, (float(t), self._seq, item))
        self._seq += 1

    def peek_time(self) -> float:
        if not self._heap:
            raise IndexError("peek_time on an empty EventClock")
        return self._heap[0][0]

    def pop(self):
        """(time, seq, item) of the next due entry; advances ``now``."""
        if not self._heap:
            raise IndexError("pop on an empty EventClock")
        t, seq, item = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        return t, seq, item

    def __len__(self) -> int:
        return len(self._heap)


class _InFlight(NamedTuple):
    """One dispatched update travelling toward the server."""

    client: int
    dseq: int           # global dispatch sequence (dispatch order)
    sent_at: float
    sent_version: int   # server model version the client encoded against
    payload: object
    lost: bool          # network loss / client-side abort: never arrives


class EventRecord(NamedTuple):
    """One served event: ``kind`` is "arrival", "drop" or "lost"."""

    kind: str
    t: float
    client: int
    staleness: int      # model versions behind at arrival (FedBuff measure)
    dseq: int
    sent_at: float
    sent_version: int
    payload: object


class EventLoop:
    """Payload-agnostic event-driven server mechanics.

    The driver alternates two calls until :meth:`ready`:
    :meth:`dispatch` whenever :attr:`wants_dispatch` (the in-flight pool has
    room for a full cohort), else :meth:`step` (serve the next due event).
    ``take_round()`` then consumes the buffer -- oldest dispatch first --
    and bumps the server version.  Staleness of an update is
    ``version_now - version_at_dispatch``; anything staler than
    ``max_staleness`` is dropped at arrival.  Updates flagged lost by the
    scenario occupy their in-flight slot until their would-be arrival time,
    then vanish (the server only learns by timeout).
    """

    def __init__(self, scenario: Scenario, n_clients: int, *, cohort: int,
                 k_arrivals: int, concurrency: int, max_staleness: int,
                 seed: int = 0) -> None:
        if k_arrivals < 1:
            raise ValueError(f"k_arrivals must be >= 1, got {k_arrivals}")
        if not 1 <= cohort <= n_clients:
            raise ValueError(f"cohort must be in [1, {n_clients}], "
                             f"got {cohort}")
        if concurrency < cohort:
            raise ValueError("concurrency must admit at least one cohort "
                             f"({cohort}), got {concurrency}")
        if max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {max_staleness}")
        self.scenario = scenario
        self.n_clients = int(n_clients)
        self.cohort = int(cohort)
        self.k_arrivals = int(k_arrivals)
        self.concurrency = int(concurrency)
        self.max_staleness = int(max_staleness)
        self.clock = EventClock()
        self.rng = np.random.default_rng(seed)          # latency/loss draws
        self.scales = scenario.latency.client_scales(n_clients, seed=seed + 1)
        self.version = 0                                # aggregations so far
        self.buffer: List[EventRecord] = []
        self._inflight_n = np.zeros(n_clients, np.int32)
        self.n_inflight = 0
        self._dseq = 0
        self.n_dispatched = 0
        self.n_arrived = 0
        self.n_dropped = 0
        self.n_lost = 0
        self.staleness_sum = 0

    # ------------------------------------------------------------- driving
    @property
    def inflight(self) -> np.ndarray:
        """(n_clients,) bool: at least one update of theirs is in the air."""
        return self._inflight_n > 0

    @property
    def wants_dispatch(self) -> bool:
        """True when the buffer still needs arrivals and the in-flight pool
        has room for one more full cohort."""
        return (len(self.buffer) < self.k_arrivals
                and self.n_inflight + self.cohort <= self.concurrency)

    def ready(self) -> bool:
        return len(self.buffer) >= self.k_arrivals

    def dispatch(self, client_ids, payloads=None):
        """File one cohort at the current simulation time.

        Latencies and loss flags come from the scenario; returns
        ``(latencies, lost)`` so the driver can log them.  ``payloads=None``
        dispatches opaque placeholders (the model-free simulator).
        """
        ids = np.asarray(client_ids, np.int64)
        if payloads is None:
            payloads = [None] * ids.size
        if len(payloads) != ids.size:
            raise ValueError(f"{ids.size} clients but {len(payloads)} "
                             "payloads")
        t = self.clock.now
        lats, lost = self.scenario.sample(t, ids, self.scales, self.rng)
        for cid, lat, lo, payload in zip(ids, lats, lost, payloads):
            self.clock.push(t + float(lat), _InFlight(
                int(cid), self._dseq, t, self.version, payload, bool(lo)))
            self._dseq += 1
            self._inflight_n[cid] += 1
        self.n_inflight += ids.size
        self.n_dispatched += ids.size
        return lats, lost

    def step(self) -> EventRecord:
        """Serve the next due event; buffers arrivals, records drops/losses."""
        t, _, f = self.clock.pop()
        self.n_inflight -= 1
        self._inflight_n[f.client] -= 1
        stal = self.version - f.sent_version
        if f.lost:
            self.n_lost += 1
            return EventRecord("lost", t, f.client, stal, f.dseq, f.sent_at,
                               f.sent_version, None)
        if stal > self.max_staleness:
            self.n_dropped += 1
            return EventRecord("drop", t, f.client, stal, f.dseq, f.sent_at,
                               f.sent_version, f.payload)
        rec = EventRecord("arrival", t, f.client, stal, f.dseq, f.sent_at,
                          f.sent_version, f.payload)
        self.buffer.append(rec)
        self.n_arrived += 1
        self.staleness_sum += stal
        return rec

    def take_round(self) -> List[EventRecord]:
        """Consume the buffer for one aggregation and bump the version.

        Returned oldest dispatch first (global dispatch order), the same
        convention as ``ArrivalSimulator.collect`` -- with K = cohort this
        makes the aggregation batch EXACTLY the dispatch batch, whatever
        order the arrivals raced in.
        """
        if not self.buffer:
            raise RuntimeError("take_round with an empty buffer: the server "
                               "only aggregates on arrivals")
        kept = sorted(self.buffer, key=lambda r: r.dseq)
        self.buffer = []
        self.version += 1
        return kept

    def stats(self) -> dict:
        """Counters + rates for scenario smoke stats and dry-run records."""
        now = self.clock.now
        served = self.n_arrived + self.n_dropped + self.n_lost
        return {
            "aggregations": self.version,
            "dispatched": self.n_dispatched,
            "arrived": self.n_arrived,
            "dropped": self.n_dropped,
            "lost": self.n_lost,
            "pending": self.n_inflight,
            "sim_time": now,
            "aggs_per_time": self.version / now if now > 0 else 0.0,
            "drop_rate": (self.n_dropped + self.n_lost) / max(served, 1),
            "mean_staleness": self.staleness_sum / max(self.n_arrived, 1),
        }


def simulate_scenario(scenario: Union[str, Scenario], *, n_clients: int = 256,
                      cohort: int = 16, k_arrivals: Optional[int] = None,
                      concurrency: Optional[int] = None,
                      max_staleness: int = 4, aggregations: int = 8,
                      sampler: Union[str, ClientSampler] = "uniform",
                      seed: int = 0) -> dict:
    """Model-free event-loop run of one scenario: pure numpy, no payloads.

    Drives :class:`EventLoop` through ``aggregations`` K-arrival triggers
    with placeholder payloads and returns :meth:`EventLoop.stats` -- the
    per-scenario event statistics the dry-run records and the scenario
    smoke tests read.  Deterministic in ``seed``.
    """
    scen = make_scenario(scenario) if isinstance(scenario, str) else scenario
    smp = make_sampler(sampler) if isinstance(sampler, str) else sampler
    k = int(k_arrivals) if k_arrivals else cohort
    conc = int(concurrency) if concurrency else max(k, cohort)
    loop = EventLoop(scen, n_clients, cohort=cohort, k_arrivals=k,
                     concurrency=conc, max_staleness=max_staleness, seed=seed)
    rng = np.random.default_rng(seed + 7)               # sampler draws
    last_seen = np.zeros(n_clients, np.int64)
    for _ in range(aggregations):
        cohorts = 0
        while not loop.ready():
            if loop.wants_dispatch:
                if cohorts >= _MAX_COHORTS_PER_AGG:
                    raise RuntimeError(
                        f"scenario {scen.name!r} starved the buffer: "
                        f"{cohorts} cohorts dispatched without reaching "
                        f"k_arrivals={k}")
                view = SamplerView(loop.version, last_seen, loop.inflight)
                loop.dispatch(smp.select(rng, view, cohort))
                cohorts += 1
            else:
                loop.step()
        for rec in loop.take_round():
            last_seen[rec.client] = loop.version
    return {"scenario": scen.name, **loop.stats()}


class EventDrivenTrainer(FederatedTrainer):
    """K-arrival-triggered (FedBuff-style) federated training.

    One ``run_round()`` = one aggregation: the event loop dispatches
    sampler-chosen cohorts whenever the in-flight pool has room, serves
    arrival/drop/lost events in time order, and the moment ``k_arrivals``
    updates sit in the buffer the codec's masked ``aggregate`` (or the
    fused ingest path) fires with each update weighted by its FedBuff
    staleness -- model versions behind, not rounds.  The two jitted phases
    are the synchronous trainer's own; clients encode against the model at
    dispatch time, exactly as the buffered trainer commits error feedback.

    With ``k_arrivals`` = cohort size (the default) and the default
    concurrency of one cohort, every aggregation consumes exactly one
    dispatch cohort in dispatch order and the trainer is bit-identical to
    :class:`FederatedTrainer` under ANY scenario that loses and drops
    nothing -- params, measured/analytic ledgers, ``wire_log``.

    Ledger semantics (the honest-accounting rules of the buffered trainer,
    per event): upstream bits bill at arrival AND at staleness-drop (the
    bytes reached the server) but never for lost/aborted updates;
    downstream ``UpdateCache`` sync cost bills per dispatched cohort at the
    next aggregation's measured per-update size.  ``event_log`` has one row
    per event; ``agg_log`` one per aggregation (arrived / dropped / lost /
    buffer staleness / simulation time).
    """

    def __init__(self, model, train, test, env: FedEnvironment, protocol,
                 tcfg: TrainerConfig = TrainerConfig(),
                 scenario: Union[str, Scenario] = "steady",
                 sampler: Union[str, ClientSampler] = "uniform",
                 k_arrivals: Optional[int] = None,
                 concurrency: Optional[int] = None, max_staleness: int = 8):
        super().__init__(model, train, test, env, protocol, tcfg)
        if not self._accepts_mask:
            raise TypeError(
                f"codec {self.protocol.name!r} overrides aggregate() without "
                "the mask/staleness parameters; event-driven aggregation "
                "needs the masked Codec API (see core.protocols.Codec)")
        self.scenario = (make_scenario(scenario)
                         if isinstance(scenario, str) else scenario)
        self.sampler = (make_sampler(sampler)
                        if isinstance(sampler, str) else sampler)
        p = env.participants_per_round
        self.k_arrivals = int(k_arrivals) if k_arrivals else p
        self.concurrency = (int(concurrency) if concurrency
                            else max(self.k_arrivals, p))
        self.max_staleness = int(max_staleness)
        self.loop = EventLoop(self.scenario, env.n_clients, cohort=p,
                              k_arrivals=self.k_arrivals,
                              concurrency=self.concurrency,
                              max_staleness=self.max_staleness,
                              seed=tcfg.seed + 2)
        self._wire_payloads = self.ingest and self.protocol.wire_format
        self.n_dropped = 0
        self.n_lost = 0
        self.event_log: list[dict] = []
        self.agg_log: list[dict] = []
        self._billed: list[EventRecord] = []    # reached server, unledgered
        self._pending_down: list[np.ndarray] = []   # cohorts since last agg

    # ----------------------------------------------------------- event side
    def _dispatch_cohort(self) -> None:
        """Sampler-chosen cohort: local SGD + encode against the CURRENT
        model (one jitted phase), then into the event queue."""
        proto = self.protocol
        p = self.env.participants_per_round
        view = SamplerView(self.round, self.last_seen, self.loop.inflight)
        sel = np.asarray(self.sampler.select(self.rng, view, p), np.int64)
        xs, ys = self._sample_batches(sel, proto.local_iters)
        msgs = self._dispatch(sel, xs, ys)
        if self._wire_payloads:
            batch = proto.encode_wire_batch(np.asarray(msgs), direction="up")
            payloads = [batch.message(i) for i in range(batch.n_msgs)]
        else:
            payloads = list(np.asarray(msgs))
        _, lost = self.loop.dispatch(sel, payloads)
        self._pending_down.append(sel)
        self.event_log.append({
            "kind": "dispatch", "t": self.loop.clock.now, "version": self.round,
            "clients": int(sel.size), "lost_in_flight": int(lost.sum())})

    def _record_event(self, ev: EventRecord) -> None:
        proto = self.protocol
        row = {"kind": ev.kind, "t": ev.t, "client": ev.client,
               "staleness": ev.staleness, "version": self.round}
        if ev.kind == "lost":
            self.n_lost += 1
            row["bits_up"] = 0.0                # bytes never reached the server
        else:
            self._billed.append(ev)
            if ev.kind == "drop":
                self.n_dropped += 1
            # exact per-event bits when the payload IS the wire stream;
            # dense-mode rounds measure the batch at the aggregation flush
            # (identical totals) and bill the analytic size per event here
            row["bits_up"] = (proto.measured_message_bits(ev.payload)
                              if self._wire_payloads and self.measure_bits
                              else proto.upload_bits(self.numel))
        self.event_log.append(row)

    # ------------------------------------------------------------ round API
    def run_round(self):
        """Advance the event loop to the next K-arrival aggregation."""
        loop = self.loop
        cohorts = 0
        while not loop.ready():
            if loop.wants_dispatch:
                if cohorts >= _MAX_COHORTS_PER_AGG:
                    raise RuntimeError(
                        f"scenario {self.scenario.name!r} starved the "
                        f"buffer: {cohorts} cohorts dispatched without "
                        f"reaching k_arrivals={self.k_arrivals}")
                self._dispatch_cohort()
                cohorts += 1
            else:
                self._record_event(loop.step())
        self._aggregate_round()

    def advance_to(self, t: float) -> int:
        """Serve every event due by simulation time ``t`` WITHOUT
        dispatching; aggregations still trigger whenever the buffer fills.
        Zero due events -- quiescence -- leaves params, codec state and
        every ledger untouched.  Returns the number of events served."""
        served = 0
        while len(self.loop.clock) and self.loop.clock.peek_time() <= t:
            self._record_event(self.loop.step())
            served += 1
            if self.loop.ready():
                self._aggregate_round()
        return served

    # ---------------------------------------------------------- aggregation
    def _aggregate_round(self) -> None:
        proto = self.protocol
        p = self.env.participants_per_round
        kept = self.loop.take_round()       # oldest dispatch first
        mask_k = np.ones(len(kept), np.float32)
        stal_k = np.asarray([r.staleness for r in kept], np.float32)
        if self.ingest:
            w = self._participation_weights_np(mask_k, stal_k)
            acc = proto.make_ingest(self.numel)
            for r, wi in zip(kept, w):
                if self._wire_payloads:
                    proto.ingest_wire(acc, r.payload, float(wi),
                                      direction="up")
                else:
                    proto.ingest_dense(acc, np.asarray(r.payload), float(wi))
            gd, self.server_state, _ = proto.aggregate_ingest(
                acc, self.server_state)
            gd = jnp.asarray(gd)
            self.params_vec = self.params_vec + gd
            gd_np = np.asarray(gd)
        else:
            # pad to a multiple of the cohort: stable jit shapes (== p in
            # the K = cohort configuration), zero-weight padding rows are
            # invisible to the masked aggregate
            kpad = p * math.ceil(len(kept) / p)
            buf = np.zeros((kpad, self.numel), np.float32)
            mask = np.zeros(kpad, np.float32)
            staleness = np.zeros(kpad, np.float32)
            for i, r in enumerate(kept):
                buf[i] = np.asarray(r.payload)
                mask[i] = 1.0
                staleness[i] = r.staleness
            gd_np = np.asarray(self._apply_update(jnp.asarray(buf), mask,
                                                  staleness))

        # ---- bit ledger: flush everything that reached the server --------
        billed, self._billed = self._billed, []
        up_analytic = len(billed) * proto.upload_bits(self.numel)
        per_update_analytic = proto.download_bits(self.numel,
                                                  n_participating=p)
        model_bits = 32.0 * self.numel
        if self.measure_bits and billed and self._wire_payloads:
            up = float(sum(proto.measured_message_bits(r.payload)
                           for r in billed))
            down_msg = proto.encode_wire(gd_np, direction="down")
            per_update = proto.measured_message_bits(down_msg)
            self._log_wire_round([r.payload.nnz for r in billed], down_msg,
                                 up, per_update)
        elif self.measure_bits and billed:
            arr = np.stack([np.asarray(r.payload) for r in billed])
            batch = proto.encode_wire_batch(arr, direction="up")
            up = proto.measured_batch_bits(batch)
            down_msg = proto.encode_wire(gd_np, direction="down")
            per_update = proto.measured_message_bits(down_msg)
            self._log_wire_round(np.asarray(batch.nnz), down_msg, up,
                                 per_update)
        elif self.measure_bits:
            up = 0.0
            down_msg = proto.encode_wire(gd_np, direction="down")
            per_update = proto.measured_message_bits(down_msg)
        else:
            up, per_update = up_analytic, per_update_analytic
        self.bits_up += up
        self.bits_up_analytic += up_analytic
        # downstream sync cost per dispatched cohort, in dispatch order --
        # cohorts may repeat a client, so last_seen commits between cohorts
        for sel in self._pending_down:
            skipped = self.round - self.last_seen[sel]
            self.bits_down += self.cache.sync_bits_batch(
                skipped, per_update, model_bits)
            self.bits_down_analytic += self.cache.sync_bits_batch(
                skipped, per_update_analytic, model_bits)
            self.last_seen[sel] = self.round
        self._pending_down = []
        self.cache.push(gd_np)
        stats = self.loop.stats()
        self.agg_log.append({
            "agg": self.loop.version, "t": self.loop.clock.now,
            "aggregated": len(kept), "billed": len(billed),
            "staleness_max": int(stal_k.max(initial=0.0)),
            "dropped_total": self.n_dropped, "lost_total": self.n_lost,
            "pending": stats["pending"],
        })
        self.round += 1

    def _history_extra(self) -> dict:
        now = self.loop.clock.now
        last = self.agg_log[-1] if self.agg_log else {}
        return {"n_dropped": self.n_dropped, "n_lost": self.n_lost,
                "sim_time": now,
                "aggs_per_time": self.round / now if now > 0 else 0.0,
                "pending": self.loop.n_inflight,
                "aggregated": last.get("aggregated", 0)}
