"""Event-driven federated serving: aggregate on K arrivals, not on a clock.

The deadline-buffered trainer (:mod:`repro.fed.loop`) still thinks in
rounds: the server closes a window every ``deadline`` time units whatever
has landed.  A fleet server does the opposite -- it reacts to events.  This
module replaces the round clock with a deterministic, seeded event queue of
in-flight updates (dispatch / arrival / drop / lost events) and a
FedBuff-style count trigger: the server aggregates the moment its buffer
holds ``k_arrivals`` updates, bumps the model version, and re-dispatches
clients (chosen by a pluggable :mod:`repro.fed.sampling` sampler) as
in-flight slots free up.  Which fleet the events come from is a registered
:mod:`repro.fed.scenarios` scenario -- diurnal load, flash crowds, regional
outages, straggler drift, adaptive client deadlines.

Three layers:

* :class:`EventClock` -- a priority queue of timestamped entries with a
  strict (time, push-sequence) order, so equal-time events pop in push
  order on every platform: the determinism invariant everything else
  leans on.
* :class:`EventLoop` -- the payload-agnostic server mechanics: in-flight
  tracking, staleness (model versions behind, FedBuff's measure) drops at
  the buffer horizon, scenario-driven latency/loss sampling, per-event
  counters.  :func:`simulate_scenario` drives it model-free (pure numpy --
  no jax) for scenario smoke stats; the trainer drives it with real
  encoded payloads.
* :class:`EventDrivenTrainer` -- :class:`FederatedTrainer` host machinery
  over the event loop, reusing the SAME two jitted phases (encode at
  dispatch, masked aggregate at trigger) and the fused ingest path
  (``TrainerConfig(ingest=True)``).  With ``k_arrivals`` = cohort size and
  the default concurrency, the buffer fills with exactly one cohort per
  aggregation (oldest dispatch first) and the trainer reproduces the
  synchronous :class:`FederatedTrainer` bit for bit -- params, measured +
  analytic ledgers and ``wire_log`` (regression-tested in
  tests/test_events.py).

Bits are billed per event, when the bytes reach the server: arrivals and
staleness-drops count (the transmission happened), network-lost and
client-aborted updates bill zero.  ``event_log`` carries one row per
arrival/drop/lost event; measured wire totals flush into the ledger at each
aggregation exactly as the synchronous trainer accounts them.

Server hardening (admission control): a registered :mod:`repro.fed.faults`
model can mangle dispatches (bit flips, truncation, duplicates, stale
replays, client crashes, a server kill), and the loop defends per event
BEFORE anything enters the aggregation buffer -- duplicate/replay rejection
keyed on ``(client, dispatch_version)``, then the staleness screen, then
payload validation (a typed ``WireDecodeError`` quarantines the message).
Rejected arrivals follow the honest-ledger rule: their bytes reached the
server, so their upstream bits bill, but they carry ZERO aggregate weight.
The trainer checkpoints crash-consistently every ``ckpt_every`` served
events through :mod:`repro.checkpoint` (``save_state``: event clock,
in-flight buffer, RNG streams, codec/residual states, ledgers, quarantine
log) and a kill-and-resume run is bit-identical to an uninterrupted one.
"""

from __future__ import annotations

import heapq
import math
from typing import List, NamedTuple, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_state, save_state
from repro.core.residual import ResidualState
from repro.core.wire import (ChunkedWireBatch, ChunkedWireMessage,
                             WireBatch, WireDecodeError, WireMessage)
from repro.fed.environment import FedEnvironment
from repro.fed.faults import CorruptPayload, FaultModel, make_fault
from repro.fed.loop import FederatedTrainer, TrainerConfig
from repro.fed.sampling import ClientSampler, SamplerView, make_sampler
from repro.fed.scenarios import Scenario, make_scenario

__all__ = ["EventClock", "EventLoop", "EventRecord", "EventDrivenTrainer",
           "simulate_scenario"]

# Safety valve: a scenario that starves the buffer (e.g. everything lost)
# must fail loudly, not dispatch forever.
_MAX_COHORTS_PER_AGG = 256


class EventClock:
    """Deterministic priority queue of timestamped entries.

    Entries pop in ``(time, push-sequence)`` order: pushes at the SAME
    simulation time drain in push order, and payloads are never compared --
    the heap-tie-breaking invariant that makes every event trace
    reproducible from the seed alone.
    """

    def __init__(self) -> None:
        self._heap: list = []
        self._seq = 0
        self.now = 0.0          # time of the latest popped entry

    def push(self, t: float, item) -> None:
        if not (math.isfinite(t) and t >= 0.0):
            raise ValueError(f"event time must be finite and >= 0, got {t}")
        heapq.heappush(self._heap, (float(t), self._seq, item))
        self._seq += 1

    def peek_time(self) -> float:
        if not self._heap:
            raise IndexError("peek_time on an empty EventClock")
        return self._heap[0][0]

    def pop(self):
        """(time, seq, item) of the next due entry; advances ``now``."""
        if not self._heap:
            raise IndexError("pop on an empty EventClock")
        t, seq, item = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        return t, seq, item

    def __len__(self) -> int:
        return len(self._heap)


class _InFlight(NamedTuple):
    """One dispatched update travelling toward the server."""

    client: int
    dseq: int           # global dispatch sequence (dispatch order)
    sent_at: float
    sent_version: int   # server model version the client encoded against
    dversion: int       # per-client dispatch version (the dedup key)
    payload: object
    lost: bool          # network loss / client-side abort: never arrives


class EventRecord(NamedTuple):
    """One served event: ``kind`` is "arrival", "drop", "lost",
    "duplicate" (an already-admitted ``(client, dispatch_version)`` key
    re-delivered) or "quarantine" (payload failed admission validation)."""

    kind: str
    t: float
    client: int
    staleness: int      # model versions behind at arrival (FedBuff measure)
    dseq: int
    sent_at: float
    sent_version: int
    payload: object


class EventLoop:
    """Payload-agnostic event-driven server mechanics.

    The driver alternates two calls until :meth:`ready`:
    :meth:`dispatch` whenever :attr:`wants_dispatch` (the in-flight pool has
    room for a full cohort), else :meth:`step` (serve the next due event).
    ``take_round()`` then consumes the buffer -- oldest dispatch first --
    and bumps the server version.  Staleness of an update is
    ``version_now - version_at_dispatch``; anything staler than
    ``max_staleness`` is dropped at arrival.  Updates flagged lost by the
    scenario occupy their in-flight slot until their would-be arrival time,
    then vanish (the server only learns by timeout).

    Admission control (``step``): a delivered update is rejected as
    "duplicate" when its ``(client, dispatch_version)`` key was already
    admitted (duplicate delivery or a stale replay of an admitted
    dispatch), then screened for staleness, then -- when a ``validator``
    is installed -- its payload is validated; a ``WireDecodeError`` there
    quarantines the message (one ``quarantine_log`` row with the typed
    reason).  ``faults`` is an optional :class:`repro.fed.faults.FaultModel`
    applied at dispatch time: its per-dispatch decisions come from its own
    counter-based generator keyed on the dispatch sequence number, so the
    loop's latency RNG never sees the faults and a ``faults=None`` run is
    bit-identical to one with the neutral model.
    """

    def __init__(self, scenario: Scenario, n_clients: int, *, cohort: int,
                 k_arrivals: int, concurrency: int, max_staleness: int,
                 seed: int = 0, faults: Optional[FaultModel] = None,
                 validator=None) -> None:
        if k_arrivals < 1:
            raise ValueError(f"k_arrivals must be >= 1, got {k_arrivals}")
        if not 1 <= cohort <= n_clients:
            raise ValueError(f"cohort must be in [1, {n_clients}], "
                             f"got {cohort}")
        if concurrency < cohort:
            raise ValueError("concurrency must admit at least one cohort "
                             f"({cohort}), got {concurrency}")
        if max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {max_staleness}")
        self.scenario = scenario
        self.n_clients = int(n_clients)
        self.cohort = int(cohort)
        self.k_arrivals = int(k_arrivals)
        self.concurrency = int(concurrency)
        self.max_staleness = int(max_staleness)
        self.clock = EventClock()
        self.rng = np.random.default_rng(seed)          # latency/loss draws
        self.scales = scenario.latency.client_scales(n_clients, seed=seed + 1)
        self.faults = faults
        self.validator = validator
        self.version = 0                                # aggregations so far
        self.last_agg_t = 0.0                           # last take_round time
        self.buffer: List[EventRecord] = []
        self._inflight_n = np.zeros(n_clients, np.int32)
        self.n_inflight = 0
        self._dseq = 0
        # admission-control state: per-client dispatch version counter, the
        # set of already-admitted (client, dversion) keys, and each client's
        # last in-flight record (what a replay fault re-delivers)
        self._dispatch_count = np.zeros(n_clients, np.int64)
        self._seen: set = set()
        self._last_sent: dict = {}
        self.quarantine_log: List[dict] = []
        self.n_dispatched = 0
        self.n_arrived = 0
        self.n_dropped = 0
        self.n_lost = 0
        self.n_duplicates = 0
        self.n_quarantined = 0
        self.n_injected = 0         # fault-injected extra deliveries
        self.staleness_sum = 0

    # ------------------------------------------------------------- driving
    @property
    def inflight(self) -> np.ndarray:
        """(n_clients,) bool: at least one update of theirs is in the air."""
        return self._inflight_n > 0

    @property
    def wants_dispatch(self) -> bool:
        """True when the buffer still needs arrivals and the in-flight pool
        has room for one more full cohort."""
        return (len(self.buffer) < self.k_arrivals
                and self.n_inflight + self.cohort <= self.concurrency)

    def ready(self) -> bool:
        return len(self.buffer) >= self.k_arrivals

    def dispatch(self, client_ids, payloads=None):
        """File one cohort at the current simulation time.

        Latencies and loss flags come from the scenario; returns
        ``(latencies, lost)`` so the driver can log them.  ``payloads=None``
        dispatches opaque placeholders (the model-free simulator).
        """
        ids = np.asarray(client_ids, np.int64)
        if payloads is None:
            payloads = [None] * ids.size
        if len(payloads) != ids.size:
            raise ValueError(f"{ids.size} clients but {len(payloads)} "
                             "payloads")
        t = self.clock.now
        lats, lost = self.scenario.sample(t, ids, self.scales, self.rng)
        for cid, lat, lo, payload in zip(ids, lats, lost, payloads):
            dv = int(self._dispatch_count[cid])
            self._dispatch_count[cid] += 1
            fl = _InFlight(int(cid), self._dseq, t, self.version, dv,
                           payload, bool(lo))
            self._dseq += 1
            arrive = t + float(lat)
            if self.faults is not None:
                fl, arrive = self._apply_faults(fl, arrive, float(lat))
            self.clock.push(arrive, fl)
            self._inflight_n[cid] += 1
        self.n_inflight += ids.size
        self.n_dispatched += ids.size
        return lats, lost

    def _apply_faults(self, fl: _InFlight, arrive: float, lat: float):
        """One dispatch through the fault model's hooks, in fixed order
        (crash -> byzantine -> corrupt -> duplicate -> replay), all drawing
        from the model's own per-``dseq`` generator -- the loop's latency
        RNG is untouched, so the fault-free trace is preserved exactly."""
        frng = self.faults.rng(fl.dseq)
        if self.faults.crash(frng):
            fl = fl._replace(lost=True)
        if not fl.lost:
            # Byzantine rewrite first: the adversary crafts a VALID payload
            # (it must survive admission), which corruption may then mangle
            # like any honest bytes on the wire
            newp = self.faults.byzantine(fl.payload, fl.client, frng)
            if newp is not fl.payload:
                fl = fl._replace(payload=newp)
            newp = self.faults.corrupt(fl.payload, frng)
            if newp is not fl.payload:
                fl = fl._replace(payload=newp)
        if self.faults.duplicate(frng) and not fl.lost:
            # a second copy of the SAME delivery, some extra transit later
            self._inject(fl, arrive + lat * (1.0 + frng.uniform()))
        if self.faults.replay(frng):
            prev = self._last_sent.get(fl.client)
            if prev is not None:
                # a stale copy of the client's previous dispatch resurfaces
                # (even one originally lost: the network kept the bytes)
                self._inject(prev._replace(lost=False),
                             arrive + lat * (1.0 + frng.uniform()))
        self._last_sent[fl.client] = fl     # post-fault: replays re-deliver
        return fl, arrive                   # what was actually on the wire

    def _inject(self, fl: _InFlight, t: float) -> None:
        """File one fault-injected extra delivery (full in-flight
        bookkeeping, but not counted as a dispatch)."""
        self.clock.push(t, fl)
        self._inflight_n[fl.client] += 1
        self.n_inflight += 1
        self.n_injected += 1

    def step(self) -> EventRecord:
        """Serve the next due event through the admission pipeline:
        duplicate/replay rejection, staleness screen, payload validation
        (quarantine), then the buffer."""
        t, _, f = self.clock.pop()
        self.n_inflight -= 1
        self._inflight_n[f.client] -= 1
        stal = self.version - f.sent_version
        if f.lost:
            self.n_lost += 1
            return EventRecord("lost", t, f.client, stal, f.dseq, f.sent_at,
                               f.sent_version, None)
        key = (f.client, f.dversion)
        if key in self._seen:
            self.n_duplicates += 1
            return EventRecord("duplicate", t, f.client, stal, f.dseq,
                               f.sent_at, f.sent_version, f.payload)
        self._seen.add(key)     # whatever happens next, this key is spent
        if stal > self.max_staleness:
            self.n_dropped += 1
            return EventRecord("drop", t, f.client, stal, f.dseq, f.sent_at,
                               f.sent_version, f.payload)
        if self.validator is not None:
            try:
                self.validator(f.payload)
            except WireDecodeError as e:
                self.n_quarantined += 1
                self.quarantine_log.append({
                    "t": t, "client": f.client, "dseq": f.dseq,
                    "reason": str(e)})
                return EventRecord("quarantine", t, f.client, stal, f.dseq,
                                   f.sent_at, f.sent_version, f.payload)
        rec = EventRecord("arrival", t, f.client, stal, f.dseq, f.sent_at,
                          f.sent_version, f.payload)
        self.buffer.append(rec)
        self.n_arrived += 1
        self.staleness_sum += stal
        return rec

    def take_round(self) -> List[EventRecord]:
        """Consume the buffer for one aggregation and bump the version.

        Returned oldest dispatch first (global dispatch order), the same
        convention as ``ArrivalSimulator.collect`` -- with K = cohort this
        makes the aggregation batch EXACTLY the dispatch batch, whatever
        order the arrivals raced in.
        """
        if not self.buffer:
            raise RuntimeError("take_round with an empty buffer: the server "
                               "only aggregates on arrivals")
        kept = sorted(self.buffer, key=lambda r: r.dseq)
        self.buffer = []
        self.version += 1
        self.last_agg_t = self.clock.now
        return kept

    def stats(self) -> dict:
        """Counters + rates for scenario smoke stats and dry-run records.

        Every rate is guarded against its zero denominator (a run with no
        served events, or a quiescent clock, reports 0.0 rates rather than
        dividing by zero).
        """
        def _rate(num, den):
            return num / den if den > 0 else 0.0

        now = self.clock.now
        served = (self.n_arrived + self.n_dropped + self.n_lost
                  + self.n_duplicates + self.n_quarantined)
        return {
            "aggregations": self.version,
            "dispatched": self.n_dispatched,
            "arrived": self.n_arrived,
            "dropped": self.n_dropped,
            "lost": self.n_lost,
            "duplicates": self.n_duplicates,
            "quarantined": self.n_quarantined,
            "injected": self.n_injected,
            "pending": self.n_inflight,
            "sim_time": now,
            # rate against the LAST aggregation's timestamp, not the full
            # clock: post-final-aggregation quiescent drain (advance_to)
            # advances the clock without aggregating and must not deflate
            # the rate
            "aggs_per_time": _rate(self.version, self.last_agg_t),
            "drop_rate": _rate(self.n_dropped + self.n_lost, served),
            "duplicate_rate": _rate(self.n_duplicates, served),
            "quarantine_rate": _rate(self.n_quarantined, served),
            "mean_staleness": _rate(self.staleness_sum, self.n_arrived),
        }


def _placeholder_validator(payload) -> None:
    """Admission validator for the model-free simulator: its payloads are
    opaque ``None`` placeholders, so the only detectable corruption is the
    fault layer's :class:`CorruptPayload` marker."""
    if isinstance(payload, CorruptPayload):
        raise WireDecodeError("opaque payload corrupted in transit")


def simulate_scenario(scenario: Union[str, Scenario], *, n_clients: int = 256,
                      cohort: int = 16, k_arrivals: Optional[int] = None,
                      concurrency: Optional[int] = None,
                      max_staleness: int = 4, aggregations: int = 8,
                      sampler: Union[str, ClientSampler] = "uniform",
                      faults: Union[str, FaultModel, None] = None,
                      seed: int = 0) -> dict:
    """Model-free event-loop run of one scenario: pure numpy, no payloads.

    Drives :class:`EventLoop` through ``aggregations`` K-arrival triggers
    with placeholder payloads and returns :meth:`EventLoop.stats` -- the
    per-scenario event statistics the dry-run records and the scenario
    smoke tests read.  ``faults`` layers a registered fault model on top
    (corrupted placeholders quarantine via the CorruptPayload marker).
    Deterministic in ``seed``.
    """
    scen = make_scenario(scenario)
    smp = make_sampler(sampler)
    fm = None if faults is None else make_fault(faults)
    k = int(k_arrivals) if k_arrivals else cohort
    conc = int(concurrency) if concurrency else max(k, cohort)
    loop = EventLoop(scen, n_clients, cohort=cohort, k_arrivals=k,
                     concurrency=conc, max_staleness=max_staleness, seed=seed,
                     faults=fm,
                     validator=None if fm is None else _placeholder_validator)
    rng = np.random.default_rng(seed + 7)               # sampler draws
    last_seen = np.zeros(n_clients, np.int64)
    seen = np.zeros(n_clients, bool)
    for _ in range(aggregations):
        cohorts = 0
        while not loop.ready():
            if loop.wants_dispatch:
                if cohorts >= _MAX_COHORTS_PER_AGG:
                    raise RuntimeError(
                        f"scenario {scen.name!r} starved the buffer: "
                        f"{cohorts} cohorts dispatched without reaching "
                        f"k_arrivals={k}")
                view = SamplerView(loop.version, last_seen, loop.inflight,
                                   seen)
                ids = np.asarray(smp.select(rng, view, cohort), np.int64)
                seen[ids] = True
                loop.dispatch(ids)
                cohorts += 1
            else:
                loop.step()
        for rec in loop.take_round():
            last_seen[rec.client] = loop.version
    return {"scenario": scen.name, **loop.stats()}


class EventDrivenTrainer(FederatedTrainer):
    """K-arrival-triggered (FedBuff-style) federated training.

    One ``run_round()`` = one aggregation: the event loop dispatches
    sampler-chosen cohorts whenever the in-flight pool has room, serves
    arrival/drop/lost events in time order, and the moment ``k_arrivals``
    updates sit in the buffer the codec's masked ``aggregate`` (or the
    fused ingest path) fires with each update weighted by its FedBuff
    staleness -- model versions behind, not rounds.  The two jitted phases
    are the synchronous trainer's own; clients encode against the model at
    dispatch time, exactly as the buffered trainer commits error feedback.

    With ``k_arrivals`` = cohort size (the default) and the default
    concurrency of one cohort, every aggregation consumes exactly one
    dispatch cohort in dispatch order and the trainer is bit-identical to
    :class:`FederatedTrainer` under ANY scenario that loses and drops
    nothing -- params, measured/analytic ledgers, ``wire_log``.

    Ledger semantics (the honest-accounting rules of the buffered trainer,
    per event): upstream bits bill at arrival AND at staleness-drop (the
    bytes reached the server) but never for lost/aborted updates;
    downstream ``UpdateCache`` sync cost bills per dispatched cohort at the
    next aggregation's measured per-update size.  ``event_log`` has one row
    per event; ``agg_log`` one per aggregation (arrived / dropped / lost /
    buffer staleness / simulation time).
    """

    def __init__(self, model, train, test, env: FedEnvironment, protocol,
                 tcfg: TrainerConfig = TrainerConfig(),
                 scenario: Union[str, Scenario] = "steady",
                 sampler: Union[str, ClientSampler] = "uniform",
                 k_arrivals: Optional[int] = None,
                 concurrency: Optional[int] = None, max_staleness: int = 8,
                 faults: Union[str, FaultModel, None] = None,
                 ckpt_path: Optional[str] = None, ckpt_every: int = 0):
        super().__init__(model, train, test, env, protocol, tcfg)
        self.scenario = make_scenario(scenario)
        self.sampler = make_sampler(sampler)
        self.faults = None if faults is None else make_fault(faults)
        self.ckpt_path = ckpt_path
        self.ckpt_every = int(ckpt_every)
        p = env.participants_per_round
        self.k_arrivals = int(k_arrivals) if k_arrivals else p
        self.concurrency = (int(concurrency) if concurrency
                            else max(self.k_arrivals, p))
        self.max_staleness = int(max_staleness)
        self._wire_payloads = self.ingest and self.protocol.wire_format
        self.loop = EventLoop(self.scenario, env.n_clients, cohort=p,
                              k_arrivals=self.k_arrivals,
                              concurrency=self.concurrency,
                              max_staleness=self.max_staleness,
                              seed=tcfg.seed + 2, faults=self.faults,
                              validator=self._validate_payload)
        self.n_dropped = 0
        self.n_lost = 0
        self.n_events_served = 0
        self.event_log: list[dict] = []
        self.agg_log: list[dict] = []
        self._billed: list[EventRecord] = []    # reached server, unledgered
        self._pending_down: list[np.ndarray] = []   # cohorts since last agg
        # rejected (duplicate/quarantined) arrivals: bits bill at the next
        # flush, but their payloads never join the aggregation buffer
        self._rejected_bits = 0.0
        self._rejected_n = 0

    # ----------------------------------------------------------- event side
    def _dispatch_cohort(self) -> None:
        """Sampler-chosen cohort: local SGD + encode against the CURRENT
        model (one jitted phase), then into the event queue."""
        proto = self.protocol
        p = self.env.participants_per_round
        view = SamplerView(self.round, self.last_seen, self.loop.inflight,
                           self.seen_mask)
        sel = np.asarray(self.sampler.select(self.rng, view, p), np.int64)
        self.seen_mask[sel] = True
        xs, ys = self._sample_batches(sel, proto.local_iters)
        msgs = self._dispatch(sel, xs, ys)
        if self._wire_payloads:
            batch = proto.encode_wire_batch(np.asarray(msgs), direction="up")
            payloads = [batch.message(i) for i in range(batch.n_msgs)]
        else:
            payloads = list(np.asarray(msgs))
        _, lost = self.loop.dispatch(sel, payloads)
        self._pending_down.append(sel)
        self.event_log.append({
            "kind": "dispatch", "t": self.loop.clock.now, "version": self.round,
            "clients": int(sel.size), "lost_in_flight": int(lost.sum())})

    def _validate_payload(self, payload) -> None:
        """Admission validation of one delivered payload; raises
        :class:`WireDecodeError` on every detectable corruption class."""
        if isinstance(payload, CorruptPayload):
            raise WireDecodeError("opaque payload corrupted in transit")
        if self._wire_payloads:
            self.protocol.validate_wire(payload, direction="up")
            return
        v = np.asarray(payload)
        if v.size != self.numel:
            raise WireDecodeError(
                f"dense payload has {v.size} elements, expected "
                f"{self.numel}")
        if not np.all(np.isfinite(v)):
            raise WireDecodeError("dense payload has non-finite values")

    def _record_event(self, ev: EventRecord) -> None:
        proto = self.protocol
        row = {"kind": ev.kind, "t": ev.t, "client": ev.client,
               "staleness": ev.staleness, "version": self.round}
        if ev.kind == "lost":
            self.n_lost += 1
            row["bits_up"] = 0.0                # bytes never reached the server
        elif ev.kind in ("duplicate", "quarantine"):
            # rejected at admission: the bytes DID reach the server, so the
            # upstream bits bill -- but the payload never aggregates (and a
            # corrupt/duplicate stream must not enter the wire log or the
            # dense re-encode stack), so it is ledgered separately
            if (self._wire_payloads and self.measure_bits
                    and not isinstance(ev.payload, CorruptPayload)):
                bits = float(proto.measured_message_bits(ev.payload))
            else:
                bits = proto.upload_bits(self.numel)
            self._rejected_bits += bits
            self._rejected_n += 1
            row["bits_up"] = bits
        else:
            self._billed.append(ev)
            if ev.kind == "drop":
                self.n_dropped += 1
            # exact per-event bits when the payload IS the wire stream;
            # dense-mode rounds measure the batch at the aggregation flush
            # (identical totals) and bill the analytic size per event here
            row["bits_up"] = (proto.measured_message_bits(ev.payload)
                              if self._wire_payloads and self.measure_bits
                              else proto.upload_bits(self.numel))
        self.event_log.append(row)

    def _serve_one(self) -> None:
        """Serve ONE event: fault-model kill check (BEFORE serving, so the
        last checkpoint is a consistent boundary), the admission pipeline,
        then the periodic crash-consistency checkpoint."""
        if self.faults is not None:
            self.faults.kill_check(self.n_events_served)
        self._record_event(self.loop.step())
        self.n_events_served += 1
        if (self.ckpt_path and self.ckpt_every
                and self.n_events_served % self.ckpt_every == 0):
            self.save_checkpoint(self.ckpt_path)

    # ------------------------------------------------------------ round API
    def run_round(self):
        """Advance the event loop to the next K-arrival aggregation."""
        loop = self.loop
        cohorts = 0
        while not loop.ready():
            if loop.wants_dispatch:
                if cohorts >= _MAX_COHORTS_PER_AGG:
                    raise RuntimeError(
                        f"scenario {self.scenario.name!r} starved the "
                        f"buffer: {cohorts} cohorts dispatched without "
                        f"reaching k_arrivals={self.k_arrivals}")
                self._dispatch_cohort()
                cohorts += 1
            else:
                self._serve_one()
        self._aggregate_round()

    def advance_to(self, t: float) -> int:
        """Serve every event due by simulation time ``t`` WITHOUT
        dispatching; aggregations still trigger whenever the buffer fills.
        Zero due events -- quiescence -- leaves params, codec state and
        every ledger untouched.  Returns the number of events served."""
        served = 0
        while len(self.loop.clock) and self.loop.clock.peek_time() <= t:
            self._serve_one()
            served += 1
            if self.loop.ready():
                self._aggregate_round()
        return served

    # ---------------------------------------------------------- aggregation
    def _aggregate_round(self) -> None:
        proto = self.protocol
        p = self.env.participants_per_round
        kept = self.loop.take_round()       # oldest dispatch first
        mask_k = np.ones(len(kept), np.float32)
        stal_k = np.asarray([r.staleness for r in kept], np.float32)
        if self.ingest:
            w = self._participation_weights_np(mask_k, stal_k)
            acc = proto.make_ingest(self.numel)
            for r, wi in zip(kept, w):
                if self._wire_payloads:
                    proto.ingest_wire(acc, r.payload, float(wi),
                                      direction="up")
                else:
                    proto.ingest_dense(acc, np.asarray(r.payload), float(wi))
            gd, self.server_state, _ = proto.aggregate_ingest(
                acc, self.server_state)
            gd = jnp.asarray(gd)
            self.params_vec = self.params_vec + gd
            gd_np = np.asarray(gd)
        else:
            # pad to a multiple of the cohort: stable jit shapes (== p in
            # the K = cohort configuration), zero-weight padding rows are
            # invisible to the masked aggregate
            kpad = p * math.ceil(len(kept) / p)
            buf = np.zeros((kpad, self.numel), np.float32)
            mask = np.zeros(kpad, np.float32)
            staleness = np.zeros(kpad, np.float32)
            for i, r in enumerate(kept):
                buf[i] = np.asarray(r.payload)
                mask[i] = 1.0
                staleness[i] = r.staleness
            gd_np = np.asarray(self._apply_update(jnp.asarray(buf), mask,
                                                  staleness))

        # ---- bit ledger: flush everything that reached the server --------
        # ``billed`` holds admitted payloads (arrivals + staleness drops);
        # rejected arrivals (duplicates / quarantined) accumulated their
        # bits in ``_rejected_bits`` at serve time -- billed here too, but
        # their payloads never touch the wire log or the dense re-encode
        billed, self._billed = self._billed, []
        rej_bits, self._rejected_bits = self._rejected_bits, 0.0
        rej_n, self._rejected_n = self._rejected_n, 0
        up_analytic = (len(billed) + rej_n) * proto.upload_bits(self.numel)
        per_update_analytic = proto.download_bits(self.numel,
                                                  n_participating=p)
        model_bits = 32.0 * self.numel
        if self.measure_bits and billed and self._wire_payloads:
            up = float(sum(proto.measured_message_bits(r.payload)
                           for r in billed)) + rej_bits
            down_msg = proto.encode_wire(gd_np, direction="down")
            per_update = proto.measured_message_bits(down_msg)
            self._log_wire_round([r.payload.nnz for r in billed], down_msg,
                                 up - rej_bits, per_update)
        elif self.measure_bits and billed:
            arr = np.stack([np.asarray(r.payload) for r in billed])
            batch = proto.encode_wire_batch(arr, direction="up")
            up = proto.measured_batch_bits(batch) + rej_bits
            down_msg = proto.encode_wire(gd_np, direction="down")
            per_update = proto.measured_message_bits(down_msg)
            self._log_wire_round(np.asarray(batch.nnz), down_msg,
                                 up - rej_bits, per_update)
        elif self.measure_bits:
            up = rej_bits
            down_msg = proto.encode_wire(gd_np, direction="down")
            per_update = proto.measured_message_bits(down_msg)
        else:
            up, per_update = up_analytic, per_update_analytic
        self.bits_up += up
        self.bits_up_analytic += up_analytic
        # downstream sync cost per dispatched cohort, in dispatch order --
        # cohorts may repeat a client, so last_seen commits between cohorts
        for sel in self._pending_down:
            skipped = self.round - self.last_seen[sel]
            self.bits_down += self.cache.sync_bits_batch(
                skipped, per_update, model_bits)
            self.bits_down_analytic += self.cache.sync_bits_batch(
                skipped, per_update_analytic, model_bits)
            self.last_seen[sel] = self.round
        self._pending_down = []
        self.cache.push(gd_np)
        stats = self.loop.stats()
        self.agg_log.append({
            "agg": self.loop.version, "t": self.loop.clock.now,
            "aggregated": len(kept), "billed": len(billed) + rej_n,
            "staleness_max": int(stal_k.max(initial=0.0)),
            "dropped_total": self.n_dropped, "lost_total": self.n_lost,
            "quarantined_total": stats["quarantined"],
            "duplicates_total": stats["duplicates"],
            "pending": stats["pending"],
        })
        self.round += 1

    def _history_extra(self) -> dict:
        now = self.loop.clock.now
        last = self.agg_log[-1] if self.agg_log else {}
        last_agg = self.loop.last_agg_t      # drain must not deflate the rate
        return {"n_dropped": self.n_dropped, "n_lost": self.n_lost,
                "n_quarantined": self.loop.n_quarantined,
                "n_duplicates": self.loop.n_duplicates,
                "sim_time": now,
                "aggs_per_time": (self.round / last_agg
                                  if last_agg > 0 else 0.0),
                "pending": self.loop.n_inflight,
                "aggregated": last.get("aggregated", 0)}

    # ------------------------------------------------ crash-consistent resume
    def save_checkpoint(self, path: str) -> None:
        """Write EVERY mutable piece of the trainer + event loop (model,
        per-client states, RNG streams, event clock with its in-flight
        payloads, admission state, ledgers, logs) so a fresh identically-
        configured trainer resumes bit-identically mid-round.  Written
        atomically (tempfile + rename), so a kill DURING the write leaves
        the previous checkpoint intact."""
        loop = self.loop
        save_state(path, {
            "base": self._base_state(),
            "loop": {
                "heap": list(loop.clock._heap),
                "clock_seq": loop.clock._seq,
                "now": loop.clock.now,
                "rng": loop.rng.bit_generator.state,
                "version": loop.version,
                "last_agg_t": loop.last_agg_t,
                "buffer": list(loop.buffer),
                "inflight_n": loop._inflight_n.copy(),
                "n_inflight": loop.n_inflight,
                "dseq": loop._dseq,
                "dispatch_count": loop._dispatch_count.copy(),
                "seen": loop._seen,
                "last_sent": loop._last_sent,
                "quarantine_log": list(loop.quarantine_log),
                "counters": [loop.n_dispatched, loop.n_arrived,
                             loop.n_dropped, loop.n_lost, loop.n_duplicates,
                             loop.n_quarantined, loop.n_injected,
                             loop.staleness_sum],
            },
            "trainer": {
                "n_dropped": self.n_dropped,
                "n_lost": self.n_lost,
                "n_events_served": self.n_events_served,
                "event_log": list(self.event_log),
                "agg_log": list(self.agg_log),
                "billed": list(self._billed),
                "pending_down": [np.asarray(s) for s in self._pending_down],
                "rejected": [self._rejected_bits, self._rejected_n],
            },
        })

    def restore_checkpoint(self, path: str) -> None:
        """Inverse of :meth:`save_checkpoint` into an identically-configured
        trainer (same model/env/protocol/scenario/sampler/seed; the fault
        model MAY differ -- resume a killed run with ``faults="none"``)."""
        st = restore_state(path, classes=_CKPT_CLASSES)
        self._load_base_state(st["base"])
        ls = st["loop"]
        loop = self.loop
        loop.clock._heap = list(ls["heap"])     # heap order is preserved
        loop.clock._seq = int(ls["clock_seq"])
        loop.clock.now = float(ls["now"])
        loop.rng.bit_generator.state = ls["rng"]
        loop.version = int(ls["version"])
        # pre-fix checkpoints carry no last_agg_t; the clock position is the
        # closest available stand-in (matches their old full-clock rate)
        loop.last_agg_t = float(ls.get("last_agg_t", ls["now"]))
        loop.buffer = list(ls["buffer"])
        loop._inflight_n = np.asarray(ls["inflight_n"], np.int32).copy()
        loop.n_inflight = int(ls["n_inflight"])
        loop._dseq = int(ls["dseq"])
        loop._dispatch_count = np.asarray(ls["dispatch_count"],
                                          np.int64).copy()
        loop._seen = set(ls["seen"])
        loop._last_sent = dict(ls["last_sent"])
        loop.quarantine_log = list(ls["quarantine_log"])
        (loop.n_dispatched, loop.n_arrived, loop.n_dropped, loop.n_lost,
         loop.n_duplicates, loop.n_quarantined, loop.n_injected,
         loop.staleness_sum) = [int(c) for c in ls["counters"]]
        tr = st["trainer"]
        self.n_dropped = int(tr["n_dropped"])
        self.n_lost = int(tr["n_lost"])
        self.n_events_served = int(tr["n_events_served"])
        self.event_log = list(tr["event_log"])
        self.agg_log = list(tr["agg_log"])
        self._billed = list(tr["billed"])
        self._pending_down = [np.asarray(s, np.int64)
                              for s in tr["pending_down"]]
        self._rejected_bits = float(tr["rejected"][0])
        self._rejected_n = int(tr["rejected"][1])


# NamedTuple classes the tagged checkpoint codec must be able to rebuild
# (payloads in the clock/buffer/billed lists, codec residual states).
_CKPT_CLASSES = {c.__name__: c for c in (
    _InFlight, EventRecord, WireMessage, WireBatch, ChunkedWireBatch,
    ChunkedWireMessage, CorruptPayload, ResidualState)}
