"""Registered fault models: WHAT goes wrong with updates (and the server).

:mod:`repro.fed.scenarios` models when updates arrive; this module models
what arrives -- and whether the processes at either end survive.  Each fault
model is a named, seeded generator of per-dispatch failures layered on top
of any scenario: bit flips in the Golomb word stream, payload truncation,
duplicate delivery, stale replay of an earlier dispatch, client crashes
mid-dispatch, and a server kill at a chosen event index.  The event loop
(:mod:`repro.fed.events`) applies the faults at dispatch time and defends
against them at admission time (quarantine on :class:`WireDecodeError`,
duplicate/replay rejection keyed on ``(client, dispatch_version)``).

Determinism contract: every fault decision for the dispatch with global
sequence number ``dseq`` is drawn from ``rng(dseq)`` -- a counter-based
generator keyed on ``(salt, model seed, dseq)`` alone.  Faults therefore
never consume the event loop's latency RNG (a no-fault run is bit-identical
to a run with ``faults=None``), need no stream state in checkpoints, and any
scenario x fault combination replays exactly from the seeds.

The registry mirrors ``repro.fed.scenarios``: ``register_fault`` /
``make_fault(name, **overrides)`` / ``registered_faults()``.  A custom fault
is a frozen dataclass subclassing :class:`FaultModel` and overriding any of
the per-dispatch hooks (``crash`` / ``corrupt`` / ``duplicate`` /
``replay``) or the per-event ``kill_check``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np

from repro.core.wire import WireMessage

__all__ = ["FaultModel", "NoFault", "BitFlipFault", "TruncateFault",
           "DuplicateFault", "ReplayFault", "ClientCrashFault",
           "ServerKillFault", "ServerKilled", "CorruptPayload",
           "register_fault", "make_fault", "registered_faults"]


class ServerKilled(RuntimeError):
    """The fault model killed the server process at a chosen event index.

    Raised by :class:`ServerKillFault` BEFORE the event is served, so a
    checkpoint written at the previous event boundary is consistent; catch
    it, restore from the checkpoint and continue (see
    ``EventDrivenTrainer.restore_checkpoint``).
    """


class CorruptPayload(NamedTuple):
    """Marker wrapping a payload corrupted past structural recognition.

    Used for opaque payloads (the model-free simulator's ``None``
    placeholders, or message types the byte-level corruptors do not
    understand) so admission control still sees -- and quarantines -- a
    deterministic corruption event.
    """

    original: object


_REGISTRY: dict[str, type["FaultModel"]] = {}

# Mixed into every per-dispatch generator key so fault draws can never
# collide with any other seeded stream in the repo.
_FAULT_SALT = 0x5EEDFA17


def register_fault(cls=None, *, name: Optional[str] = None):
    """Class decorator adding a fault model to the registry under
    ``cls.name``."""
    def _register(c):
        key = name or getattr(c, "name", None)
        if not key:
            raise ValueError(f"fault model {c.__name__} needs a `name`")
        _REGISTRY[key] = c
        return c
    return _register(cls) if cls is not None else _register


def registered_faults() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_fault(name: str, **overrides) -> "FaultModel":
    """Instantiate a registered fault model by name (loud on unknowns)."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown fault model {name!r}; registered: "
                       f"{', '.join(registered_faults())}")
    return _REGISTRY[name](**overrides)


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Base fault model: nothing ever goes wrong (every hook is neutral).

    The event loop calls :meth:`rng` once per dispatched message and feeds
    the SAME generator through the per-dispatch hooks in a fixed order
    (``crash`` -> ``corrupt`` -> ``duplicate`` -> ``replay``), so each
    model's failure pattern is a pure function of ``(seed, dseq)``.
    ``kill_check(n_served)`` runs once per served event on the trainer side.
    """

    name = "none"
    seed: int = 0

    def rng(self, dseq: int) -> np.random.Generator:
        """The counter-based generator owning dispatch ``dseq``'s draws."""
        return np.random.default_rng((_FAULT_SALT, self.seed, int(dseq)))

    # -- per-dispatch hooks --------------------------------------------------
    def crash(self, rng: np.random.Generator) -> bool:
        """True: the client dies mid-dispatch; the update never arrives."""
        return False

    def corrupt(self, payload, rng: np.random.Generator):
        """Return the payload as delivered (possibly mangled in transit)."""
        return payload

    def duplicate(self, rng: np.random.Generator) -> bool:
        """True: the network delivers a second copy of this dispatch."""
        return False

    def replay(self, rng: np.random.Generator) -> bool:
        """True: a stale copy of the client's PREVIOUS dispatch is
        re-delivered alongside this one."""
        return False

    # -- per-event hook (server side) ----------------------------------------
    def kill_check(self, n_served: int) -> None:
        """Raise :class:`ServerKilled` to kill the server before serving
        event index ``n_served``."""


@register_fault
@dataclasses.dataclass(frozen=True)
class NoFault(FaultModel):
    """The explicit no-op entry: chaos sweeps use it as their baseline row."""

    name = "none"


def _corrupt_opaque(payload) -> CorruptPayload:
    return (payload if isinstance(payload, CorruptPayload)
            else CorruptPayload(payload))


@register_fault
@dataclasses.dataclass(frozen=True)
class BitFlipFault(FaultModel):
    """Random bit flips inside the packed word stream (memory/link errors).

    With probability ``prob`` per dispatch, ``n_bits`` uniformly chosen bits
    of the message's uint32 words are XOR-flipped.  Flips that land in a
    coded field typically break the Golomb parse (quarantined at admission);
    flips in the word padding or that yield another VALID stream are
    semantically undetectable without checksums -- the quarantine rate under
    this fault is therefore below the injection rate by construction.  Dense
    ndarray payloads are poisoned with NaNs instead (caught by the trainer's
    finiteness screen); opaque payloads get the :class:`CorruptPayload`
    marker.
    """

    name = "bit-flip"
    prob: float = 0.3
    n_bits: int = 4

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(
                f"BitFlipFault.prob must be in [0, 1], got {self.prob}")
        if self.n_bits < 1:
            raise ValueError(
                f"BitFlipFault.n_bits must be >= 1, got {self.n_bits}")

    def corrupt(self, payload, rng):
        if rng.random() >= self.prob:
            return payload
        if isinstance(payload, WireMessage):
            words = np.asarray(payload.words)
            if words.size == 0:
                # nothing to flip: advertise bits the empty buffer cannot
                # hold (the _check_bit_len class of corruption)
                return payload._replace(bit_len=int(payload.bit_len) + 8)
            w = words.copy()
            idx = rng.integers(0, w.size, self.n_bits)
            bit = rng.integers(0, 32, self.n_bits).astype(np.uint32)
            np.bitwise_xor.at(w, idx, np.uint32(1) << bit)
            return payload._replace(words=w)
        if isinstance(payload, np.ndarray):
            v = np.array(payload, copy=True)
            idx = rng.integers(0, max(v.size, 1), self.n_bits)
            v.reshape(-1)[idx[idx < v.size]] = np.nan
            return v
        return _corrupt_opaque(payload)


@register_fault
@dataclasses.dataclass(frozen=True)
class TruncateFault(FaultModel):
    """Payload truncation: the tail of the word buffer is cut in transit
    while the advertised ``bit_len`` still claims the full stream -- the
    classic partial-read corruption.  Always structurally detectable
    (``bit_len`` overruns the delivered words), so every truncated payload
    quarantines."""

    name = "truncate"
    prob: float = 0.3

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(
                f"TruncateFault.prob must be in [0, 1], got {self.prob}")

    def corrupt(self, payload, rng):
        if rng.random() >= self.prob:
            return payload
        if isinstance(payload, WireMessage):
            if payload.bit_len == 0:
                return payload          # nothing on the wire to cut
            words = np.asarray(payload.words)
            return payload._replace(words=words[: words.size // 2].copy())
        if isinstance(payload, np.ndarray):
            flat = np.asarray(payload).reshape(-1)
            return np.array(flat[: max(flat.size // 2, 1)], copy=True)
        return _corrupt_opaque(payload)


@register_fault
@dataclasses.dataclass(frozen=True)
class DuplicateFault(FaultModel):
    """Duplicate delivery: with probability ``prob`` the network delivers a
    second, later copy of the same dispatch.  Admission control must reject
    the second copy (same ``(client, dispatch_version)`` key) while still
    billing its upstream bits."""

    name = "duplicate"
    prob: float = 0.3

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(
                f"DuplicateFault.prob must be in [0, 1], got {self.prob}")

    def duplicate(self, rng):
        return bool(rng.random() < self.prob)


@register_fault
@dataclasses.dataclass(frozen=True)
class ReplayFault(FaultModel):
    """Stale replay: with probability ``prob`` a copy of the client's
    PREVIOUS dispatch (older payload, older model version) is re-delivered.
    If the original already arrived, the replay is a duplicate by key; if
    the original was lost, the replay carries genuinely stale data and runs
    the normal staleness screen."""

    name = "replay"
    prob: float = 0.3

    def replay(self, rng):
        return bool(rng.random() < self.prob)

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(
                f"ReplayFault.prob must be in [0, 1], got {self.prob}")


@register_fault
@dataclasses.dataclass(frozen=True)
class ClientCrashFault(FaultModel):
    """Client crash mid-dispatch: the local step ran (client state advanced,
    battery drained) but the upload never happens -- indistinguishable from
    network loss at the server, billed zero bits."""

    name = "client-crash"
    prob: float = 0.3

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(
                f"ClientCrashFault.prob must be in [0, 1], got {self.prob}")

    def crash(self, rng):
        return bool(rng.random() < self.prob)


@register_fault
@dataclasses.dataclass(frozen=True)
class ServerKillFault(FaultModel):
    """Kill the server before serving event index ``at_event`` (0-based
    count of served events).  The trainer raises :class:`ServerKilled` at
    that boundary; resume from the last checkpoint with ``faults="none"``
    (or a later ``at_event``) and the run continues bit-identically."""

    name = "server-kill"
    at_event: int = 40

    def __post_init__(self):
        if self.at_event < 0:
            raise ValueError(
                f"ServerKillFault.at_event must be >= 0, got {self.at_event}")

    def kill_check(self, n_served):
        if n_served >= self.at_event:
            raise ServerKilled(
                f"server killed before event {n_served} "
                f"(at_event={self.at_event})")
