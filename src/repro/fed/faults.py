"""Registered fault models: WHAT goes wrong with updates (and the server).

:mod:`repro.fed.scenarios` models when updates arrive; this module models
what arrives -- and whether the processes at either end survive.  Each fault
model is a named, seeded generator of per-dispatch failures layered on top
of any scenario: bit flips in the Golomb word stream, payload truncation,
duplicate delivery, stale replay of an earlier dispatch, client crashes
mid-dispatch, and a server kill at a chosen event index.  The event loop
(:mod:`repro.fed.events`) applies the faults at dispatch time and defends
against them at admission time (quarantine on :class:`WireDecodeError`,
duplicate/replay rejection keyed on ``(client, dispatch_version)``).

Byzantine valid-update adversaries (:class:`SignFlipFault`,
:class:`ScaleAttackFault`, :class:`CollusionFault`) are the complement:
their payloads pass every admission check BY CONSTRUCTION, so the only
defense is a robust aggregation rule (:mod:`repro.core.aggregation`) --
``benchmarks/robust_bench.py`` sweeps exactly that matchup.

Determinism contract: every fault decision for the dispatch with global
sequence number ``dseq`` is drawn from ``rng(dseq)`` -- a counter-based
generator keyed on ``(salt, model seed, dseq)`` alone.  Faults therefore
never consume the event loop's latency RNG (a no-fault run is bit-identical
to a run with ``faults=None``), need no stream state in checkpoints, and any
scenario x fault combination replays exactly from the seeds.

The registry mirrors ``repro.fed.scenarios``: ``register_fault`` /
``make_fault(name, **overrides)`` / ``registered_faults()``.  A custom fault
is a frozen dataclass subclassing :class:`FaultModel` and overriding any of
the per-dispatch hooks (``crash`` / ``corrupt`` / ``duplicate`` /
``replay``) or the per-event ``kill_check``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import numpy as np

from repro.core import registry as _registry
from repro.core.wire import ChunkedWireMessage, WireMessage
from repro.fed.scenarios import _hash_frac

__all__ = ["FaultModel", "NoFault", "BitFlipFault", "TruncateFault",
           "DuplicateFault", "ReplayFault", "ClientCrashFault",
           "ServerKillFault", "ServerKilled", "CorruptPayload",
           "ByzantineFault", "SignFlipFault", "ScaleAttackFault",
           "CollusionFault",
           "register_fault", "make_fault", "registered_faults"]


class ServerKilled(RuntimeError):
    """The fault model killed the server process at a chosen event index.

    Raised by :class:`ServerKillFault` BEFORE the event is served, so a
    checkpoint written at the previous event boundary is consistent; catch
    it, restore from the checkpoint and continue (see
    ``EventDrivenTrainer.restore_checkpoint``).
    """


class CorruptPayload(NamedTuple):
    """Marker wrapping a payload corrupted past structural recognition.

    Used for opaque payloads (the model-free simulator's ``None``
    placeholders, or message types the byte-level corruptors do not
    understand) so admission control still sees -- and quarantines -- a
    deterministic corruption event.
    """

    original: object


_REGISTRY: dict[str, type["FaultModel"]] = {}

# Mixed into every per-dispatch generator key so fault draws can never
# collide with any other seeded stream in the repo.
_FAULT_SALT = 0x5EEDFA17


def register_fault(cls=None, *, name: Optional[str] = None):
    """Class decorator adding a fault model to the registry under
    ``cls.name``."""
    def _register(c):
        key = name or getattr(c, "name", None)
        if not key:
            raise ValueError(f"fault model {c.__name__} needs a `name`")
        _REGISTRY[key] = c
        return c
    return _register(cls) if cls is not None else _register


def registered_faults() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_fault(fault, **overrides) -> "FaultModel":
    """Instantiate a registered fault model by name (loud on unknowns),
    or pass a :class:`FaultModel` instance through untouched."""
    return _registry.resolve("fault model", fault, _REGISTRY, FaultModel,
                             **overrides)


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Base fault model: nothing ever goes wrong (every hook is neutral).

    The event loop calls :meth:`rng` once per dispatched message and feeds
    the SAME generator through the per-dispatch hooks in a fixed order
    (``crash`` -> ``byzantine`` -> ``corrupt`` -> ``duplicate`` ->
    ``replay``), so each model's failure pattern is a pure function of
    ``(seed, dseq)``.  ``kill_check(n_served)`` runs once per served event
    on the trainer side.
    """

    name = "none"
    seed: int = 0

    def rng(self, dseq: int) -> np.random.Generator:
        """The counter-based generator owning dispatch ``dseq``'s draws."""
        return np.random.default_rng((_FAULT_SALT, self.seed, int(dseq)))

    # -- per-dispatch hooks --------------------------------------------------
    def crash(self, rng: np.random.Generator) -> bool:
        """True: the client dies mid-dispatch; the update never arrives."""
        return False

    def byzantine(self, payload, client: int, rng: np.random.Generator):
        """Adversarial VALID-update rewrite: a Byzantine client replaces its
        honest payload with a poisoned one that still passes every admission
        check (``validate_wire``, size, finiteness) by construction -- only
        the aggregation rule can defend.  Runs before :meth:`corrupt` (the
        adversary crafts the bytes; transit may then mangle them like any
        honest message).  The base model consumes NO rng draws here, so
        adding the hook left every existing fault trace bit-identical."""
        return payload

    def corrupt(self, payload, rng: np.random.Generator):
        """Return the payload as delivered (possibly mangled in transit)."""
        return payload

    def duplicate(self, rng: np.random.Generator) -> bool:
        """True: the network delivers a second copy of this dispatch."""
        return False

    def replay(self, rng: np.random.Generator) -> bool:
        """True: a stale copy of the client's PREVIOUS dispatch is
        re-delivered alongside this one."""
        return False

    # -- per-event hook (server side) ----------------------------------------
    def kill_check(self, n_served: int) -> None:
        """Raise :class:`ServerKilled` to kill the server before serving
        event index ``n_served``."""


@register_fault
@dataclasses.dataclass(frozen=True)
class NoFault(FaultModel):
    """The explicit no-op entry: chaos sweeps use it as their baseline row."""

    name = "none"


def _corrupt_opaque(payload) -> CorruptPayload:
    return (payload if isinstance(payload, CorruptPayload)
            else CorruptPayload(payload))


@register_fault
@dataclasses.dataclass(frozen=True)
class BitFlipFault(FaultModel):
    """Random bit flips inside the packed word stream (memory/link errors).

    With probability ``prob`` per dispatch, ``n_bits`` uniformly chosen bits
    of the message's uint32 words are XOR-flipped.  Flips that land in a
    coded field typically break the Golomb parse (quarantined at admission);
    flips in the word padding or that yield another VALID stream are
    semantically undetectable without checksums -- the quarantine rate under
    this fault is therefore below the injection rate by construction.  Dense
    ndarray payloads are poisoned with NaNs instead (caught by the trainer's
    finiteness screen); opaque payloads get the :class:`CorruptPayload`
    marker.
    """

    name = "bit-flip"
    prob: float = 0.3
    n_bits: int = 4

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(
                f"BitFlipFault.prob must be in [0, 1], got {self.prob}")
        if self.n_bits < 1:
            raise ValueError(
                f"BitFlipFault.n_bits must be >= 1, got {self.n_bits}")

    def corrupt(self, payload, rng):
        if rng.random() >= self.prob:
            return payload
        if isinstance(payload, WireMessage):
            words = np.asarray(payload.words)
            if words.size == 0:
                # nothing to flip: advertise bits the empty buffer cannot
                # hold (the _check_bit_len class of corruption)
                return payload._replace(bit_len=int(payload.bit_len) + 8)
            w = words.copy()
            idx = rng.integers(0, w.size, self.n_bits)
            bit = rng.integers(0, 32, self.n_bits).astype(np.uint32)
            np.bitwise_xor.at(w, idx, np.uint32(1) << bit)
            return payload._replace(words=w)
        if isinstance(payload, np.ndarray):
            v = np.array(payload, copy=True)
            idx = rng.integers(0, max(v.size, 1), self.n_bits)
            v.reshape(-1)[idx[idx < v.size]] = np.nan
            return v
        return _corrupt_opaque(payload)


@register_fault
@dataclasses.dataclass(frozen=True)
class TruncateFault(FaultModel):
    """Payload truncation: the tail of the word buffer is cut in transit
    while the advertised ``bit_len`` still claims the full stream -- the
    classic partial-read corruption.  Always structurally detectable
    (``bit_len`` overruns the delivered words), so every truncated payload
    quarantines."""

    name = "truncate"
    prob: float = 0.3

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(
                f"TruncateFault.prob must be in [0, 1], got {self.prob}")

    def corrupt(self, payload, rng):
        if rng.random() >= self.prob:
            return payload
        if isinstance(payload, WireMessage):
            if payload.bit_len == 0:
                return payload          # nothing on the wire to cut
            words = np.asarray(payload.words)
            return payload._replace(words=words[: words.size // 2].copy())
        if isinstance(payload, np.ndarray):
            flat = np.asarray(payload).reshape(-1)
            return np.array(flat[: max(flat.size // 2, 1)], copy=True)
        return _corrupt_opaque(payload)


@register_fault
@dataclasses.dataclass(frozen=True)
class DuplicateFault(FaultModel):
    """Duplicate delivery: with probability ``prob`` the network delivers a
    second, later copy of the same dispatch.  Admission control must reject
    the second copy (same ``(client, dispatch_version)`` key) while still
    billing its upstream bits."""

    name = "duplicate"
    prob: float = 0.3

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(
                f"DuplicateFault.prob must be in [0, 1], got {self.prob}")

    def duplicate(self, rng):
        return bool(rng.random() < self.prob)


@register_fault
@dataclasses.dataclass(frozen=True)
class ReplayFault(FaultModel):
    """Stale replay: with probability ``prob`` a copy of the client's
    PREVIOUS dispatch (older payload, older model version) is re-delivered.
    If the original already arrived, the replay is a duplicate by key; if
    the original was lost, the replay carries genuinely stale data and runs
    the normal staleness screen."""

    name = "replay"
    prob: float = 0.3

    def replay(self, rng):
        return bool(rng.random() < self.prob)

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(
                f"ReplayFault.prob must be in [0, 1], got {self.prob}")


@register_fault
@dataclasses.dataclass(frozen=True)
class ClientCrashFault(FaultModel):
    """Client crash mid-dispatch: the local step ran (client state advanced,
    battery drained) but the upload never happens -- indistinguishable from
    network loss at the server, billed zero bits."""

    name = "client-crash"
    prob: float = 0.3

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(
                f"ClientCrashFault.prob must be in [0, 1], got {self.prob}")

    def crash(self, rng):
        return bool(rng.random() < self.prob)


@register_fault
@dataclasses.dataclass(frozen=True)
class ServerKillFault(FaultModel):
    """Kill the server before serving event index ``at_event`` (0-based
    count of served events).  The trainer raises :class:`ServerKilled` at
    that boundary; resume from the last checkpoint with ``faults="none"``
    (or a later ``at_event``) and the run continues bit-identically."""

    name = "server-kill"
    at_event: int = 40

    def __post_init__(self):
        if self.at_event < 0:
            raise ValueError(
                f"ServerKillFault.at_event must be >= 0, got {self.at_event}")

    def kill_check(self, n_served):
        if n_served >= self.at_event:
            raise ServerKilled(
                f"server killed before event {n_served} "
                f"(at_event={self.at_event})")


# ---------------------------------------------------------------------------
# Byzantine valid-update adversaries: payloads the admission pipeline CANNOT
# catch (they parse, size-check and finite-check like honest updates); only
# the aggregation rule (repro.core.aggregation) defends.
# ---------------------------------------------------------------------------


def _rewrite_valid(payload, factor: float):
    """Multiply an update payload by ``factor`` while keeping it VALID for
    every admission check -- the shared mechanics of the Byzantine models.

    Dense ndarrays scale directly.  A ternary wire stream (STC / chunked
    STC) scales through its µ header(s): the Golomb position words are
    untouched, so the stream still parses, and the decoder multiplies every
    surviving coordinate by the poisoned µ.  A dense sign plane (signSGD,
    ``bit_len == numel``) carries no magnitude at all: a negative factor
    inverts every sign bit (the strongest rewrite the format admits), a
    positive one is a no-op -- majority-vote formats are scale-immune by
    construction.  Opaque payloads (the model-free simulator's ``None``
    placeholders) pass through untouched: there is nothing semantic to
    poison, and wrapping them would trip quarantine, which a Byzantine
    client never does."""
    if isinstance(payload, np.ndarray):
        return np.asarray(payload, np.float32) * np.float32(factor)
    if isinstance(payload, WireMessage):
        if int(payload.bit_len) == int(payload.numel):   # dense sign plane
            if factor >= 0:
                return payload
            return payload._replace(
                words=np.bitwise_not(np.asarray(payload.words)))
        return payload._replace(mu=float(payload.mu) * float(factor))
    if isinstance(payload, ChunkedWireMessage):
        b = payload.batch
        flipped = tuple(sb._replace(mu=np.asarray(sb.mu, np.float64)
                                    * float(factor))
                        for sb in b.batches)
        return ChunkedWireMessage(b._replace(batches=flipped))
    return payload


@dataclasses.dataclass(frozen=True)
class ByzantineFault(FaultModel):
    """Base for valid-update adversaries: a deterministic ``fraction`` of
    the client population is Byzantine, membership hashed from the client
    id alone (the same Knuth-hash trick as the scenario subpopulations), so
    WHO is compromised is stable across dispatches, draw order and
    platforms -- a colluding cohort, not independent coin flips.  Every
    dispatch from a compromised client is rewritten via :meth:`attack`.
    Not registered itself: subclasses define the attack."""

    fraction: float = 0.2

    def __post_init__(self):
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(
                f"{type(self).__name__}.fraction must be in [0, 1], "
                f"got {self.fraction}")

    def is_byzantine(self, client: int) -> bool:
        return bool(_hash_frac(np.asarray([client]))[0] < self.fraction)

    def byzantine(self, payload, client, rng):
        if not self.is_byzantine(client):
            return payload
        return self.attack(payload, client, rng)

    def attack(self, payload, client: int, rng: np.random.Generator):
        raise NotImplementedError(type(self).__name__)


@register_fault
@dataclasses.dataclass(frozen=True)
class SignFlipFault(ByzantineFault):
    """Gradient-reversal attack: compromised clients send ``-scale`` times
    their honest update.  With ``scale=1`` the payload norm is exactly
    honest (no norm screen can see it); larger scales amplify the damage
    but become norm-screenable -- the classic robustness trade-off the
    robust bench sweeps."""

    name = "sign-flip"
    scale: float = 1.0

    def attack(self, payload, client, rng):
        return _rewrite_valid(payload, -self.scale)


@register_fault
@dataclasses.dataclass(frozen=True)
class ScaleAttackFault(ByzantineFault):
    """Overscaling attack: compromised clients send ``factor`` times their
    honest update -- right direction, poisoned step size.  The cheapest
    attack to mount and the one ``norm_screened_mean`` exists to stop."""

    name = "scale-attack"
    factor: float = 100.0

    def attack(self, payload, client, rng):
        return _rewrite_valid(payload, self.factor)


@register_fault
@dataclasses.dataclass(frozen=True)
class CollusionFault(ByzantineFault):
    """Colluding cohort: every compromised client sends ``scale`` times its
    honest norm along ONE common poisoned direction (seeded from the model
    seed, NOT the dispatch counter -- all colluders push the same way, which
    is what defeats per-message norm screening at ``scale=1`` and shifts a
    mean by the full colluding weight mass).  Wire-format payloads cannot
    carry an arbitrary direction without re-encoding through the codec, so
    there the colluders fall back to the coordinated amplified sign-flip of
    their own updates (documented approximation; the dense event path
    mounts the full attack)."""

    name = "collusion"
    scale: float = 1.0

    def attack(self, payload, client, rng):
        if isinstance(payload, np.ndarray):
            v = np.asarray(payload, np.float32).reshape(-1)
            d = _collusion_direction(self.seed, v.size)
            out = (np.float32(self.scale)
                   * np.float32(np.linalg.norm(v))) * d
            return out.reshape(np.shape(payload))
        return _rewrite_valid(payload, -self.scale)


@functools.lru_cache(maxsize=8)
def _collusion_direction(seed: int, numel: int) -> np.ndarray:
    """The colluders' common unit direction -- a pure function of the model
    seed and payload size (cached: one draw per fleet, not per dispatch)."""
    g = np.random.default_rng((_FAULT_SALT ^ 0xC0111DE, seed, numel))
    d = g.standard_normal(numel)
    n = np.linalg.norm(d)
    return (d / (n if n > 0 else 1.0)).astype(np.float32)
