"""Registered fleet scenarios: WHEN updates arrive (and whether they do).

:mod:`repro.fed.arrivals` models a stationary fleet -- one lognormal
latency distribution, forever.  Real fleets are nothing like that: load is
diurnal, crowds flash, a region drops off the map for an hour, chronic
stragglers drift slower as their batteries age, and clients themselves give
up on uploads that exceed their personal deadline.  Each scenario here is a
named, seeded generator layered on :class:`LatencyModel`: it turns a
dispatch at simulation time ``t`` into per-client latencies plus a lost
mask (updates that never reach the server -- no bits billed).  The
event-driven trainer (:mod:`repro.fed.events`), the model-free simulator
and ``benchmarks/async_bench.py --scenario`` all drive the same objects.

The registry mirrors ``repro.core.protocols``: ``register_scenario`` /
``make_scenario(name, **overrides)`` / ``registered_scenarios()``.  A
custom scenario is a frozen dataclass subclassing :class:`Scenario` and
overriding any of the three hooks (``latency_scale``, ``loss_prob``,
``client_factors``) or ``client_deadline`` -- see the README for a
15-line example.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core import registry as _registry
from repro.fed.arrivals import LatencyModel

__all__ = ["Scenario", "SteadyScenario", "DiurnalScenario",
           "FlashCrowdScenario", "RegionalOutageScenario",
           "StragglerDriftScenario", "AdaptiveDeadlineScenario",
           "ComposedScenario", "FlashOutageScenario",
           "register_scenario", "make_scenario", "registered_scenarios"]


_REGISTRY: dict[str, type["Scenario"]] = {}


def register_scenario(cls=None, *, name: Optional[str] = None):
    """Class decorator adding a scenario to the registry under ``cls.name``."""
    def _register(c):
        key = name or getattr(c, "name", None)
        if not key:
            raise ValueError(f"scenario {c.__name__} needs a `name`")
        _REGISTRY[key] = c
        return c
    return _register(cls) if cls is not None else _register


def registered_scenarios() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_scenario(scenario, **overrides) -> "Scenario":
    """Instantiate a registered scenario by name (loud on unknown names),
    or pass a :class:`Scenario` instance through untouched."""
    return _registry.resolve("scenario", scenario, _REGISTRY, Scenario,
                             **overrides)


def _hash_frac(ids: np.ndarray) -> np.ndarray:
    """Deterministic per-client uniform in [0, 1) from the client id alone
    (Knuth multiplicative hash) -- membership in a scenario subpopulation
    must not depend on draw order or platform."""
    h = (np.asarray(ids, np.uint64) * np.uint64(2654435761)) % np.uint64(1 << 32)
    return h.astype(np.float64) / float(1 << 32)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Base scenario: a stationary fleet (every hook is neutral).

    ``sample(t, client_ids, scales, rng)`` is the one entry point drivers
    call: latencies are ``LatencyModel`` draws scaled by the global
    ``latency_scale(t)`` and the per-client ``client_factors(t, ids)``;
    ``lost`` marks updates that never reach the server -- dropped in the
    network with probability ``loss_prob(t, ids)``, or aborted client-side
    when the draw exceeds ``client_deadline(ids, scales)``.
    """

    name = "steady"
    latency: LatencyModel = LatencyModel()

    # -- hooks ---------------------------------------------------------------
    def latency_scale(self, t: float) -> float:
        """Global (fleet-wide) latency multiplier at simulation time t."""
        return 1.0

    def client_factors(self, t: float, ids: np.ndarray) -> np.ndarray:
        """Per-client latency multipliers at time t (drift effects)."""
        return np.ones(np.asarray(ids).size, np.float64)

    def loss_prob(self, t: float, ids: np.ndarray) -> np.ndarray:
        """Per-client probability the update is lost in the network."""
        return np.zeros(np.asarray(ids).size, np.float64)

    def client_deadline(self, ids: np.ndarray,
                        scales: np.ndarray) -> Optional[np.ndarray]:
        """Per-client upload deadline (None = clients never give up)."""
        return None

    # -- driver entry point --------------------------------------------------
    def sample(self, t: float, client_ids, scales: np.ndarray,
               rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
        """(latencies, lost) for one cohort dispatched at time ``t``."""
        ids = np.asarray(client_ids, np.int64)
        lats = (self.latency.sample(ids, scales, rng)
                * self.latency_scale(t) * self.client_factors(t, ids))
        lost = np.zeros(ids.size, bool)
        lp = np.asarray(self.loss_prob(t, ids), np.float64)
        if np.any(lp > 0.0):
            lost |= rng.random(ids.size) < lp
        dl = self.client_deadline(ids, scales)
        if dl is not None:
            lost |= lats > np.asarray(dl, np.float64)
        return lats, lost


@register_scenario
@dataclasses.dataclass(frozen=True)
class SteadyScenario(Scenario):
    """Stationary fleet: the arrivals model, unmodulated (the regression
    point -- under it the event trainer's K = cohort config is bit-identical
    to the synchronous trainer)."""

    name = "steady"


@register_scenario
@dataclasses.dataclass(frozen=True)
class DiurnalScenario(Scenario):
    """Diurnal load curve: latency swells smoothly to ``(1 + amp)`` x at
    mid-period (busy hours) and back -- trough at t = 0."""

    name = "diurnal"
    amp: float = 1.0
    period: float = 6.0

    def __post_init__(self):
        if not self.period > 0.0:
            raise ValueError(
                f"DiurnalScenario.period must be > 0, got {self.period}")

    def latency_scale(self, t):
        return 1.0 + self.amp * 0.5 * (1.0 - math.cos(2.0 * math.pi
                                                      * t / self.period))


@register_scenario
@dataclasses.dataclass(frozen=True)
class FlashCrowdScenario(Scenario):
    """Flash crowd: a one-off congestion spike multiplies every latency by
    ``surge`` during ``[start, start + width)``."""

    name = "flash-crowd"
    start: float = 1.0
    width: float = 2.0
    surge: float = 5.0

    def latency_scale(self, t):
        return self.surge if self.start <= t < self.start + self.width else 1.0


@register_scenario
@dataclasses.dataclass(frozen=True)
class RegionalOutageScenario(Scenario):
    """Correlated regional dropouts: clients live in ``regions`` regions
    (``id % regions``); every ``period`` time units one region (rotating)
    loses connectivity for ``width`` units and its dispatched updates are
    lost with probability ``loss`` -- failures are CORRELATED, the exact
    condition iid-dropout models miss."""

    name = "regional-outage"
    regions: int = 4
    period: float = 4.0
    width: float = 2.0
    loss: float = 0.9

    def __post_init__(self):
        if self.regions < 1:
            raise ValueError("RegionalOutageScenario.regions must be >= 1, "
                             f"got {self.regions}")
        if not 0.0 <= self.loss <= 1.0:
            raise ValueError("RegionalOutageScenario.loss must be in [0, 1], "
                             f"got {self.loss}")

    def loss_prob(self, t, ids):
        ids = np.asarray(ids, np.int64)
        cycle = int(t // self.period)
        if t - cycle * self.period >= self.width:    # outage window over
            return np.zeros(ids.size, np.float64)
        down = cycle % self.regions                  # the region that is dark
        return np.where(ids % self.regions == down, self.loss, 0.0)


@register_scenario
@dataclasses.dataclass(frozen=True)
class StragglerDriftScenario(Scenario):
    """Chronic-straggler drift: a fixed ``frac`` of clients (deterministic
    in the client id) slows down linearly with simulation time --
    ``1 + drift * t`` on top of their base latency."""

    name = "straggler-drift"
    frac: float = 0.2
    drift: float = 0.25

    def __post_init__(self):
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError("StragglerDriftScenario.frac must be in [0, 1], "
                             f"got {self.frac}")
        if self.drift < 0.0:
            raise ValueError("StragglerDriftScenario.drift must be >= 0, "
                             f"got {self.drift}")

    def client_factors(self, t, ids):
        slow = _hash_frac(ids) < self.frac
        return np.where(slow, 1.0 + self.drift * max(t, 0.0), 1.0)


@register_scenario
@dataclasses.dataclass(frozen=True)
class AdaptiveDeadlineScenario(Scenario):
    """Per-client adaptive deadlines: every client aborts uploads slower
    than ``factor`` x its OWN typical latency (``scale_i * latency.mean``)
    -- fast clients enforce tight deadlines, slow clients loose ones, so
    the abort rate is roughly uniform across the fleet instead of
    concentrating on stragglers."""

    name = "adaptive-deadline"
    factor: float = 1.3

    def __post_init__(self):
        if not self.factor > 0.0:
            raise ValueError("AdaptiveDeadlineScenario.factor must be > 0, "
                             f"got {self.factor}")

    def client_deadline(self, ids, scales):
        ids = np.asarray(ids, np.int64)
        return self.factor * scales[ids] * self.latency.mean


@dataclasses.dataclass(frozen=True)
class ComposedScenario(Scenario):
    """Combinator overlaying two scenarios' hooks on ONE fleet.

    Latency effects multiply (a flash crowd during busy hours is slower
    than either alone); loss probabilities combine as independent drop
    events (``1 - (1-p_a)(1-p_b)`` -- a literal product would nullify a
    one-sided outage); deadlines take the elementwise minimum (whichever
    constraint binds first aborts the upload).  The composed fleet draws
    latencies from the OUTER ``latency`` model -- the components contribute
    only their modulation hooks, not their base distributions.
    """

    name = "composed"
    a: Scenario = dataclasses.field(default_factory=SteadyScenario)
    b: Scenario = dataclasses.field(default_factory=SteadyScenario)

    def __post_init__(self):
        for side, s in (("a", self.a), ("b", self.b)):
            if not isinstance(s, Scenario):
                raise TypeError(
                    f"ComposedScenario.{side} must be a Scenario, "
                    f"got {type(s).__name__}")

    def latency_scale(self, t):
        return self.a.latency_scale(t) * self.b.latency_scale(t)

    def client_factors(self, t, ids):
        return self.a.client_factors(t, ids) * self.b.client_factors(t, ids)

    def loss_prob(self, t, ids):
        pa = np.asarray(self.a.loss_prob(t, ids), np.float64)
        pb = np.asarray(self.b.loss_prob(t, ids), np.float64)
        return 1.0 - (1.0 - pa) * (1.0 - pb)

    def client_deadline(self, ids, scales):
        da = self.a.client_deadline(ids, scales)
        db = self.b.client_deadline(ids, scales)
        if da is None:
            return db
        if db is None:
            return da
        return np.minimum(np.asarray(da, np.float64),
                          np.asarray(db, np.float64))


@register_scenario
@dataclasses.dataclass(frozen=True)
class FlashOutageScenario(ComposedScenario):
    """A regional outage DURING a flash crowd (the ROADMAP's compound
    case): the surge stretches every latency while one rotating region is
    dark, so stale-but-arrived and lost-forever updates peak together."""

    name = "flash-outage"
    a: Scenario = dataclasses.field(default_factory=FlashCrowdScenario)
    b: Scenario = dataclasses.field(default_factory=RegionalOutageScenario)
