"""Federated learning environment: Algorithm 5 data splitting + Eq. 18
unbalancedness + the five environment parameters of Table III.

``split_data`` reproduces the paper's split exactly: every client holds
[Classes per Client] classes and a fraction φ_i (Eq. 18) of the data; splits
are non-overlapping.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

__all__ = ["FedEnvironment", "volume_fractions", "split_data"]


@dataclasses.dataclass(frozen=True)
class FedEnvironment:
    """Table III base configuration."""

    n_clients: int = 100
    participation: float = 0.1       # η
    classes_per_client: int = 10     # c
    batch_size: int = 20             # b
    balancedness: float = 1.0        # γ  (Eq. 18)
    alpha: float = 0.1               # α  (Eq. 18 minimum-volume floor)

    @property
    def participants_per_round(self) -> int:
        return max(1, int(round(self.participation * self.n_clients)))


def volume_fractions(n: int, gamma: float, alpha: float = 0.1) -> np.ndarray:
    """Eq. 18:  φ_i = α/n + (1-α)·γ^i / Σ_j γ^j."""
    i = np.arange(1, n + 1, dtype=np.float64)
    g = gamma ** i
    phi = alpha / n + (1 - alpha) * g / g.sum()
    return phi / phi.sum()


def split_data(labels: np.ndarray, env: FedEnvironment,
               seed: int = 0) -> List[np.ndarray]:
    """Algorithm 5: returns per-client index arrays into the dataset."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    by_class = [list(rng.permutation(np.flatnonzero(labels == j)))
                for j in range(n_classes)]
    phi = volume_fractions(env.n_clients, env.balancedness, env.alpha)
    n_total = len(labels)
    splits: List[np.ndarray] = []
    for i in range(env.n_clients):
        budget = int(phi[i] * n_total)
        per_class = max(1, budget // env.classes_per_client)
        # visit classes in order of remaining pool size (randomly rotated) so
        # depletion never fragments a client across > classes_per_client
        # classes -- every client ends with exactly c classes (Alg. 5 intent).
        start = int(rng.integers(0, n_classes))
        order = sorted(range(n_classes),
                       key=lambda j: (-len(by_class[j]),
                                      (j - start) % n_classes))
        take: list[int] = []
        classes_used = 0
        for k in order:
            if budget <= 0 or classes_used >= env.classes_per_client:
                break
            t = min(budget, per_class, len(by_class[k]))
            if t <= 0:
                continue
            take.extend(by_class[k][:t])
            del by_class[k][:t]
            budget -= t
            classes_used += 1
        splits.append(np.asarray(take, dtype=np.int64))
    return splits
