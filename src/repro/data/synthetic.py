"""Synthetic structured datasets (the container is offline -- no MNIST/CIFAR).

Classification: a Gaussian-mixture "digits" task -- each class has a random
template; samples are template + noise.  Separation is tuned so linear models
reach ~90% (like logreg@MNIST) and the task is learnable but not trivial.
Non-iid splits over CLASS labels behave exactly like the paper's splits: what
matters for the federated phenomena is the label skew, not the pixels.

LM: Zipf-distributed token streams with Markov class structure for the
transformer training examples.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["Dataset", "make_classification", "make_image_classification",
           "make_sequence_classification", "make_lm_tokens"]


class Dataset(NamedTuple):
    x: np.ndarray
    y: np.ndarray
    n_classes: int


def make_classification(seed: int = 0, n: int = 20000, d: int = 784,
                        n_classes: int = 10, sep: float = 2.2,
                        within_class_var: float = 1.0,
                        n_test: int = 2000) -> tuple[Dataset, Dataset]:
    """Flat-vector task (logreg / MLP analogue of MNIST).

    Returns (train, test) drawn from the SAME class templates.
    """
    rng = np.random.default_rng(seed)
    templates = rng.standard_normal((n_classes, d)).astype(np.float32)
    templates *= sep / np.linalg.norm(templates, axis=1, keepdims=True) * np.sqrt(d) / 10

    def draw(m):
        y = rng.integers(0, n_classes, size=m)
        x = templates[y] + within_class_var * rng.standard_normal((m, d)).astype(np.float32)
        return Dataset(x=x.astype(np.float32), y=y.astype(np.int32),
                       n_classes=n_classes)

    return draw(n), draw(n_test)


def make_image_classification(seed: int = 0, n: int = 20000, img: int = 32,
                              ch: int = 3, n_classes: int = 10,
                              sep: float = 1.5,
                              n_test: int = 2000) -> tuple[Dataset, Dataset]:
    """Image-shaped task (CNN analogue of CIFAR): smooth class templates.

    Returns (train, test) drawn from the SAME class templates.
    """
    rng = np.random.default_rng(seed)
    freq = rng.standard_normal((n_classes, 4, 4, ch)).astype(np.float32)
    # upsample low-frequency templates to img x img (structured, conv-friendly)
    templates = np.repeat(np.repeat(freq, img // 4, axis=1), img // 4, axis=2)
    templates *= sep

    def draw(m):
        y = rng.integers(0, n_classes, size=m)
        x = templates[y] + rng.standard_normal((m, img, img, ch)).astype(np.float32)
        return Dataset(x=x.astype(np.float32), y=y.astype(np.int32),
                       n_classes=n_classes)

    return draw(n), draw(n_test)


def make_sequence_classification(seed: int = 0, n: int = 20000, t: int = 28,
                                 d: int = 28, n_classes: int = 10,
                                 sep: float = 1.5,
                                 n_test: int = 2000) -> tuple[Dataset, Dataset]:
    """Sequence task (LSTM analogue of Fashion-MNIST rows).

    Returns (train, test) drawn from the SAME class templates.
    """
    rng = np.random.default_rng(seed)
    templates = rng.standard_normal((n_classes, t, d)).astype(np.float32) * sep

    def draw(m):
        y = rng.integers(0, n_classes, size=m)
        x = templates[y] + rng.standard_normal((m, t, d)).astype(np.float32)
        return Dataset(x=x.astype(np.float32), y=y.astype(np.int32),
                       n_classes=n_classes)

    return draw(n), draw(n_test)


def make_lm_tokens(seed: int = 0, n_tokens: int = 1 << 20, vocab: int = 512,
                   n_states: int = 8) -> np.ndarray:
    """Markov-modulated Zipf token stream: learnable bigram structure."""
    rng = np.random.default_rng(seed)
    # per-state Zipf over a shuffled vocab
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    base = 1.0 / ranks ** 1.1
    perms = [rng.permutation(vocab) for _ in range(n_states)]
    probs = np.stack([base[np.argsort(p)] for p in perms])
    probs /= probs.sum(axis=1, keepdims=True)
    trans = rng.dirichlet(np.ones(n_states) * 0.3, size=n_states)
    out = np.empty(n_tokens, dtype=np.int32)
    state = 0
    # vectorized-ish: sample in blocks with a fixed state per block of 64
    block = 64
    for i in range(0, n_tokens, block):
        state = rng.choice(n_states, p=trans[state])
        m = min(block, n_tokens - i)
        out[i : i + m] = rng.choice(vocab, size=m, p=probs[state])
    return out
