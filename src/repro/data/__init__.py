"""Data pipeline: synthetic datasets + federated splitting + batching."""

from .synthetic import (Dataset, make_classification,
                        make_image_classification, make_lm_tokens,
                        make_sequence_classification)

__all__ = ["Dataset", "make_classification", "make_image_classification",
           "make_lm_tokens", "make_sequence_classification"]
