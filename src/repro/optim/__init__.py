"""Optimizers (no optax in this container): momentum SGD + AdamW + schedules.

Functional interface:
    state = init(params)
    new_params, new_state = apply(params, grads, state, lr)
"""

from .sgd import adamw_apply, adamw_init, sgd_apply, sgd_init
from .schedules import constant_lr, cosine_lr, warmup_cosine_lr

__all__ = ["sgd_init", "sgd_apply", "adamw_init", "adamw_apply",
           "constant_lr", "cosine_lr", "warmup_cosine_lr"]
