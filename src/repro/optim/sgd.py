"""SGD with momentum (the paper's client optimizer) and AdamW."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sgd_init", "sgd_apply", "adamw_init", "adamw_apply"]


def sgd_init(params):
    """Momentum buffers, fp32, like params."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd_apply(params, grads, state, lr, momentum: float = 0.9):
    """Classical (heavy-ball) momentum:  v' = m·v + g;  p' = p - lr·v'."""
    new_v = jax.tree.map(
        lambda v, g: momentum * v + g.astype(jnp.float32), state, grads)
    new_p = jax.tree.map(
        lambda p, v: (p.astype(jnp.float32) - lr * v).astype(p.dtype),
        params, new_v)
    return new_p, new_v


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adamw_apply(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8,
                weight_decay=0.0):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m, v):
        step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype)

    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}
