"""Pallas TPU kernel: single-pass histogram k-selection for STC.

Design note (histogram selection)
---------------------------------
Bisection k-selection (:mod:`.topk_threshold`) does 33 full streaming passes
over HBM per compression.  This module replaces it with a *one-pass* 256-bin
magnitude histogram:

1. ``a_max = max|x|``                                   (pass 1)
2. one streaming histogram pass accumulating per-bin (count, Σ|x|) with the
   canonical sequential-grid reduction; binning is linear on ``[0, a_max]``
   with ``bin = clip(int(|x| · 256/a_max), 0, 255)``     (pass 2)
3. a jnp top-inclusive cumulative sum locates the bin ``b`` holding the k-th
   largest magnitude and its within-bin rank ``r``; ONE refinement pass
   gathers the (typically n/256 ≪ n) candidates of bin ``b`` and reads the
   exact k-th magnitude out of the top-``cap`` candidates  (pass 3)

Total: ≤3 passes, and the selection is *exact* (identical mask to
``jax.lax.top_k``'s ``|x| >= v_k`` rule, ties included) whenever the candidate
bin holds at most ``cap`` elements.  On adversarial inputs that concentrate
>``cap`` elements into one bin (heavy ties at the threshold, extreme dynamic
range) a ``lax.cond`` falls back to an exact sort-based selection, so results
are exact on every input; the fallback never runs on well-scaled gradient
noise.  Per-bin sums let µ be assembled from the histogram (bins above ``b``)
plus the gathered candidates — no extra stats pass.

Backend note: the histogram is the *general* path and the TPU path (the
one-hot binning matmul rides the MXU).  On non-TPU backends the Pallas
interpreter adds ~256× vector-op amplification that a CPU cannot hide, while
XLA's native ``top_k`` streams the input once with an O(cap) heap — so when
``k <= cap`` (every realistic sparsity at CPU-simulation sizes) the selector
short-circuits to ONE direct top-k gather pass plus a rare tie-spill stats
pass: 1-2 passes, and ~4× faster than even the pure-jnp bisection at n=2^20.
Both routes honour the same exact-selection contract and the ≤3-pass budget.

The kernel computes the per-block histogram as a one-hot (elements × bins)
matmul — the MXU-friendly TPU histogram idiom — chunked over sub-blocks of
``chunk_rows`` rows to bound VMEM when compiled (interpret mode runs a single
full-block one-hot, which XLA:CPU fuses efficiently).

``magnitude_histogram_batched`` / ``hist_topk_threshold_batched`` add a
leading client axis (grid ``(client, block)``) so a federated round's
P-client selection is ONE kernel launch.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.selection import (DEFAULT_CAP, NBINS, PASSES, bin_index,
                                  locate_bin, resolve_interpret)
from ._util import LANE, pad_3d, resolve_block_rows

__all__ = [
    "NBINS",
    "bin_index",
    "locate_bin",
    "DEFAULT_CAP",
    "magnitude_histogram",
    "magnitude_histogram_batched",
    "hist_topk_threshold",
    "hist_topk_threshold_batched",
]

_TPU_CHUNK_ROWS = 8  # compiled-mode one-hot chunk: 8*128 elems × 256 bins × 4B = 1 MiB


def _block_hist(a, bin_idx, valid, *, bins: int, chunk_rows: int):
    """(counts, sums) of one (rows, LANE) block via chunked one-hot matmuls."""
    rows = a.shape[0]
    assert chunk_rows >= rows or rows % chunk_rows == 0, (rows, chunk_rows)
    bin_sent = jnp.where(valid, bin_idx, bins)  # padding -> no bin
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, bins), 1)

    if chunk_rows >= rows:
        oh = (bin_sent.reshape(-1, 1) == iota).astype(jnp.float32)
        cnt = jnp.sum(oh, axis=0).reshape(1, bins)
        sums = jnp.dot(a.reshape(1, -1), oh)
        return cnt, sums

    nchunks = rows // chunk_rows

    def body(j, acc):
        cacc, sacc = acc
        ab = jax.lax.dynamic_slice_in_dim(a, j * chunk_rows, chunk_rows, 0)
        bb = jax.lax.dynamic_slice_in_dim(bin_sent, j * chunk_rows,
                                          chunk_rows, 0)
        oh = (bb.reshape(-1, 1) == iota).astype(jnp.float32)
        cacc = cacc + jnp.sum(oh, axis=0).reshape(1, bins)
        sacc = sacc + jnp.dot(ab.reshape(1, -1), oh)
        return cacc, sacc

    zero = jnp.zeros((1, bins), jnp.float32)
    return jax.lax.fori_loop(0, nchunks, body, (zero, zero))


def magnitude_histogram(
    x_flat: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bins: int = NBINS,
    block_rows: int | None = None,
    interpret: bool | None = None,
):
    """One streaming pass -> per-bin ``(count, Σ|x|)`` with linear binning.

    ``scale`` is the precomputed ``bins / max|x|`` scalar (0 for an all-zero
    vector, putting everything in bin 0).  Returns ``(counts, sums)`` of shape
    ``(bins,)``.  Thin wrapper over the batched kernel with a client axis of 1.
    """
    cnt, s = magnitude_histogram_batched(
        x_flat.reshape(1, -1), scale.reshape(1), bins=bins,
        block_rows=block_rows, interpret=interpret)
    return cnt[0], s[0]


def _hist_kernel_batched(x_ref, scale_ref, cnt_ref, sum_ref,
                         *, block_rows: int, n: int, bins: int,
                         chunk_rows: int):
    i = pl.program_id(1)
    a = jnp.abs(x_ref[0].astype(jnp.float32))        # (block_rows, LANE)
    scale = scale_ref[0, 0]

    row = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    gidx = (i * block_rows + row) * LANE + col
    valid = gidx < n

    cnt, sums = _block_hist(a, bin_index(a, scale, bins), valid,
                            bins=bins, chunk_rows=chunk_rows)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros((1, bins), jnp.int32)
        sum_ref[...] = jnp.zeros((1, bins), jnp.float32)

    cnt_ref[...] += cnt.astype(jnp.int32)
    sum_ref[...] += sums


def magnitude_histogram_batched(
    x: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bins: int = NBINS,
    block_rows: int | None = None,
    interpret: bool | None = None,
):
    """Batched histogram over a (clients, n) matrix -> (B, bins) each.

    ``scale``: (B,) per-client ``bins / max|x_b|``.  One kernel launch with
    grid ``(client, block)`` instead of a vmap of per-client launches.
    """
    interpret = resolve_interpret(interpret)
    block_rows = resolve_block_rows(block_rows, interpret)
    PASSES.record("histogram")
    b, n = x.shape
    x3 = pad_3d(x, block_rows)
    grid = (b, x3.shape[1] // block_rows)
    s2 = scale.reshape(b, 1).astype(jnp.float32)
    # compiled mode chunks the one-hot to bound VMEM; gcd keeps the chunk an
    # exact divisor of block_rows so no trailing rows are ever dropped
    chunk_rows = block_rows if interpret \
        else math.gcd(block_rows, _TPU_CHUNK_ROWS)

    kernel = functools.partial(_hist_kernel_batched, block_rows=block_rows,
                               n=n, bins=bins, chunk_rows=chunk_rows)
    cnt, s = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows, LANE), lambda c, i: (c, i, 0)),
            pl.BlockSpec((1, 1), lambda c, i: (c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bins), lambda c, i: (c, 0)),
            pl.BlockSpec((1, bins), lambda c, i: (c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, bins), jnp.int32),
            jax.ShapeDtypeStruct((b, bins), jnp.float32),
        ],
        interpret=interpret,
    )(x3, s2)
    return cnt, s


# ---------------------------------------------------------------------------
# selection driver (histogram -> cumsum -> one refinement pass)
# ---------------------------------------------------------------------------


def hist_topk_threshold(
    x_flat: jnp.ndarray,
    k: int,
    *,
    bins: int = NBINS,
    cap: int = DEFAULT_CAP,
    block_rows: int | None = None,
    interpret: bool | None = None,
):
    """Exact k-selection in ≤3 streaming passes (histogram + refinement).

    Returns ``(thresh, count, sum_abs)`` with ``thresh`` the exact k-th
    largest magnitude (``count = #{|x| >= thresh} >= k``, ties included) and
    ``sum_abs`` the magnitude mass above the threshold (the µ numerator).
    Drop-in replacement for :func:`.topk_threshold.topk_threshold`.
    Thin wrapper over the batched driver with a client axis of 1.
    """
    t, cnt, sums = hist_topk_threshold_batched(
        x_flat.reshape(1, -1), k, bins=bins, cap=cap, block_rows=block_rows,
        interpret=interpret)
    return t[0], cnt[0], sums[0]


def _direct_topk_select_batched(a: jnp.ndarray, k, cap_eff: int):
    """Batched form of the non-TPU small-k shortcut (per-row tie-spill mix).

    ``k`` may be a scalar or a (B,) per-row vector (the chunked codecs give
    every (client, chunk) row its own k)."""
    _, n = a.shape
    k = jnp.asarray(k, jnp.int32).reshape(-1, 1)               # (1|B, 1)
    PASSES.record("topk_gather")                               # pass 1
    topc = jax.lax.top_k(a, cap_eff)[0]
    # masked-min instead of topc[:, k-1]: see _direct_topk_select
    v = jnp.min(jnp.where(jnp.arange(cap_eff)[None, :] < k, topc, jnp.inf),
                axis=1)
    ge = topc >= v[:, None]
    cnt_g = jnp.sum(ge.astype(jnp.int32), axis=1)
    sum_g = jnp.sum(jnp.where(ge, topc, 0.0), axis=1)
    spill = (cap_eff < n) & (jnp.min(topc, axis=1) >= v)

    def _from_gather(_):
        return v, cnt_g, sum_g

    def _tie_spill(_):                                         # rare pass 2
        m = a >= v[:, None]
        cnt_s = jnp.sum(m.astype(jnp.int32), axis=1)
        sum_s = jnp.sum(jnp.where(m, a, 0.0), axis=1)
        return (v, jnp.where(spill, cnt_s, cnt_g),
                jnp.where(spill, sum_s, sum_g))

    return jax.lax.cond(jnp.any(spill), _tie_spill, _from_gather, None)


def hist_topk_threshold_batched(
    x: jnp.ndarray,
    k,
    *,
    bins: int = NBINS,
    cap: int = DEFAULT_CAP,
    block_rows: int | None = None,
    interpret: bool | None = None,
):
    """Batched exact k-selection over (clients, n); same contract per row.

    ``k`` is static: an int shared by every row, or a (B,) array giving each
    row its own k (the chunked ``(layer, chunk)`` block path -- one launch
    selects every chunk of every client).  Returns ``(thresh, count,
    sum_abs)`` vectors of shape (B,).
    """
    bsz, n = x.shape
    k_arr = np.broadcast_to(np.asarray(k, np.int64), (bsz,))
    assert 1 <= int(k_arr.min(initial=1)) and int(k_arr.max(initial=1)) <= n, \
        (k, n)
    k_max = int(k_arr.max(initial=1))
    x = x.astype(jnp.float32)
    cap_eff = min(cap, n)
    interpret = resolve_interpret(interpret)

    if interpret and k_max <= cap_eff:  # non-TPU small-k shortcut: 1-2 passes
        return _direct_topk_select_batched(jnp.abs(x), k_arr, cap_eff)

    PASSES.record("max")                                       # pass 1
    a = jnp.abs(x)
    a_max = jnp.max(a, axis=1)
    scale = jnp.where(a_max > 0, jnp.float32(bins) / a_max, 0.0)

    kj = jnp.asarray(k_arr, jnp.int32)
    cnt, sums = magnitude_histogram_batched(                   # pass 2
        x, scale, bins=bins, block_rows=block_rows, interpret=interpret)
    b, cnt_gt, sum_gt, cnt_b = jax.vmap(
        lambda c, s, kk: locate_bin(c, s, kk, bins))(cnt, sums, kj)
    r = kj - cnt_gt

    PASSES.record("refine")                                    # pass 3
    in_bin = bin_index(a, scale[:, None], bins) == b[:, None]
    topc = jax.lax.top_k(jnp.where(in_bin, a, jnp.float32(-1.0)), cap_eff)[0]
    v = jnp.take_along_axis(topc, (r - 1)[:, None], axis=1)[:, 0]
    ge = (topc >= 0.0) & (topc >= v[:, None])
    cnt_ex = cnt_gt + jnp.sum(ge.astype(jnp.int32), axis=1)
    sum_ex = sum_gt + jnp.sum(jnp.where(ge, topc, 0.0), axis=1)

    overflow = cnt_b > cap_eff

    def _exact(_):
        return v, cnt_ex, sum_ex

    def _mixed(_):
        srt = jnp.sort(a, axis=1)
        vs = jnp.take_along_axis(srt, (n - kj)[:, None], axis=1)[:, 0]
        m = a >= vs[:, None]
        cnt_s = jnp.sum(m.astype(jnp.int32), axis=1)
        sum_s = jnp.sum(jnp.where(m, a, 0.0), axis=1)
        return (jnp.where(overflow, vs, v),
                jnp.where(overflow, cnt_s, cnt_ex),
                jnp.where(overflow, sum_s, sum_ex))

    return jax.lax.cond(jnp.any(overflow), _mixed, _exact, None)
