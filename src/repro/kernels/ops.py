"""Public jit'd wrappers over the Pallas STC kernels.

``stc_compress_kernel(delta, residual, p)`` is the drop-in kernel-backed
equivalent of ``core.residual.compress_with_feedback(·, ·, stc_compress)``:

    1. k-selection by single-pass histogram  (hist_select kernel, ≤3 passes;
       ``selector="bisect"`` keeps the old 33-pass bisection for comparison)
    2. µ = sum|carried above t| / count      (assembled from the histogram
       partials + refinement gather — no extra stats pass)
    3. fused ternarize + error-feedback      (stc_compress kernel, 1 pass,
       reading the already-materialized carried vector once)

``stc_compress_batch`` compresses a whole federated round's (P, n) client
updates in ONE batched histogram launch + ONE batched apply launch (grid
``(client, block)``) instead of a vmap of per-client selections.

``interpret=None`` autodetects the backend: the kernels run compiled on TPU
and in interpreter mode everywhere else.  ``ref.py`` holds the pure-jnp
oracles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from ._util import PASSES
from .hist_select import (DEFAULT_CAP, hist_topk_threshold,
                          hist_topk_threshold_batched, magnitude_histogram,
                          magnitude_histogram_batched)
from .stc_compress import stc_apply, stc_apply_batched
from .topk_threshold import threshold_stats, topk_threshold

__all__ = [
    "stc_compress_kernel",
    "stc_compress_batch",
    "stc_compress_ref",
    "threshold_stats",
    "topk_threshold",
    "hist_topk_threshold",
    "hist_topk_threshold_batched",
    "magnitude_histogram",
    "magnitude_histogram_batched",
    "PASSES",
]


def _select(carried, k, selector, iters, block_rows, interpret, cap):
    if selector == "hist":
        return hist_topk_threshold(
            carried, k, cap=cap, block_rows=block_rows, interpret=interpret)
    if selector == "bisect":
        return topk_threshold(
            carried, k, iters=iters, block_rows=block_rows,
            interpret=interpret)
    raise ValueError(f"unknown selector {selector!r}")


@functools.partial(
    jax.jit,
    static_argnames=("p", "selector", "iters", "block_rows", "interpret",
                     "cap"),
)
def stc_compress_kernel(
    delta: jnp.ndarray,
    residual: jnp.ndarray,
    p: float,
    *,
    selector: str = "hist",
    iters: int = 32,
    block_rows: int | None = None,
    interpret: bool | None = None,
    cap: int = DEFAULT_CAP,
):
    """Kernel-backed STC with error feedback over flat fp32 vectors.

    Returns ``(tern, new_residual, mu, thresh, nnz)``.
    """
    n = delta.size
    k = max(int(n * p), 1)
    carried = delta.astype(jnp.float32) + residual.astype(jnp.float32)
    thresh, cnt, s = _select(carried, k, selector, iters, block_rows,
                             interpret, cap)
    mu = s / jnp.maximum(cnt, 1).astype(jnp.float32)
    tern, new_res = stc_apply(
        carried, thresh, mu, block_rows=block_rows, interpret=interpret
    )
    return tern, new_res, mu, thresh, cnt


@functools.partial(
    jax.jit,
    static_argnames=("p", "block_rows", "interpret", "cap"),
)
def stc_compress_batch(
    deltas: jnp.ndarray,
    residuals: jnp.ndarray,
    p: float,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
    cap: int = DEFAULT_CAP,
):
    """Batched kernel-backed STC over (clients, n) updates + residuals.

    One histogram launch + one fused-apply launch for the whole batch.
    Returns ``(tern, new_residual, mu, thresh, nnz)`` with leading client
    axis ((B, n) arrays, (B,) stats).
    """
    assert deltas.shape == residuals.shape and deltas.ndim == 2
    _, n = deltas.shape
    k = max(int(n * p), 1)
    carried = deltas.astype(jnp.float32) + residuals.astype(jnp.float32)
    thresh, cnt, s = hist_topk_threshold_batched(
        carried, k, cap=cap, block_rows=block_rows, interpret=interpret)
    mu = s / jnp.maximum(cnt, 1).astype(jnp.float32)
    tern, new_res = stc_apply_batched(
        carried, thresh, mu, block_rows=block_rows, interpret=interpret
    )
    return tern, new_res, mu, thresh, cnt


@functools.partial(jax.jit, static_argnames=("p", "iters"))
def stc_compress_ref(delta: jnp.ndarray, residual: jnp.ndarray, p: float,
                     *, iters: int = 32):
    """Pure-jnp bisection oracle with the kernel path's signature/semantics."""
    n = delta.size
    k = max(int(n * p), 1)
    carried = delta.astype(jnp.float32) + residual.astype(jnp.float32)
    thresh = ref.topk_threshold_ref(carried, k, iters=iters)
    cnt, s = ref.threshold_stats_ref(carried, thresh)
    mu = s / jnp.maximum(cnt, 1).astype(jnp.float32)
    tern, new_res = ref.stc_apply_ref(carried, thresh, mu)
    return tern, new_res, mu, thresh, cnt
