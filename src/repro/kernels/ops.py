"""Public jit'd wrappers over the Pallas STC kernels.

``stc_compress_kernel(delta, residual, p)`` is the drop-in kernel-backed
equivalent of ``core.residual.compress_with_feedback(·, ·, stc_compress)``:

    1. k-selection by threshold bisection   (topk_threshold kernel, ~32 passes)
    2. µ = sum|carried above t| / count     (reuses the final stats pass)
    3. fused ternarize + error-feedback     (stc_compress kernel, 1 pass)

On CPU the kernels run in ``interpret=True`` mode (the default here); on TPU
pass ``interpret=False``.  ``ref.py`` holds the pure-jnp oracles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .stc_compress import stc_apply
from .topk_threshold import DEFAULT_BLOCK_ROWS, threshold_stats, topk_threshold

__all__ = [
    "stc_compress_kernel",
    "stc_compress_ref",
    "threshold_stats",
    "topk_threshold",
]


@functools.partial(
    jax.jit, static_argnames=("p", "iters", "block_rows", "interpret")
)
def stc_compress_kernel(
    delta: jnp.ndarray,
    residual: jnp.ndarray,
    p: float,
    *,
    iters: int = 32,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Kernel-backed STC with error feedback over flat fp32 vectors.

    Returns ``(tern, new_residual, mu, thresh, nnz)``.
    """
    n = delta.size
    k = max(int(n * p), 1)
    carried = delta.astype(jnp.float32) + residual.astype(jnp.float32)
    thresh, cnt, s = topk_threshold(
        carried, k, iters=iters, block_rows=block_rows, interpret=interpret
    )
    mu = s / jnp.maximum(cnt, 1).astype(jnp.float32)
    tern, new_res = stc_apply(
        delta, residual, thresh, mu, block_rows=block_rows, interpret=interpret
    )
    return tern, new_res, mu, thresh, cnt


@functools.partial(jax.jit, static_argnames=("p", "iters"))
def stc_compress_ref(delta: jnp.ndarray, residual: jnp.ndarray, p: float,
                     *, iters: int = 32):
    """Pure-jnp oracle with identical signature/semantics to the kernel path."""
    n = delta.size
    k = max(int(n * p), 1)
    carried = delta.astype(jnp.float32) + residual.astype(jnp.float32)
    thresh = ref.topk_threshold_ref(carried, k, iters=iters)
    cnt, s = ref.threshold_stats_ref(carried, thresh)
    mu = s / jnp.maximum(cnt, 1).astype(jnp.float32)
    tern, new_res = ref.stc_fused_ref(delta.astype(jnp.float32),
                                      residual.astype(jnp.float32), thresh, mu)
    return tern, new_res, mu, thresh, cnt
