"""Pallas TPU kernel: bitstream word unpacking for the wire-decode path.

The streaming Golomb decoder (:mod:`repro.core.wire`) splits, like the
encoder, into an irregular chain part (terminator successor links, pointer
doubling, field gathers -- host numpy) and a perfectly regular dense part:
exploding every uint32 stream word into its 32 MSB-first bits, plus the
per-word zero count that seeds the decoder's run-length prefix scan.  The
dense part is this kernel -- the exact inverse of :mod:`bitpack`:

    bit[32w + j] = (word[w] >> (31 - j)) & 1
    zeros[w]     = 32 - sum_j bit[32w + j]

The layout mirrors the packer: words live in ``(rows, LANE)`` blocks, the
bit tensor in ``(32, rows, LANE)`` with word ``r * LANE + c`` owning column
``[:, r, c]``, so each grid step reads a ``(block_rows, LANE)`` uint32 block
and writes one bit plane per shift -- a pure VPU shift-and-mask with the
zero-count reduction fused into the same pass (the decoder always needs
both, so two outputs beat two launches).

``unpack_bits_words`` covers ALL ``32 * n_words`` bits (padding included):
retraces key off the word count alone, so per-message ``bit_len`` trimming
stays host-side and free.  ``unpack_bits_ref`` is the pure-jnp oracle; like
every kernel here, ``interpret=None`` autodetects the backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import LANE, PASSES, _cdiv, resolve_interpret

__all__ = ["unpack_bits_words", "unpack_words_with_counts", "unpack_bits_ref"]

# words per VMEM block: 32*block_rows*128 output bits (int32) = 2 MiB at 128
DEFAULT_BLOCK_ROWS = 32
INTERPRET_BLOCK_ROWS = 1024


def _resolve_rows(block_rows: int | None, interpret: bool) -> int:
    if block_rows is not None:
        return block_rows
    return INTERPRET_BLOCK_ROWS if interpret else DEFAULT_BLOCK_ROWS


def unpack_bits_ref(words: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle: uint32 words -> the full MSB-first 0/1 bit vector."""
    w = jnp.asarray(words).astype(jnp.uint32)
    shifts = jnp.uint32(31) - jnp.arange(32, dtype=jnp.uint32)
    bits = (w[:, None] >> shifts[None, :]) & jnp.uint32(1)
    return bits.reshape(-1).astype(jnp.uint8)


def _unpack_kernel(w_ref, bits_ref, zc_ref):
    w = w_ref[...].astype(jnp.uint32)            # (block_rows, LANE)
    j = jax.lax.broadcasted_iota(jnp.uint32, (32,) + w.shape, 0)
    bits = ((w[None, :, :] >> (jnp.uint32(31) - j))
            & jnp.uint32(1)).astype(jnp.int32)   # (32, block_rows, LANE)
    bits_ref[...] = bits
    zc_ref[...] = jnp.int32(32) - jnp.sum(bits, axis=0, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def unpack_words_with_counts(
    words: jnp.ndarray,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """uint32 stream -> (all ``32 * n_words`` bits, per-word zero counts).

    Stream bit ``t`` comes from word ``t >> 5`` at bit ``31 - (t & 31)``
    (the canonical order of :mod:`repro.core.wire`); ``zero_counts[w]`` is
    the number of 0-bits in word ``w``, whose exclusive scan seeds the
    decoder's terminator chains at word-aligned segment starts.
    """
    interpret = resolve_interpret(interpret)
    block_rows = _resolve_rows(block_rows, interpret)
    PASSES.record("unpack_bits")
    n_words = int(words.size)
    rows = max(_cdiv(n_words, block_rows * LANE), 1) * block_rows
    padded_words = rows * LANE
    w2 = jnp.pad(jnp.asarray(words).astype(jnp.uint32).reshape(-1),
                 (0, padded_words - n_words)).reshape(rows, LANE)
    bits3, zc2 = pl.pallas_call(
        _unpack_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((32, block_rows, LANE), lambda i: (0, i, 0)),
                   pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((32, rows, LANE), jnp.int32),
                   jax.ShapeDtypeStruct((rows, LANE), jnp.int32)],
        interpret=interpret,
    )(w2)
    # invert the packer's layout: bit j of word w sits at [j, w//LANE, w%LANE]
    bits = (bits3.reshape(32, padded_words).T.reshape(-1)
            [: 32 * n_words].astype(jnp.uint8))
    return bits, zc2.reshape(-1)[:n_words]


def unpack_bits_words(
    words: jnp.ndarray,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """uint32 word stream -> all ``32 * n_words`` bits (uint8 0/1)."""
    bits, _ = unpack_words_with_counts(words, block_rows=block_rows,
                                       interpret=interpret)
    return bits
