"""Shared helpers for the STC Pallas kernels.

The pure-jnp selection building blocks (``bin_index``, ``locate_bin``,
``resolve_interpret``, the ``PASSES`` streaming-pass counter) live in
:mod:`repro.core.selection` so core modules never depend on pallas; they are
re-exported here for the kernels.  This module adds the kernel-only pieces:

* ``resolve_block_rows`` -- ``block_rows=None`` resolves to VMEM-sized blocks
  on TPU and to large blocks under the interpreter, whose per-grid-step
  overhead dominates off-TPU.
* ``pad_2d`` / ``pad_3d`` -- zero-pad flat / (clients, n) inputs into
  ``(…, M, LANE)`` tiles with ``M % block_rows == 0``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.selection import (PASSES, PassCounter,  # noqa: F401
                                  resolve_interpret)

__all__ = ["LANE", "DEFAULT_BLOCK_ROWS", "INTERPRET_BLOCK_ROWS",
           "resolve_interpret", "resolve_block_rows", "pad_2d", "pad_3d",
           "PASSES", "PassCounter"]

LANE = 128                 # TPU lane width; last dim of every block
DEFAULT_BLOCK_ROWS = 512   # 512*128 fp32 = 256 KiB per input block in VMEM
INTERPRET_BLOCK_ROWS = 2048  # interpreter: fewer, larger grid steps (no VMEM)


def resolve_block_rows(block_rows: int | None, interpret: bool) -> int:
    """``None`` -> VMEM-sized blocks on TPU, big blocks under the interpreter
    (whose per-grid-step overhead dominates off-TPU)."""
    if block_rows is not None:
        return block_rows
    return INTERPRET_BLOCK_ROWS if interpret else DEFAULT_BLOCK_ROWS


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_2d(x_flat: jnp.ndarray, block_rows: int) -> jnp.ndarray:
    """Zero-pad a flat fp32 vector and reshape to (M, LANE), M % block_rows == 0."""
    n = x_flat.size
    per_block = block_rows * LANE
    padded = _cdiv(n, per_block) * per_block
    x = jnp.pad(x_flat, (0, padded - n))
    return x.reshape(-1, LANE)


def pad_3d(x: jnp.ndarray, block_rows: int) -> jnp.ndarray:
    """(B, n) fp32 -> zero-padded (B, M, LANE) with M % block_rows == 0."""
    bsz, n = x.shape
    per_block = block_rows * LANE
    padded = _cdiv(n, per_block) * per_block
    x = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, padded - n)))
    return x.reshape(bsz, -1, LANE)
