"""Pallas TPU kernels for the STC compression hot-spot.

* ``topk_threshold`` -- k-selection by threshold bisection (streaming counting
  kernel; avoids a global sort over 10^6..10^10 gradient elements).
* ``stc_compress``   -- fused residual-add → mask → ternarize → error-feedback
  single-pass kernel (cuts HBM traffic ~2.25× vs the unfused chain).
* ``ops``            -- jit'd public wrappers; ``ref`` -- pure-jnp oracles.

Validated in ``interpret=True`` mode on CPU (tests sweep shapes & dtypes and
assert_allclose against the oracles); on TPU pass ``interpret=False``.
"""

from .ops import stc_compress_kernel, stc_compress_ref, threshold_stats, topk_threshold
from .stc_compress import stc_apply

__all__ = [
    "stc_compress_kernel",
    "stc_compress_ref",
    "threshold_stats",
    "topk_threshold",
    "stc_apply",
]
