"""Pallas TPU kernels for the STC compression hot-spot.

* ``hist_select``    -- single-pass 256-bin histogram k-selection (counts +
  per-bin |x| sums accumulated across the sequential grid), located by a jnp
  cumulative sum plus ONE exact refinement pass: ≤3 streaming passes per
  selection vs 33 for bisection, with batched ``(client, block)`` variants so
  a federated round's P-client compression is one kernel launch.  See the
  module docstring for the full design note.
* ``topk_threshold`` -- k-selection by threshold bisection (streaming counting
  kernel; 33 passes).  Kept as the reference selector and the exactness
  fallback for pathological inputs.
* ``stc_compress``   -- fused mask → ternarize → error-feedback single-pass
  kernel over the carried vector (single + batched client axis).
* ``bitpack``        -- wire-format word packing: 32 stream bits → one uint32
  word per VPU shift-and-sum, the device half of the ``"kernel"`` wire
  backend in :mod:`repro.core.wire` (single + uniform-length batched).
* ``wiredecode``     -- the decode inverse: each uint32 stream word explodes
  into its 32 MSB-first bits plus a fused per-word zero count (the seed of
  the decoder's run-length prefix scan), the device half of the ``"kernel"``
  wire DECODE backend.
* ``ops``            -- jit'd public wrappers; ``ref`` -- pure-jnp oracles.

All entry points take ``interpret: bool | None = None`` and autodetect the
backend (compiled on TPU, interpreter elsewhere), so call sites are TPU-ready
unchanged.  Tests sweep shapes & dtypes and assert_allclose against the
oracles; ``core.selection.PASSES`` counts logical streaming passes for the
perf tests.
"""

from repro.core.selection import PASSES, resolve_interpret
from .bitpack import pack_bits_ref, pack_bits_words, pack_bits_words_batched
from .wiredecode import (unpack_bits_ref, unpack_bits_words,
                         unpack_words_with_counts)
from .hist_select import (hist_topk_threshold, hist_topk_threshold_batched,
                          magnitude_histogram, magnitude_histogram_batched)
from .ops import (stc_compress_batch, stc_compress_kernel, stc_compress_ref,
                  threshold_stats, topk_threshold)
from .stc_compress import stc_apply, stc_apply_batched

__all__ = [
    "stc_compress_kernel",
    "stc_compress_batch",
    "stc_compress_ref",
    "threshold_stats",
    "topk_threshold",
    "hist_topk_threshold",
    "hist_topk_threshold_batched",
    "magnitude_histogram",
    "magnitude_histogram_batched",
    "stc_apply",
    "stc_apply_batched",
    "pack_bits_words",
    "pack_bits_words_batched",
    "pack_bits_ref",
    "unpack_bits_words",
    "unpack_words_with_counts",
    "unpack_bits_ref",
    "PASSES",
    "resolve_interpret",
]
