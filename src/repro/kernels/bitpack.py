"""Pallas TPU kernel: bitstream word packing for the wire-format subsystem.

The vectorized Golomb encoder (:mod:`repro.core.wire`) reduces a sparse
ternary message to a dense 0/1 bit tensor (or to (value, length) chunks that
expand into one); the remaining dense work -- assembling 32 consecutive
stream bits into each uint32 word -- is exactly the kind of regular,
reduction-over-a-minor-axis computation the VPU eats:

    word[w] = sum_j bits[32w + j] << (31 - j)

The host lays the bit tensor out as ``(32, rows, LANE)`` with word
``r * LANE + c`` owning column ``[:, r, c]``, so each grid step reads a
``(32, block_rows, LANE)`` block and writes a ``(block_rows, LANE)`` uint32
block: the shift-and-sum runs over the leading 32-axis, lanes stay 128-wide,
and the summands are disjoint powers of two (no carries), so an integer sum
IS the bitwise OR.

``pack_bits_words_batched`` reduces a uniform-length ``(B, nbits)`` batch to
ONE launch by word-aligning each row and flattening -- per-row word slices
of the result are exact because rows are padded to whole words.

Like every kernel in this package, ``interpret=None`` autodetects the
backend (compiled on TPU, interpreter elsewhere), and the pure-jnp
``pack_bits_ref`` oracle is exported for the tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import LANE, PASSES, _cdiv, resolve_interpret

__all__ = ["pack_bits_words", "pack_bits_words_batched", "pack_bits_ref"]

# words per VMEM block: 32*block_rows*128 input bits (int32) = 2 MiB at 128
DEFAULT_BLOCK_ROWS = 32
INTERPRET_BLOCK_ROWS = 1024


def _resolve_rows(block_rows: int | None, interpret: bool) -> int:
    if block_rows is not None:
        return block_rows
    return INTERPRET_BLOCK_ROWS if interpret else DEFAULT_BLOCK_ROWS


def pack_bits_ref(bits: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp oracle: pack a flat 0/1 vector into MSB-first uint32 words."""
    m = bits.size
    w = _cdiv(m, 32)
    b = jnp.pad(bits.astype(jnp.uint32), (0, 32 * w - m)).reshape(w, 32)
    weights = (jnp.uint32(1) << (31 - jnp.arange(32, dtype=jnp.uint32)))
    return jnp.sum(b * weights[None, :], axis=1, dtype=jnp.uint32)


def _pack_kernel(b_ref, out_ref):
    b = b_ref[...].astype(jnp.uint32)            # (32, block_rows, LANE)
    j = jax.lax.broadcasted_iota(jnp.uint32, b.shape, 0)
    # disjoint powers of two per j: the integer sum is the bitwise OR
    out_ref[...] = jnp.sum(b << (jnp.uint32(31) - j), axis=0,
                           dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def pack_bits_words(
    bits: jnp.ndarray,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Pack a flat 0/1 vector into the canonical uint32 word stream.

    ``bits``: (m,) integer/bool array of 0/1.  Returns ``ceil(m/32)`` words;
    stream bit ``t`` lands in word ``t >> 5`` at bit ``31 - (t & 31)``.
    """
    interpret = resolve_interpret(interpret)
    block_rows = _resolve_rows(block_rows, interpret)
    PASSES.record("pack_bits")
    m = int(bits.size)
    n_words = _cdiv(m, 32)
    rows = _cdiv(n_words, block_rows * LANE) * block_rows
    padded_words = rows * LANE
    b = jnp.pad(bits.astype(jnp.int32).reshape(-1),
                (0, 32 * padded_words - m))
    # bit j of word w at [j, w // LANE, w % LANE]
    b3 = b.reshape(padded_words, 32).T.reshape(32, rows, LANE)
    out = pl.pallas_call(
        _pack_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((32, block_rows, LANE), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.uint32),
        interpret=interpret,
    )(b3)
    return out.reshape(-1)[:n_words]


def pack_bits_words_batched(
    bits: jnp.ndarray,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Pack a uniform-length ``(B, nbits)`` bit batch in ONE kernel launch.

    Each row is padded to a whole number of words, so the flattened stream's
    word ``i * words_per_row + w`` is exactly row ``i``'s word ``w``.
    Returns ``(B, ceil(nbits/32))`` uint32.
    """
    bsz, m = bits.shape
    wpr = _cdiv(m, 32)
    padded = jnp.pad(bits.astype(jnp.int32), ((0, 0), (0, 32 * wpr - m)))
    words = pack_bits_words(padded.reshape(-1), block_rows=block_rows,
                            interpret=interpret)
    return words.reshape(bsz, wpr)
