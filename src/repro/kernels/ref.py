"""Pure-jnp oracles for the Pallas kernels.

These define the exact semantics the kernels must match (assert_allclose in
tests).  They are also the CPU fallback used when pallas is unavailable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "threshold_stats_ref",
    "topk_threshold_ref",
    "magnitude_histogram_ref",
    "stc_apply_ref",
    "stc_fused_ref",
]


def threshold_stats_ref(x: jnp.ndarray, thresh: jnp.ndarray):
    """(count, sum|x|) of entries with |x| >= thresh.  x: flat fp32."""
    a = jnp.abs(x)
    mask = a >= thresh
    return jnp.sum(mask.astype(jnp.int32)), jnp.sum(jnp.where(mask, a, 0.0))


def topk_threshold_ref(x: jnp.ndarray, k: int, iters: int = 32):
    """Magnitude threshold t such that count(|x| >= t) ~= k, via bisection.

    This is the kernel-friendly k-selection: binary search on the threshold
    over [0, max|x|], `iters` rounds (fp32 has 24 mantissa bits; 32 halvings
    of the bracket give exact-to-ulp selection for any realistic k).
    Matches `jax.lax.top_k`'s kth magnitude up to ties.
    """
    a = jnp.abs(x)
    # invariant: count(lo) >= k, count(hi) < k  (count(t) = #{|x| >= t})
    hi = jnp.max(a) * jnp.asarray(1.0 + 1e-6, a.dtype) + jnp.asarray(1e-30, a.dtype)
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((a >= mid).astype(jnp.int32))
        keep = cnt >= k
        lo = jnp.where(keep, mid, lo)
        hi = jnp.where(keep, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    # lo is the largest bracketed threshold with count >= k
    return lo


def magnitude_histogram_ref(x: jnp.ndarray, scale: jnp.ndarray,
                            bins: int = 256):
    """Per-bin (count, Σ|x|) with the linear binning of ``hist_select``.

    Must use the *identical* bin expression as the kernel so masks agree
    bit-for-bit -- hence the shared ``selection.bin_index`` definition.
    """
    from repro.core.selection import bin_index
    a = jnp.abs(x.astype(jnp.float32))
    idx = bin_index(a, scale, bins)
    cnt = jnp.bincount(idx, length=bins).astype(jnp.int32)
    sums = jnp.bincount(idx, weights=a, length=bins).astype(jnp.float32)
    return cnt, sums


def stc_apply_ref(carried: jnp.ndarray, thresh: jnp.ndarray, mu: jnp.ndarray):
    """Fused STC apply on the carried vector ``delta + residual``:

        tern         = µ * sign(carried) * (|carried| >= thresh)
        new_residual = carried - tern

    carried flat fp32; thresh/mu scalars.  Returns (tern, new_residual).
    """
    mask = jnp.abs(carried) >= thresh
    tern = jnp.where(mask, mu * jnp.sign(carried), 0.0)
    return tern.astype(carried.dtype), (carried - tern).astype(carried.dtype)


def stc_fused_ref(delta: jnp.ndarray, residual: jnp.ndarray, thresh: jnp.ndarray,
                  mu: jnp.ndarray):
    """Fused STC apply: given carried = delta + residual, a magnitude threshold
    and the (precomputed) ternary magnitude µ, produce

        tern        = µ * sign(carried) * (|carried| >= thresh)
        new_residual = carried - tern

    delta/residual flat fp32; thresh/mu scalars.  Returns (tern, new_residual).
    """
    carried = delta + residual
    mask = jnp.abs(carried) >= thresh
    tern = jnp.where(mask, mu * jnp.sign(carried), 0.0)
    return tern.astype(delta.dtype), (carried - tern).astype(residual.dtype)
