"""Pallas TPU kernel: fused STC apply (residual-add → mask → ternarize → EF).

Naively, one STC round over the flat parameter vector does

    carried = ΔW + A          (read 2n, write n)
    mask    = |carried| >= t  (read n)
    tern    = µ·sign·mask     (read n, write n)
    A'      = carried - tern  (read 2n, write n)

≈ 9n fp32 HBM moves.  This kernel fuses everything into ONE pass: read
(ΔW, A) once, write (T*, A') once — 4n moves, a 2.25× cut on the dominant
memory term of the compression step.  Inputs are tiled to (block_rows, 128)
VMEM blocks; the threshold t and magnitude µ are scalar (1,1) operands
computed by the bisection kernel in :mod:`.topk_threshold`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .topk_threshold import LANE, DEFAULT_BLOCK_ROWS, _pad_2d

__all__ = ["stc_apply"]


def _fused_kernel(d_ref, r_ref, t_ref, mu_ref, tern_ref, res_ref,
                  *, block_rows: int, n: int):
    i = pl.program_id(0)
    d = d_ref[...].astype(jnp.float32)
    r = r_ref[...]
    t = t_ref[0, 0]
    mu = mu_ref[0, 0]

    carried = d + r

    row = jax.lax.broadcasted_iota(jnp.int32, d.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    gidx = (i * block_rows + row) * LANE + col
    valid = gidx < n

    m = (jnp.abs(carried) >= t) & valid
    tern = jnp.where(m, mu * jnp.sign(carried), jnp.zeros_like(carried))
    tern_ref[...] = tern
    res_ref[...] = carried - tern


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stc_apply(
    delta: jnp.ndarray,
    residual: jnp.ndarray,
    thresh: jnp.ndarray,
    mu: jnp.ndarray,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Fused  tern = µ·sign(Δ+A)·[|Δ+A| >= t];  A' = (Δ+A) - tern.

    delta/residual: flat fp32 vectors of equal length; thresh/mu scalars.
    Returns ``(tern, new_residual)`` flat fp32 vectors of the input length.
    """
    assert delta.shape == residual.shape, (delta.shape, residual.shape)
    n = delta.size
    d2 = _pad_2d(delta.astype(jnp.float32), block_rows)
    r2 = _pad_2d(residual.astype(jnp.float32), block_rows)
    grid = (d2.shape[0] // block_rows,)
    t2 = thresh.reshape(1, 1).astype(jnp.float32)
    mu2 = mu.reshape(1, 1).astype(jnp.float32)

    kernel = functools.partial(_fused_kernel, block_rows=block_rows, n=n)
    tern, res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(d2.shape, jnp.float32),
            jax.ShapeDtypeStruct(d2.shape, jnp.float32),
        ],
        interpret=interpret,
    )(d2, r2, t2, mu2)
    return tern.reshape(-1)[:n], res.reshape(-1)[:n]
