"""Pallas TPU kernel: fused STC apply (mask → ternarize → error-feedback).

Naively, one STC round over the flat parameter vector does

    mask    = |carried| >= t  (read n)
    tern    = µ·sign·mask     (read n, write n)
    A'      = carried - tern  (read n, write n)

This kernel fuses everything into ONE pass: read ``carried`` once, write
``(T*, A')`` once — 3n fp32 HBM moves.  The caller threads the carried vector
``ΔW + A`` (already materialized by the k-selection step) straight through, so
the delta/residual pair is never re-read and the add never recomputed.
Inputs are tiled to (block_rows, 128) VMEM blocks; the threshold t and
magnitude µ are scalar (1, 1) operands computed by the histogram selector in
:mod:`.hist_select` (or the bisection fallback in :mod:`.topk_threshold`).

``stc_apply_batched`` adds a leading client axis: grid ``(client, block)``
with per-client (t, µ) scalars, so compressing P participants is ONE kernel
launch instead of a vmap of P launches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import (LANE, PASSES, pad_3d, resolve_block_rows,
                    resolve_interpret)

__all__ = ["stc_apply", "stc_apply_batched"]


def stc_apply(
    carried: jnp.ndarray,
    thresh: jnp.ndarray,
    mu: jnp.ndarray,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
):
    """Fused  tern = µ·sign(carried)·[|carried| >= t];  A' = carried - tern.

    carried: flat fp32 vector (= ΔW + A); thresh/mu scalars.
    Returns ``(tern, new_residual)`` flat fp32 vectors of the input length.
    Thin wrapper over the batched kernel with a client axis of 1.
    """
    tern, res = stc_apply_batched(
        carried.reshape(1, -1), thresh.reshape(1), mu.reshape(1),
        block_rows=block_rows, interpret=interpret)
    return tern[0], res[0]


def _fused_kernel(c_ref, t_ref, mu_ref, tern_ref, res_ref,
                          *, block_rows: int, n: int):
    i = pl.program_id(1)                     # block index within the client
    carried = c_ref[0].astype(jnp.float32)   # (block_rows, LANE)
    t = t_ref[0, 0]
    mu = mu_ref[0, 0]

    row = jax.lax.broadcasted_iota(jnp.int32, carried.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.int32, carried.shape, 1)
    gidx = (i * block_rows + row) * LANE + col
    valid = gidx < n

    m = (jnp.abs(carried) >= t) & valid
    tern = jnp.where(m, mu * jnp.sign(carried), jnp.zeros_like(carried))
    tern_ref[0] = tern
    res_ref[0] = carried - tern


def stc_apply_batched(
    carried: jnp.ndarray,
    thresh: jnp.ndarray,
    mu: jnp.ndarray,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
):
    """Batched fused apply over a (clients, n) carried matrix.

    carried: (B, n) fp32; thresh/mu: (B,) per-client scalars.
    Returns ``(tern, new_residual)`` of shape (B, n).
    """
    interpret = resolve_interpret(interpret)
    block_rows = resolve_block_rows(block_rows, interpret)
    PASSES.record("stc_apply")
    b, n = carried.shape
    c3 = pad_3d(carried, block_rows)
    grid = (b, c3.shape[1] // block_rows)
    t2 = thresh.reshape(b, 1).astype(jnp.float32)
    mu2 = mu.reshape(b, 1).astype(jnp.float32)

    kernel = functools.partial(_fused_kernel, block_rows=block_rows,
                               n=n)
    tern, res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows, LANE), lambda c, i: (c, i, 0)),
            pl.BlockSpec((1, 1), lambda c, i: (c, 0)),
            pl.BlockSpec((1, 1), lambda c, i: (c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_rows, LANE), lambda c, i: (c, i, 0)),
            pl.BlockSpec((1, block_rows, LANE), lambda c, i: (c, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(c3.shape, jnp.float32),
            jax.ShapeDtypeStruct(c3.shape, jnp.float32),
        ],
        interpret=interpret,
    )(c3, t2, mu2)
    return tern.reshape(b, -1)[:, :n], res.reshape(b, -1)[:, :n]
