"""Pallas TPU kernel: threshold statistics for k-selection by bisection.

Global top-k over 10^6..10^10 gradient elements is the hot-spot of STC's
compression step.  A full sort (`jax.lax.top_k`) is MXU-hostile and O(n log n)
in VPU ops; instead we do TPU-friendly *k-selection by threshold bisection*:
each bisection round is one streaming pass that counts elements with
``|x| >= t`` (and sums their magnitudes, which the final round reuses as the
ternary µ numerator).

NOTE: bisection costs ``iters + 1`` (default 33) full streaming passes over
HBM per selection.  It is kept as (a) the reference selector and (b) the
rare-case fallback of the single-pass histogram selector in
:mod:`.hist_select`, which replaces it on the hot path (≤3 passes).

The kernel tiles the (padded, reshaped to (M, 128)) input into VMEM blocks of
``(block_rows, 128)`` and accumulates scalar partials across the sequential
TPU grid into a (1, 1) output block (same output block for every grid step —
the canonical Pallas reduction pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._util import (DEFAULT_BLOCK_ROWS, LANE, PASSES, pad_2d,
                    resolve_block_rows, resolve_interpret)

__all__ = ["threshold_stats", "topk_threshold", "LANE", "DEFAULT_BLOCK_ROWS"]

# back-compat alias: older call sites import the padder from this module
_pad_2d = pad_2d


def _stats_kernel(x_ref, t_ref, cnt_ref, sum_ref, *, block_rows: int, n: int):
    i = pl.program_id(0)
    x = x_ref[...]                       # (block_rows, LANE) fp32
    t = t_ref[0, 0]

    # global element index of each lane slot, to mask the zero padding
    row = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    gidx = (i * block_rows + row) * LANE + col
    valid = gidx < n

    a = jnp.abs(x)
    m = (a >= t) & valid
    c = jnp.sum(m.astype(jnp.int32))
    s = jnp.sum(jnp.where(m, a, jnp.zeros_like(a)))

    @pl.when(i == 0)
    def _init():
        cnt_ref[0, 0] = jnp.zeros((), jnp.int32)
        sum_ref[0, 0] = jnp.zeros((), jnp.float32)

    cnt_ref[0, 0] += c
    sum_ref[0, 0] += s


def threshold_stats(
    x_flat: jnp.ndarray,
    thresh: jnp.ndarray,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
):
    """(count, sum|x|) over entries of ``x_flat`` with ``|x| >= thresh``.

    x_flat: flat fp32 vector (any length); thresh: scalar fp32.
    """
    interpret = resolve_interpret(interpret)
    block_rows = resolve_block_rows(block_rows, interpret)
    PASSES.record("threshold_stats")
    n = x_flat.size
    x2 = pad_2d(x_flat.astype(jnp.float32), block_rows)
    m_rows = x2.shape[0]
    grid = (m_rows // block_rows,)
    t2 = thresh.reshape(1, 1).astype(jnp.float32)

    kernel = functools.partial(_stats_kernel, block_rows=block_rows, n=n)
    cnt, s = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2, t2)
    return cnt[0, 0], s[0, 0]


def topk_threshold(
    x_flat: jnp.ndarray,
    k: int,
    *,
    iters: int = 32,
    block_rows: int | None = None,
    interpret: bool | None = None,
):
    """Bisection k-selection driving the stats kernel (``iters + 1`` passes).

    Returns ``(thresh, count, sum_abs)`` where ``count = #{|x| >= thresh} >= k``
    and ``sum_abs`` is the magnitude mass above the threshold (the µ numerator).
    """
    interpret = resolve_interpret(interpret)
    # fori_loop traces the body once; record the logical pass count explicitly
    # (iters bisection rounds; the final stats call records itself).
    PASSES.record("bisect_round", iters - 1)
    a_max = jnp.max(jnp.abs(x_flat)).astype(jnp.float32)
    hi0 = a_max * jnp.float32(1.0 + 1e-6) + jnp.float32(1e-30)
    lo0 = jnp.float32(0.0)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt, _ = threshold_stats(
            x_flat, mid, block_rows=block_rows, interpret=interpret
        )
        keep = cnt >= k
        return jnp.where(keep, mid, lo), jnp.where(keep, hi, mid)

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
    cnt, s = threshold_stats(x_flat, lo, block_rows=block_rows,
                             interpret=interpret)
    return lo, cnt, s
