"""Mixture-of-Experts FFN: token-choice top-k router, shared + routed experts.

Dispatch is the TPU-native sort-based formulation: tokens are argsorted by
expert id and pushed through `jax.lax.ragged_dot` (grouped matmul over the
expert dimension), which gives the *true* active-expert FLOPs
(2·T·k·d·d_ff per matmul) instead of the quadratic one-hot-einsum dispatch.
Expert weights are tensor-sharded on their hidden (d_expert) dim over the
``model`` axis -- token routing stays local to the data shard, so the MoE
introduces no all_to_all in the baseline sharding (see DESIGN.md; an
expert-parallel all_to_all layout is a recorded hillclimb lever).

Includes the standard switch-style load-balance auxiliary loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import MoEConfig
from .layers import dense_init, mlp_apply, mlp_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, d_model: int, cfg: MoEConfig, act: str = "swiglu"):
    ks = jax.random.split(key, 3 + cfg.n_shared)
    d_e = cfg.d_expert
    e = cfg.n_experts
    scale = 1.0 / jnp.sqrt(d_model)
    p = {
        "router": dense_init(ks[0], d_model, e, scale=0.02),
        # stacked expert weights: (E, d, f) / (E, f, d)
        "w_gate": jax.random.normal(ks[1], (e, d_model, d_e), jnp.float32) * scale,
        "w_up": jax.random.normal(ks[2], (e, d_model, d_e), jnp.float32) * scale,
        "w_down": jax.random.normal(
            jax.random.fold_in(ks[2], 1), (e, d_e, d_model), jnp.float32
        ) * (1.0 / jnp.sqrt(d_e)),
    }
    for i in range(cfg.n_shared):
        p[f"shared_{i}"] = mlp_init(ks[3 + i], d_model, d_e, act)
    return p


def moe_apply(params, x: jnp.ndarray, cfg: MoEConfig, act: str = "swiglu"):
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    k = cfg.top_k
    e = cfg.n_experts

    logits = xt @ params["router"].astype(x.dtype)               # (T, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balance aux loss (switch-transformer style) -----------------
    me = jnp.mean(probs, axis=0)                                 # (E,)
    one_hot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)   # (T,k,E)
    ce = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)              # tokens/expert
    aux = cfg.aux_loss_coef * e * jnp.sum(me * ce) / k

    if cfg.dispatch == "capacity":
        out = _capacity_dispatch(params, xt, expert_idx, gate_vals, cfg, act)
    else:
        out = _ragged_dispatch(params, xt, expert_idx, gate_vals, cfg, act)

    for i in range(cfg.n_shared):
        out = out + mlp_apply(
            jax.tree.map(lambda w: w.astype(x.dtype), params[f"shared_{i}"]),
            xt, act)
    return out.reshape(b, s, d), aux


def _ragged_dispatch(params, xt, expert_idx, gate_vals, cfg: MoEConfig,
                     act: str):
    """Sort-based exact dispatch through jax.lax.ragged_dot."""
    t, d = xt.shape
    k, e = cfg.top_k, cfg.n_experts
    flat_expert = expert_idx.reshape(-1)                         # (T*k,)
    sort_idx = jnp.argsort(flat_expert)                          # (T*k,)
    token_of = sort_idx // k                                     # source token
    xs = jnp.take(xt, token_of, axis=0)                          # (T*k, d)
    group_sizes = jnp.bincount(flat_expert, length=e).astype(jnp.int32)

    gate_h = jax.lax.ragged_dot(xs, params["w_gate"].astype(xt.dtype),
                                group_sizes)
    up_h = jax.lax.ragged_dot(xs, params["w_up"].astype(xt.dtype),
                              group_sizes)
    h = jax.nn.silu(gate_h) * up_h if act == "swiglu" else jax.nn.gelu(up_h)
    out_s = jax.lax.ragged_dot(h, params["w_down"].astype(xt.dtype),
                               group_sizes)

    gates_sorted = jnp.take(gate_vals.reshape(-1), sort_idx)     # (T*k,)
    out_s = out_s * gates_sorted[:, None].astype(out_s.dtype)
    return jnp.zeros((t, d), out_s.dtype).at[token_of].add(out_s)


def _capacity_dispatch(params, xt, expert_idx, gate_vals, cfg: MoEConfig,
                       act: str):
    """Fixed-capacity dispatch: gather tokens into (E, C, d) buffers, one
    batched einsum per matmul, scatter back.  FLOPs = capacity_factor x the
    active-expert cost (the HLO accounting matches the MODEL_FLOPS roofline,
    unlike ragged_dot's CPU lowering).  Overflow tokens are DROPPED (their
    gate contribution is zero) -- the standard switch/MaxText trade-off.
    """
    t, d = xt.shape
    k, e = cfg.top_k, cfg.n_experts
    cap = max(int(t * k * cfg.capacity_factor / e + 0.999), 8)
    cap = min(cap, t * k)

    flat_expert = expert_idx.reshape(-1)                         # (T*k,)
    sort_idx = jnp.argsort(flat_expert)
    grp = jnp.take(flat_expert, sort_idx)                        # sorted ids
    counts = jnp.bincount(flat_expert, length=e)
    starts = jnp.cumsum(counts) - counts                         # (E,)
    rank = jnp.arange(t * k) - jnp.take(starts, grp)             # pos in group
    keep = rank < cap
    dest = jnp.where(keep, grp * cap + rank, e * cap)            # pad slot

    token_of = sort_idx // k                                     # (T*k,)
    buf = jnp.zeros((e * cap + 1, d), xt.dtype)
    buf = buf.at[dest].set(jnp.take(xt, token_of, axis=0))
    xe = buf[: e * cap].reshape(e, cap, d)                       # (E, C, d)

    gate_h = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(xt.dtype))
    up_h = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(xt.dtype))
    h = jax.nn.silu(gate_h) * up_h if act == "swiglu" else jax.nn.gelu(up_h)
    oe = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(xt.dtype))

    oe_flat = jnp.concatenate(
        [oe.reshape(e * cap, d), jnp.zeros((1, d), oe.dtype)], axis=0)
    out_s = jnp.take(oe_flat, jnp.where(keep, dest, e * cap), axis=0)
    gates_sorted = jnp.take(gate_vals.reshape(-1), sort_idx)
    out_s = out_s * (gates_sorted * keep)[:, None].astype(out_s.dtype)
    return jnp.zeros((t, d), out_s.dtype).at[token_of].add(out_s)
