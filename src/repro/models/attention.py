"""Attention: GQA (+ optional QKV bias), causal / sliding-window / cross,
memory-efficient chunked online-softmax, and single-token decode with KV cache.

The chunked formulation (lax.scan over KV chunks with an online softmax) keeps
the materialized score block at (B, KV, rep, Sq, C) instead of (B, H, Sq, Skv),
which is what lets the 4k/32k dry-runs fit HBM without a handwritten flash
kernel -- and it lowers on any backend (the dry-run compiles on CPU, where a
Mosaic kernel would not).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .flash import flash_attention
from .layers import apply_rope, dense_init, rope_freqs

__all__ = ["KVCache", "attn_init", "attn_apply", "attn_decode", "init_kv_cache",
           "chunked_attention"]

NEG_INF = -1e30

# "flash": custom-VJP O(S·d)-residual attention (default).
# "chunked": naive online-softmax scan (reference; O(S²) bwd residuals).
ATTN_IMPL = "flash"

# §Perf hook (decode): when set (by launch.serve), applied to q/k/v/scores in
# attn_decode to pin the attention computation to a chosen layout -- used to
# force fully-local decode attention when head counts don't divide the model
# axis (see launch/serve.make_decode_step cache_mode="local").
DECODE_SHARD_HINT = None


def _attention(q, k, v, *, causal, window=0, chunk=1024, impl=None):
    impl = impl or ATTN_IMPL
    if impl == "flash":
        return flash_attention(q, k, v, causal, window, chunk)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             chunk=chunk)


class KVCache(NamedTuple):
    k: jnp.ndarray      # (B, S_cache, KV, hd)
    v: jnp.ndarray      # (B, S_cache, KV, hd)
    idx: jnp.ndarray    # scalar int32: number of valid positions written
    ring: bool = False  # True -> S_cache is a sliding window ring buffer


def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), jnp.float32)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), jnp.float32)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), jnp.float32)
    return p


def chunked_attention(
    q: jnp.ndarray,            # (B, Sq, H, hd)
    k: jnp.ndarray,            # (B, Skv, KV, hd)
    v: jnp.ndarray,            # (B, Skv, KV, hd)
    *,
    causal: bool,
    window: int = 0,           # 0 = unbounded
    q_offset: jnp.ndarray | int = 0,   # absolute position of q[0]
    chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention, scanning KV in chunks. Returns (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    hdv = v.shape[3]                                 # may differ from hd (MLA)
    rep = h // kv
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = q.reshape(b, sq, kv, rep, hd).astype(jnp.float32) * scale
    kc = k.reshape(b, n_chunks, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kv, hdv).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(sq)                    # (Sq,)

    def body(carry, inputs):
        m, l, acc = carry
        ci, kch, vch = inputs                            # kch: (B, C, KV, hd)
        kv_pos = ci * chunk + jnp.arange(chunk)          # (C,)
        s = jnp.einsum("bqgrd,bcgd->bgrqc", qg, kch.astype(jnp.float32))
        valid = (kv_pos[None, :] < skv)                  # mask the zero padding
        if causal:
            valid = valid & (kv_pos[None, :] <= q_pos[:, None])
        if window:
            valid = valid & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(valid[None, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrqc,bcgd->bgrqd", p, vch.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, kv, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, rep, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, rep, sq, hdv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hdv).astype(q.dtype)


def _project_qkv(params, x, n_heads, n_kv_heads, head_dim):
    b, s, _ = x.shape
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    return (q.reshape(b, s, n_heads, head_dim),
            k.reshape(b, s, n_kv_heads, head_dim),
            v.reshape(b, s, n_kv_heads, head_dim))


def attn_apply(
    params, x: jnp.ndarray, *, n_heads: int, n_kv_heads: int, head_dim: int,
    rope_theta: float = 10000.0, causal: bool = True, window: int = 0,
    memory: Optional[jnp.ndarray] = None, chunk: int = 1024,
    positions: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Full-sequence attention. ``memory`` switches to cross-attention
    (k/v projected from memory, no causal mask, no RoPE on memory keys)."""
    b, s, _ = x.shape
    if memory is None:
        q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
        pos = positions if positions is not None else jnp.arange(s)
        cos, sin = rope_freqs(pos, head_dim, rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        out = _attention(q, k, v, causal=causal, window=window, chunk=chunk)
    else:
        sm = memory.shape[1]
        q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, n_heads, head_dim)
        k = (memory @ params["wk"].astype(x.dtype)).reshape(b, sm, n_kv_heads, head_dim)
        v = (memory @ params["wv"].astype(x.dtype)).reshape(b, sm, n_kv_heads, head_dim)
        out = _attention(q, k, v, causal=False, window=0, chunk=chunk)
    return out.reshape(b, s, n_heads * head_dim) @ params["wo"].astype(x.dtype)


def init_kv_cache(batch: int, s_cache: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, ring: bool = False) -> KVCache:
    shape = (batch, s_cache, n_kv_heads, head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        idx=jnp.zeros((), jnp.int32), ring=ring,
    )


def attn_decode(
    params, x: jnp.ndarray, cache: KVCache, *, n_heads: int, n_kv_heads: int,
    head_dim: int, rope_theta: float = 10000.0, window: int = 0,
    memory: Optional[jnp.ndarray] = None,
) -> tuple[jnp.ndarray, KVCache]:
    """One-token decode: x is (B, 1, d). Returns (out (B,1,d), new_cache).

    Full cache: write at idx. Sliding window (``cache.ring``): write at
    idx % S_cache; positions beyond the window are never attended because the
    ring only holds the last S_cache = window tokens.
    """
    b = x.shape[0]
    if memory is not None:
        sm = memory.shape[1]
        q = (x @ params["wq"].astype(x.dtype)).reshape(b, 1, n_heads, head_dim)
        k = (memory @ params["wk"].astype(x.dtype)).reshape(b, sm, n_kv_heads, head_dim)
        v = (memory @ params["wv"].astype(x.dtype)).reshape(b, sm, n_kv_heads, head_dim)
        out = _dense_decode_attn(q, k, v, jnp.ones((sm,), bool))
        return out.reshape(b, 1, n_heads * head_dim) @ params["wo"].astype(x.dtype), cache

    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    pos = cache.idx[None]                                     # absolute position
    cos, sin = rope_freqs(pos, head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if DECODE_SHARD_HINT is not None:
        q = DECODE_SHARD_HINT(q)
        k = DECODE_SHARD_HINT(k)
        v = DECODE_SHARD_HINT(v)

    s_cache = cache.k.shape[1]
    slot = jnp.where(cache.ring, cache.idx % s_cache, cache.idx)
    new_k = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
    n_valid = jnp.minimum(cache.idx + 1, s_cache)
    valid = (jnp.arange(s_cache) < n_valid)
    out = _dense_decode_attn(q, new_k, new_v, valid)
    out = out.reshape(b, 1, n_heads * head_dim) @ params["wo"].astype(x.dtype)
    return out, KVCache(k=new_k, v=new_v, idx=cache.idx + 1, ring=cache.ring)


def _dense_decode_attn(q, k, v, valid):
    """q: (B,1,H,hd); k/v: (B,S,KV,hd); valid: (S,) bool."""
    b, _, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qg = q.reshape(b, kv, rep, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bgrd,bcgd->bgrc", qg, k.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrc,bcgd->bgrd", p, v.astype(jnp.float32))
    return o.reshape(b, 1, h, hd).astype(q.dtype)
