"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:

    r_t = σ(W_a x_t)                       (recurrence gate)
    i_t = σ(W_x x_t)                       (input gate)
    a_t = exp(-c · softplus(Λ) · r_t)      (diagonal recurrence, 0<a<1)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The temporal-mixing block is conv1d(4) → RG-LRU → out-proj.  Training uses
``jax.lax.associative_scan`` over the (a, b) pairs (the diagonal linear
recurrence composes associatively: (a2,b2)∘(a1,b1) = (a1·a2, a2·b1+b2)),
which parallelizes to O(log T) depth on TPU.  Decode is the O(1) recurrence.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import RGLRUConfig
from .layers import dense_init

__all__ = ["RGLRUCache", "rglru_init", "rglru_apply", "rglru_decode",
           "init_rglru_cache"]


class RGLRUCache(NamedTuple):
    h: jnp.ndarray       # (B, w) recurrent state
    conv: jnp.ndarray    # (B, k-1, w) conv history
    idx: jnp.ndarray


def _width(d_model: int, cfg: RGLRUConfig) -> int:
    return cfg.block_width or d_model


def rglru_init(key, d_model: int, cfg: RGLRUConfig):
    w = _width(d_model, cfg)
    ks = jax.random.split(key, 5)
    # Λ init so that a^c ∈ (0.9, 0.999) at r=1 (paper's init range)
    lam = jnp.log(jnp.expm1(
        -jnp.log(jnp.linspace(0.9, 0.999, w).astype(jnp.float32)) / cfg.c))
    return {
        "w_in": dense_init(ks[0], d_model, w),
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, w), jnp.float32) * 0.1,
        "w_a": dense_init(ks[2], w, w),
        "w_x": dense_init(ks[3], w, w),
        "lam": lam,
        "w_out": dense_init(ks[4], w, d_model),
    }


def _gates(params, x, cfg: RGLRUConfig):
    """x: (..., w) -> (a, b) of the recurrence h' = a·h + b."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"])
    i = jax.nn.sigmoid(xf @ params["w_x"])
    log_a = -cfg.c * jax.nn.softplus(params["lam"])[..., :] * r
    a = jnp.exp(log_a)
    # multiplier sqrt(1-a^2) computed stably from log_a
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i * xf)
    return a, b


def _conv(x, conv_w, tail=None):
    k = conv_w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + conv_w[i].astype(x.dtype) * xp[:, i : i + x.shape[1]]
    return out


def rglru_apply(params, x: jnp.ndarray, cfg: RGLRUConfig, d_model: int):
    """Temporal-mixing block over a full sequence. x: (B,S,d) -> (B,S,d)."""
    u = x @ params["w_in"].astype(x.dtype)           # (B,S,w)
    u = _conv(u, params["conv_w"])
    a, b = _gates(params, u, cfg)                    # (B,S,w) fp32

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype) @ params["w_out"].astype(x.dtype)


def init_rglru_cache(batch: int, d_model: int, cfg: RGLRUConfig,
                     dtype=jnp.float32):
    w = _width(d_model, cfg)
    return RGLRUCache(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, cfg.d_conv - 1, w), dtype),
        idx=jnp.zeros((), jnp.int32),
    )


def rglru_decode(params, x: jnp.ndarray, cache: RGLRUCache, cfg: RGLRUConfig,
                 d_model: int):
    """One-token decode. x: (B,1,d)."""
    u = x @ params["w_in"].astype(x.dtype)           # (B,1,w)
    hist = jnp.concatenate([cache.conv.astype(x.dtype), u], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", hist, params["conv_w"].astype(x.dtype))
    new_conv = hist[:, 1:, :]
    a, b = _gates(params, conv_out[:, None, :], cfg)
    h_new = a[:, 0] * cache.h + b[:, 0]
    out = h_new.astype(x.dtype)[:, None, :] @ params["w_out"].astype(x.dtype)
    return out, RGLRUCache(h=h_new, conv=new_conv, idx=cache.idx + 1)
