"""Flash-style attention with a custom VJP, in pure jnp.

The naive chunked online-softmax (attention.chunked_attention) is numerically
fine but its ``lax.scan`` saves every per-chunk probability block for the
backward pass -- ~O(S^2) residuals per layer (measured ~22 GiB/layer on the
phi3 train_4k dry-run).  This implementation saves only ``(q, k, v, out,
lse)`` -- O(S·d) -- and recomputes the probability blocks chunk-by-chunk in a
hand-written backward, exactly like the FlashAttention backward:

    D    = rowsum(dO ⊙ O)
    p_c  = exp(q·k_cᵀ·scale - lse)
    dV_c = p_cᵀ · dO
    dP_c = dO · v_cᵀ
    dS_c = p_c ⊙ (dP_c - D)
    dQ  += scale · dS_c · k_c ;   dK_c = scale · dS_cᵀ · q

Supports GQA grouping, causal masks, sliding windows, cross attention, and
v-head-dim != qk-head-dim (MLA).  On TPU the chunk matmuls map to the MXU; no
Mosaic kernel is needed, so the same code lowers on the CPU dry-run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["flash_attention"]

NEG_INF = -1e30

# §Perf lever (A4): dtype of the recomputed probability blocks in fwd/bwd.
# bf16 halves the dominant score-chain HBM traffic; the softmax statistics
# (m, l, lse, D) and accumulators stay fp32.
P_BLOCK_DTYPE = jnp.float32


def _chunk_kv(x, chunk):
    """(B, Skv, KV, h) -> (n_chunks, B, chunk, KV, h), zero-padded."""
    b, skv, kv, h = x.shape
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return x.reshape(b, n_chunks, chunk, kv, h).transpose(1, 0, 2, 3, 4)


def _mask(q_pos, kv_pos, skv, causal, window):
    valid = kv_pos[None, :] < skv
    if causal:
        valid = valid & (kv_pos[None, :] <= q_pos[:, None])
    if window:
        valid = valid & (kv_pos[None, :] > q_pos[:, None] - window)
    return valid                                    # (Sq, C)


def _fwd_scan(q, k, v, causal, window, chunk, q_offset):
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    hdv = v.shape[3]
    rep = h // kv
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    chunk = min(chunk, skv)

    qg = q.reshape(b, sq, kv, rep, hd).astype(jnp.float32) * scale
    kc = _chunk_kv(k, chunk)
    vc = _chunk_kv(v, chunk)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry
        ci, kch, vch = inp
        kv_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqgrd,bcgd->bgrqc", qg, kch.astype(jnp.float32))
        valid = _mask(q_pos, kv_pos, skv, causal, window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrqc,bcgd->bgrqd", p.astype(P_BLOCK_DTYPE),
                        vch.astype(P_BLOCK_DTYPE),
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, kv, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, rep, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, rep, sq, hdv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(kc.shape[0]), kc, vc))
    lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), jnp.float32(1e30))
    out = acc / jnp.maximum(l, 1e-30)[..., None]    # (b,kv,rep,sq,hdv)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    chunk: int = 1024, q_offset: int = 0):
    """q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd[v]). Returns (B,Sq,H,hdv)."""
    out, _ = _fwd_scan(q, k, v, causal, window, chunk, q_offset)
    b, sq, h, hd = q.shape
    return (out.transpose(0, 3, 1, 2, 4)
               .reshape(b, sq, h, v.shape[3]).astype(q.dtype))


def _flash_fwd(q, k, v, causal, window, chunk, q_offset):
    out, lse = _fwd_scan(q, k, v, causal, window, chunk, q_offset)
    b, sq, h, hd = q.shape
    o = (out.transpose(0, 3, 1, 2, 4)
            .reshape(b, sq, h, v.shape[3]).astype(q.dtype))
    return o, (q, k, v, out, lse)


def _flash_bwd(causal, window, chunk, q_offset, res, do):
    q, k, v, out, lse = res                          # out: (b,kv,rep,sq,hdv)
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    hdv = v.shape[3]
    rep = h // kv
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    chunk_sz = min(chunk, skv)

    qg = q.reshape(b, sq, kv, rep, hd).astype(jnp.float32)
    dog = (do.reshape(b, sq, kv, rep, hdv)
             .transpose(0, 2, 3, 1, 4).astype(jnp.float32))  # (b,kv,rep,sq,hdv)
    dmass = jnp.sum(dog * out, axis=-1)              # D: (b,kv,rep,sq)

    kc = _chunk_kv(k, chunk_sz)
    vc = _chunk_kv(v, chunk_sz)
    n_chunks = kc.shape[0]
    q_pos = q_offset + jnp.arange(sq)

    def body(dq_acc, inp):
        ci, kch, vch = inp
        kv_pos = ci * chunk_sz + jnp.arange(chunk_sz)
        s = jnp.einsum("bqgrd,bcgd->bgrqc", qg * scale,
                       kch.astype(jnp.float32))
        valid = _mask(q_pos, kv_pos, skv, causal, window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None]).astype(P_BLOCK_DTYPE)  # recomputed
        dv_c = jnp.einsum("bgrqc,bgrqd->bcgd", p,
                          dog.astype(P_BLOCK_DTYPE),
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bgrqd,bcgd->bgrqc", dog.astype(P_BLOCK_DTYPE),
                        vch.astype(P_BLOCK_DTYPE),
                        preferred_element_type=jnp.float32)
        ds = (p.astype(jnp.float32) * (dp - dmass[..., None]) *
              scale).astype(P_BLOCK_DTYPE)
        dq_acc = dq_acc + jnp.einsum("bgrqc,bcgd->bqgrd", ds,
                                     kch.astype(P_BLOCK_DTYPE),
                                     preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bgrqc,bqgrd->bcgd", ds,
                          qg.astype(P_BLOCK_DTYPE),
                          preferred_element_type=jnp.float32)
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros((b, sq, kv, rep, hd), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(
        body, dq0, (jnp.arange(n_chunks), kc, vc))
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(b, -1, kv, hd)[:, :skv]
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(b, -1, kv, hdv)[:, :skv]
    dq = dq.reshape(b, sq, h, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
