"""Unified model configuration for the 10 assigned architectures.

One ``ModelConfig`` drives :mod:`repro.models.transformer`, which composes
attention / MLA / MoE / SSD / RG-LRU blocks per ``block_pattern``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["MoEConfig", "MLAConfig", "SSMConfig", "RGLRUConfig",
           "EncoderConfig", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0            # shared (always-on) experts, DeepSeek-style
    d_expert: int = 0            # FFN hidden size per routed expert
    aux_loss_coef: float = 0.01  # load-balance auxiliary loss
    first_dense: int = 0         # leading layers that stay dense (DeepSeek: 1)
    # "ragged": sort + jax.lax.ragged_dot (exact, no token dropping; CPU HLO
    #           overcounts FLOPs ~E x because the CPU lowering unrolls dense
    #           per-group dots -- fine on TPU/Mosaic).
    # "capacity": fixed-capacity gather -> batched einsum -> scatter
    #           (MaxText-style; drops overflow tokens at capacity_factor).
    dispatch: str = "ragged"
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 = full-rank queries (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU temporal-mixing block."""

    d_conv: int = 4
    c: float = 8.0               # the RG-LRU exponent scale
    block_width: int = 0         # lru width; 0 -> d_model


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder for enc-dec archs (whisper). Frontend is a stub: input_specs
    provides (batch, n_frames, d_model) frame embeddings."""

    n_layers: int = 24
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # block pattern: tuple of per-layer kinds cycled over n_layers.
    # kinds: "attn", "mla", "ssd", "rglru", "local"  (local = sliding attn)
    block_pattern: Tuple[str, ...] = ("attn",)
    mlp_act: str = "swiglu"      # swiglu | gelu
    attn_bias: bool = False      # qwen2-style QKV bias
    rope_theta: float = 10000.0
    sliding_window: int = 0      # 0 = full attention ("local" blocks need > 0)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    n_prefix_tokens: int = 0     # VLM: patch-embedding prefix length
    remat: bool = True           # checkpoint each block (training)
    logit_chunk: int = 0         # 0 = unchunked LM head / loss

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top_k routed experts).
        Used for MODEL_FLOPS = 6·N_active·D in the roofline."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        e = self.moe
        mult = 3 if self.mlp_act == "swiglu" else 2
        per_expert = mult * self.d_model * e.d_expert
        n_moe_layers = max(self.n_layers - e.first_dense, 0)
        inactive = n_moe_layers * (e.n_experts - e.top_k) * per_expert
        return total - inactive

    def param_count(self) -> int:
        """Exact parameter count of the constructed model (counted at init in
        tests; this analytic version is used for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            total += d if kind == "ssd" else 2 * d  # RMSNorm gains
            if kind in ("attn", "local"):
                q = d * self.n_heads * hd + (self.n_heads * hd if self.attn_bias else 0)
                kv = 2 * (d * self.n_kv_heads * hd + (self.n_kv_heads * hd if self.attn_bias else 0))
                o = self.n_heads * hd * d
                total += q + kv + o
            elif kind == "mla":
                m = self.mla
                total += d * m.kv_lora_rank                       # W_dkv
                total += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                total += d * m.qk_rope_head_dim                   # shared rope key
                total += d * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                total += self.n_heads * m.v_head_dim * d          # W_o
            elif kind == "ssd":
                s = self.ssm
                d_in = s.expand * d
                nheads = d_in // s.head_dim
                total += d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
                total += s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
                total += 3 * nheads                               # A_log, D, dt_bias
                total += d_in                                     # gate norm
                total += d_in * d                                 # out proj
            elif kind == "rglru":
                r = self.rglru
                w = r.block_width or d
                # w_in + w_a + w_x + conv + Λ + w_out
                total += d * w + 2 * w * w + r.d_conv * w + w + w * d
            # MLP / MoE
            if kind == "ssd":
                continue  # mamba block has no separate MLP
            if self.moe is not None and i >= self.moe.first_dense:
                e = self.moe
                total += d * e.n_experts                          # router
                mult = 3 if self.mlp_act == "swiglu" else 2
                total += e.n_experts * mult * d * e.d_expert
                total += e.n_shared * mult * d * (e.d_expert or self.d_ff)
            else:
                mult = 3 if self.mlp_act == "swiglu" else 2
                total += mult * d * self.d_ff
        total += d  # final norm
        if self.n_prefix_tokens:
            total += d * d  # VLM projector
        if self.encoder is not None:
            enc = self.encoder
            total += d  # encoder final norm
            for _ in range(enc.n_layers):
                total += 2 * d
                total += 4 * d * self.n_heads * hd                # self-attn (MHA)
                mult = 3 if self.mlp_act == "swiglu" else 2
                total += mult * d * self.d_ff
            # decoder cross-attention (added to every decoder layer)
            total += self.n_layers * (4 * d * self.n_heads * hd + d)
        return total
