"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD algorithm: the sequence is split into chunks of length L; within a
chunk the output is the masked "attention-like" quadratic term (MXU-friendly),
across chunks a small recurrence over per-chunk states (h: (heads, p, n))
propagates history.  This is the TPU-native adaptation: the chunk matmuls map
to the MXU and the cross-chunk scan is O(T/L) sequential steps.

Decode is the O(1) recurrence  h' = exp(dt·A)·h + dt·B⊗x;  y = C·h + D·x.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import SSMConfig
from .layers import dense_init

__all__ = ["SSMCache", "ssd_init", "ssd_apply", "ssd_decode", "init_ssm_cache"]


class SSMCache(NamedTuple):
    state: jnp.ndarray      # (B, H, p, n) recurrent state
    conv: jnp.ndarray       # (B, d_conv-1, d_conv_channels) conv tail
    idx: jnp.ndarray


def _dims(d_model: int, cfg: SSMConfig):
    d_in = cfg.expand * d_model
    n_heads = d_in // cfg.head_dim
    d_conv_ch = d_in + 2 * cfg.n_groups * cfg.d_state
    return d_in, n_heads, d_conv_ch


def ssd_init(key, d_model: int, cfg: SSMConfig):
    d_in, n_heads, d_conv_ch = _dims(d_model, cfg)
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_in + 2 * cfg.n_groups * cfg.d_state + n_heads  # z,x,B,C,dt
    return {
        "w_in": dense_init(ks[0], d_model, d_proj),
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, d_conv_ch), jnp.float32) * 0.1,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_g": jnp.ones((d_in,), jnp.float32),
        "w_out": dense_init(ks[2], d_in, d_model),
    }


def _split_proj(proj, d_in, n_groups, d_state, n_heads):
    zs = d_in
    xs = d_in
    bs = n_groups * d_state
    cs = n_groups * d_state
    z, xbc_dt = jnp.split(proj, [zs], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [xs + bs + cs], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_tail=None):
    """Depthwise causal conv over (B,S,C). conv_tail: (B, k-1, C) history."""
    k = conv_w.shape[0]
    if conv_tail is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_tail.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)                     # (B, S+k-1, C)
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + conv_w[i].astype(xbc.dtype) * xp[:, i : i + xbc.shape[1]]
    return jax.nn.silu(out)


def _segsum(x):
    """log-space cumulative segment sums: out[i,j] = sum_{j<l<=i} x[l]."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD. xh: (B,S,H,p); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,G,n).

    Returns (y (B,S,H,p), final_state (B,H,p,n)).
    """
    b, s, h, p = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    L = min(chunk, s)
    nc = s // L
    assert s % L == 0, f"seq {s} must be divisible by chunk {L}"
    rep = h // g

    # reshape to chunks
    xc = xh.reshape(b, nc, L, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, L, h).astype(jnp.float32)
    Bc = Bm.reshape(b, nc, L, g, n).astype(jnp.float32)
    Cc = Cm.reshape(b, nc, L, g, n).astype(jnp.float32)
    Bc = jnp.repeat(Bc, rep, axis=3)                             # (b,nc,L,h,n)
    Cc = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * (-jnp.exp(A.astype(jnp.float32)))[None, None, None, :]  # (b,nc,L,h) <= 0

    # 1) intra-chunk (diagonal) term: masked quadratic attention analogue
    seg = _segsum(dA.transpose(0, 1, 3, 2))                      # (b,nc,h,L,L)
    decay = jnp.exp(seg)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)            # (b,nc,h,L,L)
    y_diag = jnp.einsum("bchls,bcsh,bcshp->bclhp",
                        scores * decay, dtc, xc)

    # 2) per-chunk states: h_c = sum_l decay_to_end[l] * dt_l * B_l ⊗ x_l
    dA_cum = jnp.cumsum(dA, axis=2)                              # (b,nc,L,h)
    decay_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)           # (b,nc,L,h)
    states = jnp.einsum("bclh,bclh,bclhn,bclhp->bchpn",
                        decay_end, dtc, Bc, xc)                  # (b,nc,h,p,n)

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                   # (b,nc,h)

    def step(hprev, inp):
        st, dec = inp                                            # (b,h,p,n), (b,h)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev                                       # emit state BEFORE chunk

    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    states_t = states.transpose(1, 0, 2, 3, 4)                   # (nc,b,h,p,n)
    decay_t = chunk_decay.transpose(1, 0, 2)                     # (nc,b,h)
    h_final, h_in = jax.lax.scan(step, h0, (states_t, decay_t))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                         # (b,nc,h,p,n)

    # 4) off-diagonal term: contribution of the incoming state to each position
    state_decay = jnp.exp(dA_cum)                                # decay from chunk start
    y_off = jnp.einsum("bclhn,bclh,bchpn->bclhp", Cc, state_decay, h_in)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, h_final


def ssd_apply(params, x: jnp.ndarray, cfg: SSMConfig, d_model: int):
    """Full Mamba-2 mixer block (no separate MLP). x: (B,S,d) -> (B,S,d)."""
    b, s, _ = x.shape
    d_in, n_heads, _ = _dims(d_model, cfg)
    g, n = cfg.n_groups, cfg.d_state

    proj = x @ params["w_in"].astype(x.dtype)
    z, xbc, dt = _split_proj(proj, d_in, g, n, n_heads)
    xbc = _causal_conv(xbc, params["conv_w"])
    xi, Bm, Cm = jnp.split(xbc, [d_in, d_in + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"][None, None, :])

    xh = xi.reshape(b, s, n_heads, cfg.head_dim)
    Bm = Bm.reshape(b, s, g, n)
    Cm = Cm.reshape(b, s, g, n)
    y, _ = ssd_scan(xh, dt, params["A_log"], Bm, Cm, cfg.chunk)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(x.dtype)

    # gated RMSNorm (mamba2's norm-before-out-proj)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) *
         params["norm_g"]).astype(x.dtype)
    return y @ params["w_out"].astype(x.dtype)


def init_ssm_cache(batch: int, d_model: int, cfg: SSMConfig, dtype=jnp.float32):
    d_in, n_heads, d_conv_ch = _dims(d_model, cfg)
    return SSMCache(
        state=jnp.zeros((batch, n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.d_conv - 1, d_conv_ch), dtype),
        idx=jnp.zeros((), jnp.int32),
    )


def ssd_decode(params, x: jnp.ndarray, cache: SSMCache, cfg: SSMConfig,
               d_model: int):
    """One-token decode. x: (B,1,d). O(1) state update."""
    b = x.shape[0]
    d_in, n_heads, d_conv_ch = _dims(d_model, cfg)
    g, n = cfg.n_groups, cfg.d_state

    proj = x @ params["w_in"].astype(x.dtype)
    z, xbc, dt = _split_proj(proj, d_in, g, n, n_heads)

    # conv with cached tail
    hist = jnp.concatenate([cache.conv.astype(x.dtype), xbc], axis=1)  # (B,k,C)
    conv_out = jnp.einsum("bkc,kc->bc", hist, params["conv_w"].astype(x.dtype))
    xbc1 = jax.nn.silu(conv_out)[:, None, :]
    new_conv = hist[:, 1:, :]

    xi, Bm, Cm = jnp.split(xbc1, [d_in, d_in + g * n], axis=-1)
    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    xh = xi.reshape(b, n_heads, cfg.head_dim).astype(jnp.float32)
    Bm = jnp.repeat(Bm.reshape(b, g, n), n_heads // g, axis=1).astype(jnp.float32)
    Cm = jnp.repeat(Cm.reshape(b, g, n), n_heads // g, axis=1).astype(jnp.float32)

    dA = jnp.exp(dt1 * (-jnp.exp(params["A_log"].astype(jnp.float32)))[None, :])
    new_state = (cache.state * dA[..., None, None] +
                 jnp.einsum("bh,bhn,bhp->bhpn", dt1, Bm, xh))
    y = jnp.einsum("bhn,bhpn->bhp", Cm, new_state)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, d_in).astype(x.dtype)

    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) *
         params["norm_g"]).astype(x.dtype)
    out = y @ params["w_out"].astype(x.dtype)
    return out, SSMCache(state=new_state, conv=new_conv, idx=cache.idx + 1)
