"""TransformerLM: composes attention / MLA / MoE / SSD / RG-LRU blocks from a
ModelConfig into a trainable LM, an encoder-decoder (whisper), or a VLM
(prefix patch embeddings).  Pure functional: params are nested dicts.

Public entry points
    init_model(cfg, key)                  -> params
    forward(params, cfg, tokens, ...)     -> (logits, aux_loss)
    lm_loss(params, cfg, tokens, labels)  -> scalar (chunked LM head optional)
    init_cache(cfg, batch, s_cache)       -> per-layer cache list
    decode_step(params, cfg, token, caches, ...) -> (logits, caches)
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention, mla, moe, rglru, ssm
from .config import ModelConfig
from .layers import embed_init, mlp_apply, mlp_init, rms_norm, rms_norm_init

__all__ = ["init_model", "forward", "lm_loss", "init_cache", "decode_step",
           "encode_frames"]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, kind: str, key, layer_idx: int):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    block: dict[str, Any] = {"norm1": rms_norm_init(d)}
    if kind in ("attn", "local"):
        block["mix"] = attention.attn_init(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads, hd, bias=cfg.attn_bias)
    elif kind == "mla":
        block["mix"] = mla.mla_init(ks[0], d, cfg.n_heads, cfg.mla)
    elif kind == "ssd":
        block["mix"] = ssm.ssd_init(ks[0], d, cfg.ssm)
    elif kind == "rglru":
        block["mix"] = rglru.rglru_init(ks[0], d, cfg.rglru)
    else:
        raise ValueError(kind)

    if kind != "ssd":  # mamba2 blocks have no separate MLP
        block["norm2"] = rms_norm_init(d)
        if cfg.moe is not None and layer_idx >= cfg.moe.first_dense:
            block["moe"] = moe.moe_init(ks[1], d, cfg.moe, cfg.mlp_act)
        else:
            block["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_act)

    if cfg.encoder is not None:  # decoder layers get cross-attention
        block["norm_x"] = rms_norm_init(d)
        block["cross"] = attention.attn_init(ks[2], d, cfg.n_heads,
                                             cfg.n_heads, hd)
    return block


def init_model(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 5)
    params: dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
        "blocks": [
            _init_block(cfg, cfg.layer_kind(i), ks[1 + i], i)
            for i in range(cfg.n_layers)
        ],
        "final_norm": rms_norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[-1], cfg.vocab_size, cfg.d_model)
    if cfg.encoder is not None:
        eks = jax.random.split(ks[-2], cfg.encoder.n_layers + 1)
        params["encoder"] = {
            "blocks": [
                {
                    "norm1": rms_norm_init(cfg.d_model),
                    "mix": attention.attn_init(eks[i], cfg.d_model,
                                               cfg.n_heads, cfg.n_heads,
                                               cfg.resolved_head_dim),
                    "norm2": rms_norm_init(cfg.d_model),
                    "mlp": mlp_init(jax.random.fold_in(eks[i], 7),
                                    cfg.d_model, cfg.d_ff, cfg.mlp_act),
                }
                for i in range(cfg.encoder.n_layers)
            ],
            "final_norm": rms_norm_init(cfg.d_model),
        }
    if cfg.n_prefix_tokens:
        params["prefix_proj"] = embed_init(ks[-3], cfg.d_model, cfg.d_model).T
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block_apply(block, cfg: ModelConfig, kind: str, x,
                 memory: Optional[jnp.ndarray], chunk: int):
    h = rms_norm(block["norm1"], x, cfg.norm_eps)
    window = cfg.sliding_window if kind == "local" else (
        cfg.sliding_window if (kind == "attn" and cfg.sliding_window and
                               len(cfg.block_pattern) == 1) else 0)
    if kind in ("attn", "local"):
        mix = attention.attn_apply(
            block["mix"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            causal=True, window=window, chunk=chunk)
    elif kind == "mla":
        mix = mla.mla_apply(block["mix"], h, n_heads=cfg.n_heads, cfg=cfg.mla,
                            rope_theta=cfg.rope_theta, chunk=chunk,
                            window=cfg.sliding_window)
    elif kind == "ssd":
        mix = ssm.ssd_apply(block["mix"], h, cfg.ssm, cfg.d_model)
    elif kind == "rglru":
        mix = rglru.rglru_apply(block["mix"], h, cfg.rglru, cfg.d_model)
    x = x + mix

    if memory is not None and "cross" in block:
        hx = rms_norm(block["norm_x"], x, cfg.norm_eps)
        x = x + attention.attn_apply(
            block["cross"], hx, n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
            head_dim=cfg.resolved_head_dim, memory=memory, chunk=chunk)

    aux = jnp.zeros((), jnp.float32)
    if kind != "ssd":
        h2 = rms_norm(block["norm2"], x, cfg.norm_eps)
        if "moe" in block:
            out, aux = moe.moe_apply(block["moe"], h2, cfg.moe, cfg.mlp_act)
            x = x + out
        else:
            x = x + mlp_apply(
                jax.tree.map(lambda w: w.astype(x.dtype), block["mlp"]),
                h2, cfg.mlp_act)
    return x, aux


def encode_frames(params, cfg: ModelConfig, frames: jnp.ndarray,
                  chunk: int = 1024) -> jnp.ndarray:
    """Run the (whisper) encoder over stub frame embeddings (B, F, d)."""
    x = frames
    for block in params["encoder"]["blocks"]:
        h = rms_norm(block["norm1"], x, cfg.norm_eps)
        x = x + attention.attn_apply(
            block["mix"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
            head_dim=cfg.resolved_head_dim, causal=False, chunk=chunk)
        h2 = rms_norm(block["norm2"], x, cfg.norm_eps)
        x = x + mlp_apply(
            jax.tree.map(lambda w: w.astype(x.dtype), block["mlp"]),
            h2, cfg.mlp_act)
    return rms_norm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            prefix: Optional[jnp.ndarray] = None,
            frames: Optional[jnp.ndarray] = None,
            compute_dtype=jnp.bfloat16, chunk: int = 1024,
            return_hidden: bool = False):
    """tokens: (B, S) int32.  prefix: (B, P, d) VLM patch embeddings.
    frames: (B, F, d) audio frame embeddings (enc-dec).  Returns
    (logits (B, S_total, V), aux_loss)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    if prefix is not None:
        pfx = (prefix.astype(compute_dtype) @
               params["prefix_proj"].astype(compute_dtype))
        x = jnp.concatenate([pfx, x], axis=1)

    memory = None
    if frames is not None:
        memory = encode_frames(params, cfg, frames.astype(compute_dtype), chunk)

    aux_total = jnp.zeros((), jnp.float32)
    for i, block in enumerate(params["blocks"]):
        kind = cfg.layer_kind(i)
        fn = functools.partial(_block_apply, cfg=cfg, kind=kind, chunk=chunk)
        if cfg.remat:
            fn = jax.checkpoint(lambda b, xx, mm, fn=fn: fn(b, x=xx, memory=mm))
            x, aux = fn(block, x, memory)
        else:
            x, aux = fn(block, x=x, memory=memory)
        aux_total = aux_total + aux

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, aux_total
    head = params.get("lm_head", params["embed"])
    logits = x @ head.T.astype(compute_dtype)
    return logits, aux_total


def lm_loss(params, cfg: ModelConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray, *, prefix=None, frames=None,
            compute_dtype=jnp.bfloat16, chunk: int = 1024) -> jnp.ndarray:
    """Causal LM cross-entropy (mean over tokens) + MoE aux loss.

    With ``cfg.logit_chunk > 0`` the LM head + softmax run in sequence chunks
    (never materializing the full (B,S,V) logits) -- the memory-term lever for
    the big-vocab archs.
    """
    hidden, aux = forward(params, cfg, tokens, prefix=prefix, frames=frames,
                          compute_dtype=compute_dtype, chunk=chunk,
                          return_hidden=True)
    if prefix is not None:
        hidden = hidden[:, prefix.shape[1]:, :]      # loss only on text tokens
    head = params.get("lm_head", params["embed"]).T.astype(compute_dtype)

    def ce(h_chunk, y_chunk):
        logits = (h_chunk @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_chunk[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    b, s, _ = hidden.shape
    if cfg.logit_chunk and s > cfg.logit_chunk and s % cfg.logit_chunk == 0:
        nc = s // cfg.logit_chunk
        hc = hidden.reshape(b, nc, cfg.logit_chunk, -1).transpose(1, 0, 2, 3)
        yc = labels.reshape(b, nc, cfg.logit_chunk).transpose(1, 0, 2)

        def body(tot, xy):
            h, y = xy
            return tot + ce(h, y), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, yc))
    else:
        total = ce(hidden, labels)
    return total / (b * s) + aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, s_cache: int,
               dtype=jnp.bfloat16) -> list:
    """Per-layer cache list. 'local' layers get a ring buffer of the window;
    full-attn layers get s_cache slots (sliding_window>0 on a pure-attn config
    turns ALL layers into ring buffers -- the long_500k dense variant)."""
    caches = []
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind in ("attn", "local"):
            use_window = (kind == "local") or (
                cfg.sliding_window and len(cfg.block_pattern) == 1)
            size = min(cfg.sliding_window, s_cache) if use_window and cfg.sliding_window else s_cache
            caches.append(attention.init_kv_cache(
                batch, size, cfg.n_kv_heads, cfg.resolved_head_dim, dtype,
                ring=bool(use_window and cfg.sliding_window and size < s_cache)))
        elif kind == "mla":
            caches.append(mla.init_mla_cache(batch, s_cache, cfg.mla, dtype))
        elif kind == "ssd":
            caches.append(ssm.init_ssm_cache(batch, cfg.d_model, cfg.ssm, dtype))
        elif kind == "rglru":
            caches.append(rglru.init_rglru_cache(batch, cfg.d_model, cfg.rglru,
                                                 dtype))
    return caches


def decode_step(params, cfg: ModelConfig, token: jnp.ndarray, caches: list, *,
                memory: Optional[jnp.ndarray] = None,
                compute_dtype=jnp.bfloat16):
    """One decode step. token: (B, 1) int32 -> (logits (B,1,V), new caches)."""
    x = jnp.take(params["embed"], token, axis=0).astype(compute_dtype)
    new_caches = []
    for i, block in enumerate(params["blocks"]):
        kind = cfg.layer_kind(i)
        h = rms_norm(block["norm1"], x, cfg.norm_eps)
        if kind in ("attn", "local"):
            mix, c = attention.attn_decode(
                block["mix"], h, caches[i], n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
                rope_theta=cfg.rope_theta)
        elif kind == "mla":
            mix, c = mla.mla_decode(block["mix"], h, caches[i],
                                    n_heads=cfg.n_heads, cfg=cfg.mla,
                                    rope_theta=cfg.rope_theta)
        elif kind == "ssd":
            mix, c = ssm.ssd_decode(block["mix"], h, caches[i], cfg.ssm,
                                    cfg.d_model)
        elif kind == "rglru":
            mix, c = rglru.rglru_decode(block["mix"], h, caches[i], cfg.rglru,
                                        cfg.d_model)
        x = x + mix
        new_caches.append(c)

        if memory is not None and "cross" in block:
            hx = rms_norm(block["norm_x"], x, cfg.norm_eps)
            out, _ = attention.attn_decode(
                block["cross"], hx, caches[i], n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_heads, head_dim=cfg.resolved_head_dim,
                memory=memory)
            x = x + out

        if kind != "ssd":
            h2 = rms_norm(block["norm2"], x, cfg.norm_eps)
            if "moe" in block:
                out, _ = moe.moe_apply(block["moe"], h2, cfg.moe, cfg.mlp_act)
                x = x + out
            else:
                x = x + mlp_apply(
                    jax.tree.map(lambda w: w.astype(x.dtype), block["mlp"]),
                    h2, cfg.mlp_act)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    return x @ head.T.astype(compute_dtype), new_caches
