"""Basic layers: norms, MLPs, embeddings, RoPE. Pure-functional (params are
nested dicts of jnp arrays); init in fp32, compute dtype chosen by caller."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init", "rms_norm", "rms_norm_init", "mlp_init", "mlp_apply",
    "embed_init", "rope_freqs", "apply_rope",
]


def dense_init(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def rms_norm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32)}


def rms_norm(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * params["g"]).astype(dt)


def mlp_init(key, d_model: int, d_ff: int, act: str = "swiglu"):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff),
            "w_up": dense_init(ks[1], d_model, d_ff),
            "w_down": dense_init(ks[2], d_ff, d_model),
        }
    return {
        "w_up": dense_init(ks[0], d_model, d_ff),
        "w_down": dense_init(ks[1], d_ff, d_model),
    }


def mlp_apply(params, x: jnp.ndarray, act: str = "swiglu") -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


def embed_init(key, vocab: int, d_model: int):
    return jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02


def rope_freqs(positions: jnp.ndarray, dim: int, theta: float) -> tuple:
    """positions: (..., S) int32 -> (cos, sin) of shape (..., S, dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, dim) with rotary applied over the last dim (paired)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1).astype(x.dtype)
