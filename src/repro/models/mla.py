"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Keys/values are up-projected from a low-rank latent ``c_kv = x @ W_dkv``
(rank ``kv_lora_rank``); a small decoupled RoPE key (``qk_rope_head_dim``,
shared across heads) carries positional information.  The KV cache stores only
``(c_kv, k_rope)`` -- (kv_lora + rope_dim) floats per position instead of
``2·H·hd`` -- which is the whole point of MLA for long-context decode.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .attention import NEG_INF, _attention
from .config import MLAConfig
from .layers import apply_rope, dense_init, rope_freqs

__all__ = ["MLACache", "mla_init", "mla_apply", "mla_decode", "init_mla_cache"]


class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # (B, S, kv_lora)
    k_rope: jnp.ndarray  # (B, S, rope_dim)
    idx: jnp.ndarray


def mla_init(key, d_model: int, n_heads: int, cfg: MLAConfig):
    ks = jax.random.split(key, 6)
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "wq": dense_init(ks[0], d_model, n_heads * qk_dim),
        "w_dkv": dense_init(ks[1], d_model, cfg.kv_lora_rank),
        "w_uk": dense_init(ks[2], cfg.kv_lora_rank, n_heads * cfg.qk_nope_head_dim),
        "w_uv": dense_init(ks[3], cfg.kv_lora_rank, n_heads * cfg.v_head_dim),
        "w_kr": dense_init(ks[4], d_model, cfg.qk_rope_head_dim),
        "wo": dense_init(ks[5], n_heads * cfg.v_head_dim, d_model),
    }


def _mla_qkv(params, x, n_heads: int, cfg: MLAConfig, positions, rope_theta):
    """Returns q (B,S,H,qk_dim), k (B,S,H,qk_dim), v (B,S,H,v_dim)."""
    b, s, _ = x.shape
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, n_heads, qk_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)

    c_kv = x @ params["w_dkv"].astype(x.dtype)                   # (B,S,r)
    k_nope = (c_kv @ params["w_uk"].astype(x.dtype)).reshape(
        b, s, n_heads, cfg.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"].astype(x.dtype)).reshape(
        b, s, n_heads, cfg.v_head_dim)
    k_rope = (x @ params["w_kr"].astype(x.dtype))[:, :, None, :]  # shared head

    cos, sin = rope_freqs(positions, cfg.qk_rope_head_dim, rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    k_rope = jnp.broadcast_to(k_rope, (b, s, n_heads, cfg.qk_rope_head_dim))

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    return q, k, v, c_kv


def mla_apply(params, x, *, n_heads: int, cfg: MLAConfig,
              rope_theta: float = 10000.0, chunk: int = 1024,
              window: int = 0) -> jnp.ndarray:
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v, _ = _mla_qkv(params, x, n_heads, cfg, positions, rope_theta)
    # qk_dim != v_dim is handled (MLA); flash/chunked are dim-agnostic
    out = _attention(q, k, v, causal=True, window=window, chunk=chunk)
    return out.reshape(b, s, n_heads * cfg.v_head_dim) @ params["wo"].astype(x.dtype)


def init_mla_cache(batch: int, s_cache: int, cfg: MLAConfig, dtype=jnp.bfloat16):
    return MLACache(
        c_kv=jnp.zeros((batch, s_cache, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, s_cache, cfg.qk_rope_head_dim), dtype),
        idx=jnp.zeros((), jnp.int32),
    )


def mla_decode(params, x, cache: MLACache, *, n_heads: int, cfg: MLAConfig,
               rope_theta: float = 10000.0):
    """One-token decode from the latent cache. x: (B,1,d)."""
    b = x.shape[0]
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    pos = cache.idx[None]

    q = (x @ params["wq"].astype(x.dtype)).reshape(b, 1, n_heads, qk_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    cos, sin = rope_freqs(pos, cfg.qk_rope_head_dim, rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    c_new = x @ params["w_dkv"].astype(x.dtype)                  # (B,1,r)
    kr_new = apply_rope((x @ params["w_kr"].astype(x.dtype))[:, :, None, :],
                        cos, sin)[:, :, 0, :]                    # (B,1,rope)

    s_cache = cache.c_kv.shape[1]
    c_kv = jax.lax.dynamic_update_slice(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), (0, cache.idx, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache.k_rope, kr_new.astype(cache.k_rope.dtype), (0, cache.idx, 0))

    # absorbed attention: score = q_nope·(c_kv W_uk) + q_rope·k_rope
    # (materializing per-head keys for the cache would defeat MLA; instead we
    # absorb W_uk into the query -- the classic MLA decode trick.)
    w_uk = params["w_uk"].astype(x.dtype).reshape(
        cfg.kv_lora_rank, n_heads, cfg.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)       # (B,H,r)
    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                       c_kv.astype(jnp.float32))
    s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(jnp.float32(qk_dim))
    scores = (s_lat + s_rope) * scale
    valid = jnp.arange(s_cache) <= cache.idx
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)

    # values from latent: o_lat = p·c_kv, then up-project through W_uv
    o_lat = jnp.einsum("bhs,bsr->bhr", p, c_kv.astype(jnp.float32))
    w_uv = params["w_uv"].astype(x.dtype).reshape(
        cfg.kv_lora_rank, n_heads, cfg.v_head_dim)
    o = jnp.einsum("bhr,rhd->bhd", o_lat.astype(x.dtype), w_uv)
    out = o.reshape(b, 1, n_heads * cfg.v_head_dim) @ params["wo"].astype(x.dtype)
    return out, MLACache(c_kv=c_kv, k_rope=k_rope, idx=cache.idx + 1)
