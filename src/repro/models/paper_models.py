"""The paper's own benchmark models, in pure JAX.

The paper evaluates on VGG11*@CIFAR, CNN@KWS, LSTM@Fashion-MNIST and logistic
regression@MNIST.  This container is offline (no dataset downloads), so the
federated experiments run these architectures on synthetic structured data of
matching shapes (see repro.data.synthetic); the *qualitative* claims
(non-iid degradation ordering, ternarization harmlessness, pareto dominance)
are distribution-free.

Every model follows the same functional interface:
    init(key) -> params ;  apply(params, x) -> logits
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["logreg_init", "logreg_apply", "mlp_init_model", "mlp_apply_model",
           "cnn_init", "cnn_apply", "lstm_init", "lstm_apply", "MODEL_ZOO"]


# -- logistic regression (paper: 7850 params on 784->10) ---------------------

def logreg_init(key, d_in: int = 784, n_classes: int = 10):
    return {"w": dense_init(key, d_in, n_classes, scale=0.01),
            "b": jnp.zeros((n_classes,), jnp.float32)}


def logreg_apply(params, x):
    return x.reshape(x.shape[0], -1) @ params["w"] + params["b"]


# -- small MLP ----------------------------------------------------------------

def mlp_init_model(key, d_in: int = 784, d_hidden: int = 128,
                   n_classes: int = 10):
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, d_in, d_hidden),
            "b1": jnp.zeros((d_hidden,), jnp.float32),
            "w2": dense_init(k2, d_hidden, n_classes),
            "b2": jnp.zeros((n_classes,), jnp.float32)}


def mlp_apply_model(params, x):
    h = jax.nn.relu(x.reshape(x.shape[0], -1) @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


# -- VGG11*-style CNN (reduced filters, no BN/dropout -- paper Sec. VI) -------

_VGG_FILTERS = (32, 64, 128, 128)   # reduced VGG11* column for 32x32 inputs


def cnn_init(key, in_ch: int = 3, n_classes: int = 10, hidden: int = 128,
             img: int = 32):
    ks = jax.random.split(key, len(_VGG_FILTERS) + 2)
    params = {}
    ch = in_ch
    for i, f in enumerate(_VGG_FILTERS):
        params[f"conv{i}"] = (
            jax.random.normal(ks[i], (3, 3, ch, f), jnp.float32)
            * jnp.sqrt(2.0 / (9 * ch)))
        ch = f
    spatial = img // (2 ** len(_VGG_FILTERS))
    flat = ch * spatial * spatial
    params["fc1"] = dense_init(ks[-2], flat, hidden)
    params["fc1b"] = jnp.zeros((hidden,), jnp.float32)
    params["fc2"] = dense_init(ks[-1], hidden, n_classes)
    params["fc2b"] = jnp.zeros((n_classes,), jnp.float32)
    return params


def cnn_apply(params, x):
    """x: (B, H, W, C)."""
    h = x
    for i in range(len(_VGG_FILTERS)):
        h = jax.lax.conv_general_dilated(
            h, params[f"conv{i}"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"] + params["fc1b"])
    return h @ params["fc2"] + params["fc2b"]


# -- 2-layer LSTM (paper: rows of the image as a 28-step sequence) ------------

def lstm_init(key, d_in: int = 28, d_hidden: int = 128, n_layers: int = 2,
              n_classes: int = 10):
    params = {"layers": []}
    k = key
    d = d_in
    for _ in range(n_layers):
        k, k1, k2 = jax.random.split(k, 3)
        params["layers"].append({
            "wx": dense_init(k1, d, 4 * d_hidden),
            "wh": dense_init(k2, d_hidden, 4 * d_hidden),
            "b": jnp.zeros((4 * d_hidden,), jnp.float32),
        })
        d = d_hidden
    k, k1 = jax.random.split(k)
    params["out"] = dense_init(k1, d_hidden, n_classes)
    params["out_b"] = jnp.zeros((n_classes,), jnp.float32)
    return params


def _lstm_layer(lp, xs):
    """xs: (T, B, d) -> (T, B, h)."""
    h_dim = lp["wh"].shape[0]
    b = xs.shape[1]

    def step(carry, x):
        h, c = carry
        gates = x @ lp["wx"] + h @ lp["wh"] + lp["b"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((b, h_dim)), jnp.zeros((b, h_dim)))
    _, hs = jax.lax.scan(step, init, xs)
    return hs


def lstm_apply(params, x):
    """x: (B, T, d) image rows as sequence -> logits (B, n_classes)."""
    xs = x.reshape(x.shape[0], 28, -1).transpose(1, 0, 2)
    for lp in params["layers"]:
        xs = _lstm_layer(lp, xs)
    return xs[-1] @ params["out"] + params["out_b"]


MODEL_ZOO = {
    "logreg": (logreg_init, logreg_apply),
    "mlp": (mlp_init_model, mlp_apply_model),
    "cnn": (cnn_init, cnn_apply),
    "lstm": (lstm_init, lstm_apply),
}
