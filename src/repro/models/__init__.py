"""Model zoo: unified TransformerLM covering the 10 assigned architectures
plus the paper's own small federated benchmarks."""

from .config import (EncoderConfig, MLAConfig, ModelConfig, MoEConfig,
                     RGLRUConfig, SSMConfig)
from .transformer import (decode_step, encode_frames, forward, init_cache,
                          init_model, lm_loss)
from . import paper_models

__all__ = [
    "EncoderConfig", "MLAConfig", "ModelConfig", "MoEConfig", "RGLRUConfig",
    "SSMConfig", "decode_step", "encode_frames", "forward", "init_cache",
    "init_model", "lm_loss", "paper_models",
]
