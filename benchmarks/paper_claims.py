"""Paper-claim benchmarks: one function per paper table/figure.

Each returns rows of (name, value, derived) and is runnable standalone:
    PYTHONPATH=src python -m benchmarks.paper_claims [fig2|fig3|fig5|table4|fig8]

The container is offline, so the paper's datasets are replaced by synthetic
structured tasks of matching shapes (DESIGN.md §2); every claim checked here
is about the ORDERING/ROBUSTNESS of methods, which transfers.
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import golomb, make_protocol
from repro.data import make_classification
from repro.fed import FedEnvironment, FederatedTrainer, TrainerConfig
from repro.models.paper_models import MODEL_ZOO


def _trainer(proto, train, test, n_clients=10, cpc=10, participation=1.0,
             batch=20, lr=0.04, momentum=0.0, seed=0):
    env = FedEnvironment(n_clients=n_clients, participation=participation,
                         classes_per_client=cpc, batch_size=batch)
    return FederatedTrainer(MODEL_ZOO["logreg"], train, test, env, proto,
                            TrainerConfig(lr=lr, momentum=momentum, seed=seed))


def fig2_noniid_convergence(rounds=60, verbose=True):
    """Fig. 2/6: accuracy after a fixed iteration budget, iid vs non-iid.

    Expected ordering (paper): STC degrades least under non-iid; signSGD
    degrades most; FedAvg in between.
    """
    train, test = make_classification(seed=0, n=10000, n_test=2000)
    rows = []
    for cpc, tag in [(10, "iid"), (2, "noniid2"), (1, "noniid1")]:
        for pname, kw, r in [
            ("baseline", {}, rounds),
            ("stc", dict(sparsity_up=1 / 50, sparsity_down=1 / 50), rounds),
            ("fedavg", dict(local_iters=10), rounds // 10),
            ("signsgd", {}, rounds),
        ]:
            tr = _trainer(make_protocol(pname, **kw), train, test, cpc=cpc)
            h = tr.run(r, eval_every=r)[-1]
            rows.append((f"fig2/{tag}/{pname}", h["acc"],
                         f"iters={h['iterations']}"))
            if verbose:
                print(rows[-1])
    # assertion of the paper's ordering on the hardest split
    accs = {r[0].split("/")[-1]: r[1] for r in rows if "noniid1" in r[0]}
    assert accs["stc"] > accs["signsgd"], "STC must beat signSGD on non-iid(1)"
    return rows


def fig3_sign_congruence(verbose=True):
    """Fig. 3: P[sign(batch grad) == sign(full grad)] vs batch size,
    iid vs non-iid(1) batches."""
    train, _ = make_classification(seed=0, n=8000, n_test=10)
    init, apply = MODEL_ZOO["logreg"]
    params = init(jax.random.PRNGKey(0))

    def grad_of(idx):
        x = jnp.asarray(train.x[idx])
        y = jnp.asarray(train.y[idx])

        def loss(p):
            lg = apply(p, x)
            return jnp.mean(jax.nn.logsumexp(lg, -1) -
                            jnp.take_along_axis(lg, y[:, None], -1)[:, 0])

        g = jax.grad(loss)(params)
        return np.concatenate([np.asarray(v).ravel()
                               for v in jax.tree.leaves(g)])

    g_full = grad_of(np.arange(len(train.y)))
    rng = np.random.default_rng(0)
    rows = []
    for k in [1, 4, 16, 64, 256]:
        # iid batches
        cong_iid = []
        for _ in range(20):
            idx = rng.integers(0, len(train.y), k)
            cong_iid.append(np.mean(np.sign(grad_of(idx)) == np.sign(g_full)))
        # non-iid batches: all samples from one class
        cong_non = []
        for _ in range(20):
            c = rng.integers(0, 10)
            pool = np.flatnonzero(train.y == c)
            idx = rng.choice(pool, size=k)
            cong_non.append(np.mean(np.sign(grad_of(idx)) == np.sign(g_full)))
        rows.append((f"fig3/iid/b{k}", float(np.mean(cong_iid)), ""))
        rows.append((f"fig3/noniid/b{k}", float(np.mean(cong_non)), ""))
        if verbose:
            print(rows[-2], rows[-1])
    # paper claim: iid congruence grows with batch size; non-iid stays low
    iid = [r[1] for r in rows if "/iid/" in r[0]]
    non = [r[1] for r in rows if "/noniid/" in r[0]]
    assert iid[-1] > iid[0] + 0.05, "iid congruence must grow with batch"
    assert iid[-1] > non[-1] + 0.05, "non-iid congruence must stay low"
    return rows


def fig5_ternarization(rounds=50, verbose=True):
    """Fig. 5: sparse+ternary vs pure sparse at matched sparsity: the
    accuracy difference must be small (ternarization is ~free)."""
    train, test = make_classification(seed=0, n=10000, n_test=2000)
    rows = []
    for p in [1 / 25, 1 / 100]:
        stc = _trainer(make_protocol("stc", sparsity_up=p, sparsity_down=p),
                       train, test, cpc=2)
        topk = _trainer(make_protocol("topk", sparsity_up=p), train, test,
                        cpc=2)
        a_stc = stc.run(rounds, eval_every=rounds)[-1]["acc"]
        a_topk = topk.run(rounds, eval_every=rounds)[-1]["acc"]
        rows.append((f"fig5/p{p:.3f}/stc", a_stc, ""))
        rows.append((f"fig5/p{p:.3f}/topk", a_topk, f"gap={a_topk-a_stc:.3f}"))
        if verbose:
            print(rows[-2], rows[-1])
        assert abs(a_topk - a_stc) < 0.1, "ternarization must be ~harmless"
    return rows


def table4_bits_to_accuracy(target=0.9, max_rounds=120, verbose=True):
    """Table IV: upload+download MB to reach a target accuracy (iid env)."""
    train, test = make_classification(seed=0, n=10000, n_test=2000)
    rows = []
    for pname, kw, per_round in [
        ("baseline", {}, 1),
        ("signsgd", {}, 1),
        ("fedavg", dict(local_iters=10), 10),
        ("stc", dict(sparsity_up=1 / 50, sparsity_down=1 / 50), 1),
        ("stc", dict(sparsity_up=1 / 200, sparsity_down=1 / 200), 1),
    ]:
        tag = pname + (f"_p{1/kw['sparsity_up']:.0f}" if "sparsity_up" in kw
                       else (f"_n{kw['local_iters']}" if "local_iters" in kw
                             else ""))
        tr = _trainer(make_protocol(pname, **kw), train, test, cpc=10,
                      n_clients=20, participation=0.5)
        reached = None
        for r in range(max_rounds // max(per_round, 1)):
            tr.run_round()
            if (r + 1) % 5 == 0:
                acc = tr.evaluate()
                if acc >= target:
                    reached = (tr.bits_up / 8e6, tr.bits_down / 8e6,
                               tr.round * per_round)
                    break
        if reached:
            rows.append((f"table4/{tag}", reached[0],
                         f"downMB={reached[1]:.2f},iters={reached[2]}"))
        else:
            rows.append((f"table4/{tag}", float("nan"), "n.a."))
        if verbose:
            print(rows[-1])
    return rows


def fig8_participation(rounds=60, verbose=True):
    """Fig. 8: robustness to low client participation fractions."""
    train, test = make_classification(seed=0, n=10000, n_test=2000)
    rows = []
    for n_clients, part in [(10, 1.0), (20, 0.25), (40, 0.125)]:
        for pname, kw, r in [
            ("stc", dict(sparsity_up=1 / 50, sparsity_down=1 / 50), rounds),
            ("fedavg", dict(local_iters=10), rounds // 10),
        ]:
            tr = _trainer(make_protocol(pname, **kw), train, test, cpc=2,
                          n_clients=n_clients, participation=part)
            h = tr.run(r, eval_every=r)[-1]
            rows.append((f"fig8/{part:.3f}/{pname}", h["acc"], ""))
            if verbose:
                print(rows[-1])
    return rows


def golomb_codec(verbose=True):
    """Appendix A: codec throughput + measured-vs-analytic message size."""
    rng = np.random.default_rng(0)
    n, p = 500_000, 1 / 400
    x = np.zeros(n, np.float32)
    k = int(n * p)
    x[rng.choice(n, k, replace=False)] = 0.3 * rng.choice([-1, 1], k)
    t0 = time.time()
    payload, bit_len, mu, _ = golomb.encode_ternary(x, p)
    t_enc = time.time() - t0
    t0 = time.time()
    golomb.decode_ternary(payload, bit_len, mu, n, p)
    t_dec = time.time() - t0
    from repro.core import wire
    t0 = time.time()
    wire.encode_ternary_words(x, p)
    t_vec = time.time() - t0
    analytic = k * (golomb.golomb_position_bits(p) + 1.0)
    rows = [
        ("golomb/encode_us_per_nnz", 1e6 * t_enc / k, "per-bit oracle"),
        ("golomb/decode_us_per_nnz", 1e6 * t_dec / k, "per-bit oracle"),
        ("golomb/wire_encode_us_per_nnz", 1e6 * t_vec / k,
         "vectorized packer (core.wire)"),
        ("golomb/measured_bits", float(bit_len),
         f"analytic={analytic:.0f},ratio={bit_len/analytic:.4f}"),
        ("golomb/compression_x", 32.0 * n / bit_len, "vs dense fp32"),
    ]
    if verbose:
        for r in rows:
            print(r)
    return rows


BENCHES = {
    "fig2": fig2_noniid_convergence,
    "fig3": fig3_sign_congruence,
    "fig5": fig5_ternarization,
    "table4": table4_bits_to_accuracy,
    "fig8": fig8_participation,
    "golomb": golomb_codec,
}


if __name__ == "__main__":
    which = sys.argv[1:] or list(BENCHES)
    for name in which:
        print(f"=== {name} ===")
        BENCHES[name]()
