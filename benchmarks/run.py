"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [names...]

Prints ``name,value,derived`` CSV rows.  The fed benchmarks are scaled-down
(CPU) versions of the paper's experiments on synthetic structured data; the
``roofline`` benchmark reads the dry-run artifacts if present.

Whenever the ``kernels`` bench runs, its rows are also written to
``benchmarks/BENCH_stc.json`` (and the ``wire`` bench's to
``benchmarks/BENCH_wire.json``) so the perf trajectories are tracked across
PRs (compare the committed file against a fresh run).
"""

from __future__ import annotations

import datetime
import json
import os
import platform
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
BENCH_STC_PATH = os.path.join(_HERE, "BENCH_stc.json")
BENCH_WIRE_PATH = os.path.join(_HERE, "BENCH_wire.json")
BENCH_ASYNC_PATH = os.path.join(_HERE, "BENCH_async.json")
BENCH_CHUNKED_PATH = os.path.join(_HERE, "BENCH_chunked.json")
BENCH_INGEST_PATH = os.path.join(_HERE, "BENCH_ingest.json")
BENCH_EVENTS_PATH = os.path.join(_HERE, "BENCH_events.json")
BENCH_FAULTS_PATH = os.path.join(_HERE, "BENCH_faults.json")
BENCH_ROBUST_PATH = os.path.join(_HERE, "BENCH_robust.json")
BENCH_ADAPTIVE_PATH = os.path.join(_HERE, "BENCH_adaptive.json")


def _write_bench(path: str, rows, unit: str = "us") -> None:
    """Persist bench rows for cross-PR tracking (``scripts/check_bench.py``
    gates the slow CI lane on them).  Timing files keep the historical
    ``us`` value key; non-timing files (unit != "us") use ``value``."""
    key = "us" if unit == "us" else "value"
    payload = {
        "generated": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "host": {"machine": platform.machine(),
                 "python": platform.python_version()},
        "unit": unit,
        "rows": [{"name": name,
                  key: round(float(val), 1 if unit == "us" else 4),
                  "note": derived}
                 for name, val, derived in rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def write_bench_stc(rows) -> None:
    _write_bench(BENCH_STC_PATH, rows)


def write_bench_wire(rows) -> None:
    _write_bench(BENCH_WIRE_PATH, rows)


def write_bench_async(rows) -> None:
    _write_bench(BENCH_ASYNC_PATH, rows, unit="mixed")


def write_bench_chunked(rows) -> None:
    _write_bench(BENCH_CHUNKED_PATH, rows)


def write_bench_ingest(rows) -> None:
    _write_bench(BENCH_INGEST_PATH, rows, unit="mixed")


def write_bench_events(rows) -> None:
    _write_bench(BENCH_EVENTS_PATH, rows, unit="mixed")


def write_bench_faults(rows) -> None:
    _write_bench(BENCH_FAULTS_PATH, rows, unit="mixed")


def write_bench_robust(rows) -> None:
    _write_bench(BENCH_ROBUST_PATH, rows, unit="mixed")


def write_bench_adaptive(rows) -> None:
    _write_bench(BENCH_ADAPTIVE_PATH, rows, unit="mixed")


def main() -> None:
    args = [a for a in sys.argv[1:] if not a.startswith("-")]
    quick = "--quick" in sys.argv

    from benchmarks import kernel_bench, paper_claims

    rows = []
    which = args or ["golomb", "wire", "kernels", "chunked", "ingest",
                     "events", "faults", "robust", "adaptive", "async",
                     "fig3", "fig5", "fig2", "table4", "fig8", "roofline"]
    if quick:
        which = args or ["golomb", "wire", "kernels", "chunked", "ingest",
                         "events", "faults", "robust", "adaptive", "fig3"]

    for name in which:
        print(f"# === {name} ===", flush=True)
        if name == "kernels":
            krows = kernel_bench.run(verbose=False)
            write_bench_stc(krows)
            rows += krows
        elif name == "wire":
            from benchmarks import wire_bench
            wrows = wire_bench.run(verbose=False)
            write_bench_wire(wrows)
            rows += wrows
        elif name == "chunked":
            from benchmarks import chunked_bench
            crows = chunked_bench.run(verbose=False)
            write_bench_chunked(crows)
            rows += crows
        elif name == "ingest":
            from benchmarks import ingest_bench
            irows = ingest_bench.run(verbose=False, smoke=quick)
            if not quick:    # quick = smoke scale; keep the tracked file
                write_bench_ingest(irows)    # at the fleet operating point
            rows += irows
        elif name == "events":
            from benchmarks import events_bench
            erows = events_bench.run(verbose=False, smoke=quick)
            if not quick:    # quick = smoke scale; keep the tracked file
                write_bench_events(erows)    # at the full scenario sweep
            rows += erows
        elif name == "faults":
            from benchmarks import faults_bench
            frows = faults_bench.run(verbose=False, smoke=quick)
            if not quick:    # quick = smoke scale; keep the tracked file
                write_bench_faults(frows)    # at the full chaos sweep
            rows += frows
        elif name == "robust":
            from benchmarks import robust_bench
            brows = robust_bench.run(verbose=False, smoke=quick)
            if not quick:    # quick = smoke scale; keep the tracked file
                write_bench_robust(brows)    # at the full rule x attack sweep
            rows += brows
        elif name == "adaptive":
            from benchmarks import adaptive_bench
            adrows = adaptive_bench.run(verbose=False, smoke=quick)
            if not quick:    # quick = smoke scale; keep the tracked file
                write_bench_adaptive(adrows)  # full accuracy-per-bit sweep
            rows += adrows
        elif name == "async":
            from benchmarks import async_bench
            arows = async_bench.run(verbose=False)
            write_bench_async(arows)
            rows += arows
        elif name == "roofline":
            from benchmarks import roofline
            recs = roofline.load_records()
            if not recs:
                print("# (no dry-run artifacts; skipping roofline rows)")
                continue
            for r in recs:
                a = roofline.analyze(r)
                rows.append((f"roofline/{a['arch']}/{a['shape']}/{a['mesh']}",
                             max(a["t_compute_s"], a["t_memory_s"],
                                 a["t_collective_s"]),
                             f"dominant={a['dominant']}"))
        else:
            rows += paper_claims.BENCHES[name](verbose=False)

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
