"""Adaptive-sparsity convergence-vs-bits benchmark (accuracy-per-bit).

    PYTHONPATH=src python -m benchmarks.adaptive_bench [--smoke]

One synchronous federated run per sparsity controller on the non-IID
synthetic benchmark (100 clients, 4 classes each, cohort 10 -- the same
fleet operating point as the events/async benches), all through the SAME
chunked STC codec so the only variable is WHO sets each chunk's k:

  adaptive/<ctrl>/acc             -- accuracy after the round budget
  adaptive/<ctrl>/bits_up         -- total MEASURED upstream bits
  adaptive/<ctrl>/bits_to_target  -- measured upstream bits when the run
                                     first reaches the fixed-p baseline's
                                     final accuracy (NaN = never reached;
                                     check_bench treats NaN rows as
                                     report-only warnings)

``fixed`` is the static-p baseline every controller is judged against;
``ternquant`` (Xu et al. 2020 dense ternary) rides along as the
registry's non-sparse comparison entry.  The paper's Pareto claim is the
``bits_to_target`` column: an adaptive controller earns its keep by
reaching the fixed-p final accuracy with strictly fewer measured bits.

Written to ``benchmarks/BENCH_adaptive.json`` (unit "mixed" -- report-only
in the regression gate).  ``--smoke`` is the CI lane: two rounds per
controller at toy scale, asserting the measured-bits <= wire-bound
invariant every round under time-varying k.
"""

from __future__ import annotations

import math
import sys

from repro.data import make_classification
from repro.fed import FedEnvironment, FederatedTrainer, TrainerConfig
from repro.models.paper_models import MODEL_ZOO

_N_CLIENTS = 100
_ETA = 1 / 10                       # cohort of 10
_ROUNDS = 25
_P = 1 / 20                         # fixed-p schedule (base_k ~ 6 per chunk)
_CHUNKS = 128
_LR = 0.06

#: controller label -> TrainerConfig(controller=) value (None = static path)
_CONTROLLERS = (
    ("fixed", None),
    ("residual_mass", ("residual_mass", {"budget": 0.75})),
    ("snr_constant", ("snr_constant", {"snr": 1.0})),
)


def _make_controller(spec):
    from repro.core import make_controller
    if spec is None:
        return None
    name, kw = spec
    return make_controller(name, **kw)


def _trainer(train, test, env, controller, protocol="stc", chunks=_CHUNKS):
    from repro.core import make_protocol
    kw = {"stc": dict(sparsity_up=_P, sparsity_down=_P)}
    return FederatedTrainer(
        MODEL_ZOO["logreg"], train, test, env,
        make_protocol(protocol, **kw.get(protocol, {})),
        TrainerConfig(lr=_LR, seed=0, chunks=chunks, controller=controller))


def _bits_to_target(history, target: float) -> float:
    """Cumulative measured upstream bits at the first eval reaching
    ``target`` accuracy (NaN when the run never gets there)."""
    for rec in history:
        if rec["acc"] >= target:
            return float(rec["bits_up"])
    return float("nan")


def run(verbose: bool = True, smoke: bool = False):
    rows = []
    if smoke:
        train, test = make_classification(seed=0, n=600, n_test=160)
        env = FedEnvironment(n_clients=12, participation=0.5,
                             classes_per_client=2, batch_size=10)
        for label, spec in _CONTROLLERS:
            tr = _trainer(train, test, env, _make_controller(spec),
                          chunks=32)
            hist = tr.run(2, eval_every=1)
            # the wire bound must stay a true ceiling under time-varying k
            for row in tr.wire_log:
                assert row["bits_up_bound"] is None or \
                    row["bits_up"] <= row["bits_up_bound"], (label, row)
            rows.append((f"adaptive/smoke/{label}/acc", hist[-1]["acc"],
                         "2 rounds, wire bound asserted per round"))
            if verbose:
                print(f"adaptive/smoke/{label}: acc={hist[-1]['acc']:.3f}")
        return rows

    train, test = make_classification(seed=0, n=6000, n_test=1200)
    env = FedEnvironment(n_clients=_N_CLIENTS, participation=_ETA,
                         classes_per_client=4, batch_size=10)
    note = (f"rounds={_ROUNDS} clients={_N_CLIENTS} p={_P:g} "
            f"chunks={_CHUNKS} lr={_LR}")

    histories = {}
    for label, spec in _CONTROLLERS:
        tr = _trainer(train, test, env, _make_controller(spec))
        histories[label] = tr.run(_ROUNDS, eval_every=1)
    # the registry's dense-ternary comparison entry (flat, no controller)
    tr = _trainer(train, test, env, None, protocol="ternquant", chunks=None)
    histories["ternquant"] = tr.run(_ROUNDS, eval_every=1)

    target = histories["fixed"][-1]["acc"]
    for label, hist in histories.items():
        acc = hist[-1]["acc"]
        bits = float(hist[-1]["bits_up"])
        b2t = _bits_to_target(hist, target)
        stem = f"adaptive/{label}"
        rows.append((f"{stem}/acc", acc, note))
        rows.append((f"{stem}/bits_up", bits, note))
        rows.append((f"{stem}/bits_to_target", b2t,
                     f"target=fixed final acc {target:.4f}; " + note))
        if verbose:
            b2s = "never" if math.isnan(b2t) else f"{b2t / 8e6:.3f}MB"
            print(f"{stem}: acc={acc:.4f} upMB={bits / 8e6:.3f} "
                  f"bits_to_target={b2s}")
    return rows


if __name__ == "__main__":
    run(verbose=True, smoke="--smoke" in sys.argv)
