"""Robust-aggregation benchmark: rule x attack x Byzantine-fraction sweep.

    PYTHONPATH=src python -m benchmarks.robust_bench [--smoke]

One event-driven training run per (aggregation rule, valid-update attack)
cell on a steady fleet -- the adversarial complement of ``faults_bench``:
there the payloads were CORRUPTED (and quarantined at admission), here they
are perfectly VALID wire messages whose contents lie, so the only defence
is the server's combine rule (:mod:`repro.core.aggregation`).  Per cell:

  robust/<rule>/<attack>/acc    -- accuracy after the aggregation budget

with ``<attack>`` one of ``none``, ``sign-flip@0.2``, ``sign-flip@0.4``,
``collusion@0.2``.  The headline reading (the PR's acceptance bar): under
the 20% sign-flip colluding cohort, ``coordinate_median`` and
``trimmed_mean`` stay within 2% of their own no-attack accuracy while
``mean`` demonstrably does not; at 40% Byzantine the trimmed mean's
beta=0.25 budget is exceeded and only the median (breakdown point 1/2)
holds.  ``norm_screened_mean`` -- PR 8's clip/reject screen as a rule --
rejects the large-norm flips but, unlike the median, can be fooled by
attacks that keep honest-looking norms.

The codec is ``baseline`` (dense updates): robust statistics act on the
clients' actual coordinates, not on a sparsified proxy.  The norm screen's
bound is calibrated from a short no-attack probe run (3x the median honest
update norm), exactly how an operator would set it.

Written to ``benchmarks/BENCH_robust.json`` (unit "mixed" -- report-only
in the regression gate).  ``--smoke`` is the CI lane: 2 aggregations of
EVERY registered rule under one Byzantine fault, seconds not minutes.
"""

from __future__ import annotations

import dataclasses
import sys

import numpy as np

from repro.core import make_protocol, make_rule, registered_rules
from repro.core.aggregation import NormScreenedMeanRule, TrimmedMeanRule
from repro.data import make_classification
from repro.fed import (EventDrivenTrainer, FaultModel, FedEnvironment,
                       TrainerConfig, make_fault)
from repro.models.paper_models import MODEL_ZOO

_N_CLIENTS = 100
_ETA = 1 / 5               # cohort of 20: robust statistics need the votes
_AGGREGATIONS = 15
_TRIM_BETA = 0.25          # tolerates 20% Byzantine mass, not 40%


@dataclasses.dataclass(frozen=True)
class _NormProbeFault(FaultModel):
    """Records every honest update's l2 norm; rewrites nothing.  Used to
    calibrate the norm screen the way an operator would: watch the fleet,
    then set the bound."""

    name = "norm-probe"
    norms: list = dataclasses.field(default_factory=list)

    def byzantine(self, payload, client, rng):
        self.norms.append(float(np.linalg.norm(
            np.asarray(payload, np.float64).ravel())))
        return payload


def _trainer(train, test, rule, faults, *, n_clients):
    # near-IID label split: the median/trimmed-mean guarantees (Yin et al.
    # 2018) assume bounded cross-client heterogeneity -- under a severe
    # non-IID skew the quantile shift from heterogeneity alone swamps the
    # Byzantine signal this sweep is isolating
    env = FedEnvironment(n_clients=n_clients, participation=_ETA,
                         classes_per_client=10, batch_size=20)
    proto = make_protocol("baseline", rule=rule)
    return EventDrivenTrainer(
        MODEL_ZOO["logreg"], train, test, env, proto,
        TrainerConfig(lr=0.06, seed=0),
        scenario="steady", faults=faults)


def _calibrate_bound(train, test, *, n_clients, aggregations=2) -> float:
    probe = _NormProbeFault()
    tr = _trainer(train, test, "mean", probe, n_clients=n_clients)
    tr.run(aggregations, eval_every=aggregations)
    return 3.0 * float(np.median(probe.norms))


def _sweep_rules(bound: float) -> dict:
    return {
        "mean": make_rule("mean"),
        "coordinate_median": make_rule("coordinate_median"),
        "trimmed_mean": TrimmedMeanRule(beta=_TRIM_BETA),
        "norm_screened_mean": NormScreenedMeanRule(bound=bound,
                                                   policy="reject"),
    }


def _attacks(fractions=(0.2, 0.4)) -> list:
    atk = [("none", None)]
    for f in fractions:
        atk.append((f"sign-flip@{f}",
                    make_fault("sign-flip", scale=10.0, fraction=f)))
    atk.append(("collusion@0.2",
                make_fault("collusion", scale=10.0, fraction=0.2)))
    return atk


def _cell_rows(train, test, rules, attacks, aggregations, *, n_clients,
               verbose):
    rows = []
    for rname, rule in rules.items():
        for aname, fault in attacks:
            tr = _trainer(train, test, rule, fault, n_clients=n_clients)
            hist = tr.run(aggregations, eval_every=aggregations)
            acc = hist[-1]["acc"]
            note = (f"aggs={aggregations} clients={n_clients} "
                    f"codec=baseline scenario=steady rule={rule}")
            rows.append((f"robust/{rname}/{aname}/acc", acc, note))
            if verbose:
                print(f"robust/{rname}/{aname}: acc={acc:.3f}")
    return rows


def run(verbose: bool = True, smoke: bool = False):
    if smoke:
        # CI lane: every registered rule (defaults) x one Byzantine fault
        train, test = make_classification(seed=0, n=600, n_test=160)
        rules = {name: make_rule(name) for name in registered_rules()}
        attacks = [("sign-flip@0.2",
                    make_fault("sign-flip", scale=10.0, fraction=0.2))]
        return _cell_rows(train, test, rules, attacks, 2, n_clients=40,
                          verbose=verbose)
    train, test = make_classification(seed=0, n=6000, n_test=1200)
    bound = _calibrate_bound(train, test, n_clients=_N_CLIENTS)
    if verbose:
        print(f"# calibrated norm bound: {bound:.4f}")
    return _cell_rows(train, test, _sweep_rules(bound), _attacks(),
                      _AGGREGATIONS, n_clients=_N_CLIENTS, verbose=verbose)


if __name__ == "__main__":
    run(verbose=True, smoke="--smoke" in sys.argv)
