"""Server ingestion benchmark: fused decode→aggregate vs the dense path.

    PYTHONPATH=src python -m benchmarks.ingest_bench [--smoke]

The fleet regime (P >= 512 uploads/round, numel = 2^20, p = 1/400 -- the
paper's §V operating point) is where the server's dense ``(P, numel)``
decode buffer becomes the wall: 2 GiB of fp32 per round at P=512 before a
single aggregate FLOP.  The fused ingest path
(:mod:`repro.core.ingest`) scatters every upload's decoded Golomb fields
straight into ONE O(numel) accumulator, so its peak ingest memory is
independent of P.

Measured rows (written to ``benchmarks/BENCH_ingest.json``, unit "mixed" --
report-only in the regression gate, like BENCH_async):

  ingest/fused_uploads_per_s   -- fused ingest throughput at the big point
  ingest/dense_uploads_per_s   -- dense decode->aggregate throughput
  ingest/speedup               -- fused / dense (acceptance: >= 5x)
  ingest/fused_peak_mib_P*     -- tracemalloc peak during ingest, two P's
  ingest/dense_peak_mib_P*     -- same for the dense decode buffer
  ingest/identity              -- 1.0 iff fused == dense oracle bitwise

Both timed paths start from the SAME encoded wire batch and end with the
same downstream compression (``finalize_ingest`` / ``aggregate``), so the
comparison isolates exactly the ingest stage the PR replaces.
"""

from __future__ import annotations

import sys
import time
import tracemalloc

import numpy as np

from repro.core import make_protocol, wire

_MU = 0.01


def _make_batch(P: int, n: int, p: float, rng) -> wire.WireBatch:
    """P synthetic sparse ternary uploads, encoded one row at a time (the
    dense (P, n) tensor is never materialized -- clients encode clientside)."""
    k = max(int(n * p), 1)
    msgs = []
    row = np.zeros(n, np.float32)
    for _ in range(P):
        idx = rng.choice(n, size=k, replace=False)
        row[idx] = rng.choice((-1.0, 1.0), size=k).astype(np.float32) * _MU
        msgs.append(wire.encode_ternary_words(row, p))
        row[idx] = 0.0
    return wire.concat_messages(msgs)


def _fused(codec, batch, w, n, state):
    acc = codec.make_ingest(n)
    codec.ingest_wire_batch(acc, batch, w, direction="up")
    return codec.aggregate_ingest(acc, state), acc


def _dense(codec, batch, w, n, state):
    import jax.numpy as jnp
    block = wire.decode_ternary_words_batch(batch, codec.sparsity_up)
    out = codec.aggregate(jnp.asarray(block), state,
                          mask=jnp.asarray(w, jnp.float32))
    return out, block


def _peak_mib(fn) -> float:
    tracemalloc.start()
    fn()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return peak / 2**20


def run(verbose: bool = True, smoke: bool = False):
    P, n = (32, 1 << 14) if smoke else (512, 1 << 20)
    p = 1 / 400
    rng = np.random.default_rng(0)
    codec = make_protocol("stc", sparsity_up=p, sparsity_down=p)
    w = np.ones(P, np.float64)
    state = codec.init_server_state(n)

    batch = _make_batch(P, n, p, rng)

    # ---- correctness first: fused == dense oracle, bitwise -----------------
    (gd_f, _, _), acc = _fused(codec, batch, w, n, state)
    oracle = codec.make_ingest(n)
    block = wire.decode_ternary_words_batch(batch, p)
    for i in range(P):
        codec.ingest_dense(oracle, block[i], float(w[i]))
    gd_o, _, _ = codec.aggregate_ingest(oracle, state)
    identical = (np.array_equal(np.asarray(acc.sum), np.asarray(oracle.sum))
                 and np.array_equal(np.asarray(gd_f), np.asarray(gd_o)))
    del block, oracle

    # ---- throughput --------------------------------------------------------
    reps = 3 if smoke else 2
    t_f = min(_timed(lambda: _fused(codec, batch, w, n, state))
              for _ in range(reps))
    t_d = min(_timed(lambda: _dense(codec, batch, w, n, state))
              for _ in range(reps))
    fused_ups, dense_ups = P / t_f, P / t_d
    speedup = fused_ups / dense_ups

    # ---- peak ingest memory at two cohort sizes ----------------------------
    # fused peak must be ~independent of P (the accumulator is O(numel));
    # the dense buffer grows linearly.  Only the ingest stage is traced.
    P2 = max(P // 4, 1)
    batch2 = _make_batch(P2, n, p, rng)
    w2 = np.ones(P2, np.float64)

    def fused_ingest_only(b, ww):
        acc = codec.make_ingest(n)
        codec.ingest_wire_batch(acc, b, ww, direction="up")

    mem = {
        f"fused_peak_mib_P{P}": _peak_mib(
            lambda: fused_ingest_only(batch, w)),
        f"fused_peak_mib_P{P2}": _peak_mib(
            lambda: fused_ingest_only(batch2, w2)),
        f"dense_peak_mib_P{P}": _peak_mib(
            lambda: wire.decode_ternary_words_batch(batch, p)),
        f"dense_peak_mib_P{P2}": _peak_mib(
            lambda: wire.decode_ternary_words_batch(batch2, p)),
    }

    note = f"P={P} n=2^{n.bit_length() - 1} p=1/{int(round(1 / p))}"
    rows = [
        ("ingest/fused_uploads_per_s", fused_ups, note),
        ("ingest/dense_uploads_per_s", dense_ups, note),
        ("ingest/speedup", speedup, note + " acceptance>=5x"),
        ("ingest/identity", 1.0 if identical else 0.0,
         "fused == dense oracle, bitwise"),
    ] + [(f"ingest/{k}", v, note) for k, v in mem.items()]
    if verbose:
        for name, val, derived in rows:
            print(f"{name},{val:.4f},{derived}")
    if not identical:
        raise AssertionError("fused ingest diverged from the dense oracle")
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


if __name__ == "__main__":
    run(verbose=True, smoke="--smoke" in sys.argv)
