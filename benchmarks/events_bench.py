"""Event-driven server benchmark across fleet scenarios.

    PYTHONPATH=src python -m benchmarks.events_bench [--smoke]

One K-arrival-triggered :class:`EventDrivenTrainer` training run per
registered fleet scenario (the same CPU-scale synthetic task as the async
bench, heterogeneous straggler fleet), reporting per scenario:

  events/<scenario>/acc            -- accuracy after the aggregation budget
  events/<scenario>/bits_up        -- total MEASURED upstream bits (drops
                                      bill, losses don't)
  events/<scenario>/drop_rate      -- (dropped + lost) / served events
  events/<scenario>/aggs_per_time  -- aggregations per simulated time unit

Written to ``benchmarks/BENCH_events.json`` (unit "mixed" -- report-only in
the regression gate).  ``aggs_per_time`` is the row that separates the
scenarios: the count trigger keeps aggregating through a flash crowd (at a
lower rate) where a fixed deadline would close empty windows, and a
regional outage shows up as billed-bits loss, not server stalls.

``--smoke`` is the CI lane: a model-free :func:`simulate_scenario` pass
over every registered scenario plus one tiny training run, seconds not
minutes.
"""

from __future__ import annotations

import sys

from repro.data import make_classification
from repro.fed import (EventDrivenTrainer, FedEnvironment, LatencyModel,
                       TrainerConfig, make_scenario, registered_scenarios,
                       simulate_scenario)
from repro.models.paper_models import MODEL_ZOO

# same heterogeneous straggler fleet as the async bench, so the
# events/<scenario> rows are comparable with the async/<proto> families
_LATENCY = LatencyModel(mean=0.6, sigma=0.5, hetero=0.4,
                        straggler_frac=0.15, straggler_scale=4.0)
_N_CLIENTS = 100
_ETA = 1 / 10                       # cohort of 10
_AGGREGATIONS = 10
_MAX_STALENESS = 2                  # tight horizon: stragglers really drop


def _trainer(train, test, scenario, tcfg=None, **kw):
    from repro.core import make_protocol
    env = FedEnvironment(n_clients=_N_CLIENTS, participation=_ETA,
                         classes_per_client=4, batch_size=10)
    proto = make_protocol("stc", sparsity_up=1 / 50, sparsity_down=1 / 50)
    cohort = env.participants_per_round
    return EventDrivenTrainer(
        MODEL_ZOO["logreg"], train, test, env, proto,
        tcfg or TrainerConfig(lr=0.06, seed=0), scenario=scenario,
        k_arrivals=kw.pop("k_arrivals", cohort),
        concurrency=kw.pop("concurrency", 2 * cohort),
        max_staleness=kw.pop("max_staleness", _MAX_STALENESS), **kw)


def run(verbose: bool = True, smoke: bool = False):
    rows = []
    if smoke:
        # model-free event-loop pass over EVERY registration (pure numpy)
        for name in registered_scenarios():
            st = simulate_scenario(name, n_clients=64, cohort=8,
                                   concurrency=16, max_staleness=2,
                                   aggregations=4, seed=0)
            note = (f"smoke sim aggs={st['aggregations']} "
                    f"dispatched={st['dispatched']}")
            rows.append((f"events/sim/{name}/drop_rate", st["drop_rate"],
                         note))
            if verbose:
                print(f"events/sim/{name}: drop_rate={st['drop_rate']:.3f} "
                      f"aggs/t={st['aggs_per_time']:.2f}")
        train, test = make_classification(seed=0, n=600, n_test=160)
        tr = _trainer(train, test, make_scenario("steady", latency=_LATENCY))
        hist = tr.run(2, eval_every=2)
        rows.append(("events/smoke/acc", hist[-1]["acc"], "2 aggregations"))
        if verbose:
            print(f"events/smoke: acc={hist[-1]['acc']:.3f}")
        return rows

    train, test = make_classification(seed=0, n=6000, n_test=1200)
    for name in registered_scenarios():
        tr = _trainer(train, test, make_scenario(name, latency=_LATENCY))
        hist = tr.run(_AGGREGATIONS, eval_every=_AGGREGATIONS)
        acc = hist[-1]["acc"]
        st = tr.loop.stats()
        note = (f"aggs={_AGGREGATIONS} clients={_N_CLIENTS} "
                f"K={tr.k_arrivals} conc={tr.concurrency} "
                f"max_staleness={tr.max_staleness} measured={tr.measure_bits}")
        stem = f"events/{name}"
        rows.append((f"{stem}/acc", acc, note))
        rows.append((f"{stem}/bits_up", tr.bits_up, note))
        rows.append((f"{stem}/drop_rate", st["drop_rate"], note))
        rows.append((f"{stem}/aggs_per_time", st["aggs_per_time"], note))
        if verbose:
            print(f"{stem}: acc={acc:.3f} upMB={tr.bits_up / 8e6:.3f} "
                  f"drop_rate={st['drop_rate']:.3f} "
                  f"aggs/t={st['aggs_per_time']:.2f}")
    return rows


if __name__ == "__main__":
    run(verbose=True, smoke="--smoke" in sys.argv)
