"""Async buffered-aggregation benchmark (written to ``BENCH_async.json``).

The paper's headline regime -- many clients, low participation (§V) --
is exactly where a synchronous round stalls on stragglers.  This bench runs
the deadline-buffered trainer against the synchronous baseline on the
CPU-scale synthetic task across participation rates 1/10 ... 1/400 and a
deadline sweep, reporting:

  async/<proto>/p<1/eta>/d<deadline>/acc      -- accuracy after R rounds
  async/<proto>/p<1/eta>/d<deadline>/bits_up  -- total MEASURED upstream bits
  async/<proto>/p<1/eta>/d<deadline>/dropped  -- arrivals past the horizon

Accuracy-vs-round is the `acc` row family read across the deadline axis at a
fixed participation (deadline=inf is the synchronous reference); measured
bits-vs-deadline is the `bits_up` family: a tighter deadline defers stragglers
into later buffered rounds, so the ledger shows WHEN the bytes land, not a
modeled expectation.  The latency fleet is heterogeneous with a chronic
straggler population, so tight deadlines genuinely drop/delay updates.
"""

from __future__ import annotations

import math

from repro.core import make_protocol
from repro.data import make_classification
from repro.fed import (BufferedFederatedTrainer, FedEnvironment,
                       FederatedTrainer, LatencyModel, TrainerConfig)
from repro.models.paper_models import MODEL_ZOO

# (n_clients, participation) grid: eta = 1/10 ... 1/400 of the paper's §V
# sweep, scaled so every cell stays CPU-sized (cohort of at most 10)
_PARTICIPATION = (
    (100, 1 / 10),
    (100, 1 / 50),
    (200, 1 / 100),
    (400, 1 / 400),
)
_DEADLINES = (math.inf, 1.0, 0.5)
_LATENCY = LatencyModel(mean=0.6, sigma=0.5, hetero=0.4,
                        straggler_frac=0.15, straggler_scale=4.0)


def _proto(name: str):
    if name == "stc":
        return make_protocol("stc", sparsity_up=1 / 50, sparsity_down=1 / 50)
    return make_protocol(name)


def run(verbose: bool = True, rounds: int = 12, protocols=("stc",)):
    data = make_classification(seed=0, n=6000, n_test=1200)
    train, test = data
    rows = []
    for name in protocols:
        for n_clients, eta in _PARTICIPATION:
            env = FedEnvironment(n_clients=n_clients, participation=eta,
                                 classes_per_client=4, batch_size=10)
            for deadline in _DEADLINES:
                proto = _proto(name)
                tcfg = TrainerConfig(lr=0.06, seed=0)
                if math.isinf(deadline):
                    tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test,
                                          env, proto, tcfg)
                    dropped = 0
                else:
                    tr = BufferedFederatedTrainer(
                        MODEL_ZOO["logreg"], train, test, env, proto, tcfg,
                        latency=_LATENCY, deadline=deadline, max_staleness=6)
                hist = tr.run(rounds, eval_every=rounds)
                if not math.isinf(deadline):
                    dropped = tr.n_dropped
                acc = hist[-1]["acc"]
                dtag = "inf" if math.isinf(deadline) else f"{deadline:g}"
                stem = f"async/{name}/p{int(round(1 / eta))}/d{dtag}"
                note = (f"rounds={rounds} clients={n_clients} "
                        f"measured={tr.measure_bits}")
                rows.append((f"{stem}/acc", acc, note))
                rows.append((f"{stem}/bits_up", tr.bits_up, note))
                rows.append((f"{stem}/dropped", float(dropped), note))
                if verbose:
                    print(f"{stem}: acc={acc:.3f} "
                          f"upMB={tr.bits_up / 8e6:.3f} dropped={dropped}")
    return rows


if __name__ == "__main__":
    run(verbose=True)
