"""Async buffered-aggregation benchmark (written to ``BENCH_async.json``).

The paper's headline regime -- many clients, low participation (§V) --
is exactly where a synchronous round stalls on stragglers.  This bench runs
the deadline-buffered trainer against the synchronous baseline on the
CPU-scale synthetic task across participation rates 1/10 ... 1/400 and a
deadline sweep, reporting:

  async/<proto>/p<1/eta>/d<deadline>/acc      -- accuracy after R rounds
  async/<proto>/p<1/eta>/d<deadline>/bits_up  -- total MEASURED upstream bits
  async/<proto>/p<1/eta>/d<deadline>/dropped  -- arrivals past the horizon

Accuracy-vs-round is the `acc` row family read across the deadline axis at a
fixed participation (deadline=inf is the synchronous reference); measured
bits-vs-deadline is the `bits_up` family: a tighter deadline defers stragglers
into later buffered rounds, so the ledger shows WHEN the bytes land, not a
modeled expectation.  The latency fleet is heterogeneous with a chronic
straggler population, so tight deadlines genuinely drop/delay updates.
"""

from __future__ import annotations

import math
import time

import numpy as np

from repro.core import make_protocol, wire
from repro.data import make_classification
from repro.fed import (BufferedFederatedTrainer, FedEnvironment,
                       FederatedTrainer, LatencyModel, TrainerConfig,
                       make_scenario)
from repro.fed.arrivals import ArrivalSimulator
from repro.models.paper_models import MODEL_ZOO

# (n_clients, participation) grid: eta = 1/10 ... 1/400 of the paper's §V
# sweep, scaled so every cell stays CPU-sized (cohort of at most 10)
_PARTICIPATION = (
    (100, 1 / 10),
    (100, 1 / 50),
    (200, 1 / 100),
    (400, 1 / 400),
)
_DEADLINES = (math.inf, 1.0, 0.5)
_LATENCY = LatencyModel(mean=0.6, sigma=0.5, hetero=0.4,
                        straggler_frac=0.15, straggler_scale=4.0)


def _proto(name: str):
    if name == "stc":
        return make_protocol("stc", sparsity_up=1 / 50, sparsity_down=1 / 50)
    return make_protocol(name)


# fleet-scale sweep: the trainer is out of the loop (no model, no data
# shards) -- this exercises the SERVER path alone: a 10^5-client arrivals
# model feeding synthetic sparse uploads through the fused ingest
# accumulator, the regime the dense (P, numel) decode block cannot reach.
_FLEET = ((100_000, 1 / 400),)
_FLEET_NUMEL = 1 << 18
_MAX_STALENESS = 6


def _sparse_payloads(rng, cohort: int, numel: int, p: float):
    """``cohort`` synthetic sparse ternary uploads, one wire message each
    (shared by the fleet sweep here and ``benchmarks.events_bench``: the
    dense ``(cohort, numel)`` tensor is never materialized)."""
    k = max(int(numel * p), 1)
    row = np.zeros(numel, np.float32)
    payloads = []
    for _ in range(cohort):
        idx = rng.choice(numel, size=k, replace=False)
        row[idx] = rng.choice((-1.0, 1.0), size=k) * 0.01
        payloads.append(wire.encode_ternary_words(row, p))
        row[idx] = 0.0
    return payloads


def fleet(verbose: bool = True, rounds: int = 8, scenario=None):
    """Fleet-scale ingest sweep; ``scenario`` (a registered name or a
    :class:`repro.fed.Scenario`) reshapes WHEN uploads land: the scenario
    samples time-varying latencies and loss masks, lost uploads never reach
    the simulator (and bill nothing), and the rows move to the
    ``async/fleet/stc/c*/<scenario>`` stems so the default family stays
    comparable across PRs."""
    scen = (make_scenario(scenario, latency=_LATENCY)
            if isinstance(scenario, str) else scenario)
    rows = []
    p = 1 / 400
    proto = make_protocol("stc", sparsity_up=p, sparsity_down=p)
    for n_clients, eta in _FLEET:
        cohort = max(int(round(n_clients * eta)), 1)
        sim = ArrivalSimulator(_LATENCY, n_clients=n_clients,
                               deadline=1.0, seed=0)
        rng = np.random.default_rng(0)
        state = proto.init_server_state(_FLEET_NUMEL)
        ingested = dropped = lost_total = 0
        t_ingest = 0.0
        for rnd in range(rounds):
            ids = rng.choice(n_clients, size=cohort, replace=False)
            payloads = _sparse_payloads(rng, cohort, _FLEET_NUMEL, p)
            if scen is None:
                sim.dispatch(rnd, ids, payloads)
            else:
                lats, lost = scen.sample(rnd * sim.deadline, ids,
                                         sim.scales, sim.rng)
                keep = ~lost
                lost_total += int(lost.sum())
                sim.dispatch_with_latencies(
                    rnd, ids[keep],
                    [pl for pl, kp in zip(payloads, keep) if kp],
                    lats[keep])
            arrivals = sim.collect(rnd)
            kept = [a for a in arrivals
                    if rnd - a.sent_round <= _MAX_STALENESS]
            dropped += len(arrivals) - len(kept)
            if not kept:
                continue
            stal = np.asarray([rnd - a.sent_round for a in kept])
            w = np.asarray(proto.participation_weights(
                np.ones(len(kept), np.float32), stal), np.float64)
            t0 = time.perf_counter()
            acc = proto.make_ingest(_FLEET_NUMEL)
            for a, wi in zip(kept, w):
                proto.ingest_wire(acc, a.payload, float(wi))
            _, state, _ = proto.aggregate_ingest(acc, state)
            t_ingest += time.perf_counter() - t0
            ingested += len(kept)
        ups = ingested / t_ingest if t_ingest > 0 else 0.0
        stem = f"async/fleet/stc/c{n_clients}"
        note = (f"rounds={rounds} cohort={cohort} numel={_FLEET_NUMEL} "
                f"ingest-only timing")
        if scen is not None:
            stem += f"/{scen.name}"
            note += f" scenario={scen.name}"
        rows.append((f"{stem}/uploads_per_s", ups, note))
        rows.append((f"{stem}/ingested", float(ingested), note))
        rows.append((f"{stem}/dropped", float(dropped), note))
        if scen is not None:
            rows.append((f"{stem}/lost", float(lost_total), note))
        if verbose:
            print(f"{stem}: {ups:.1f} uploads/s ingested={ingested} "
                  f"dropped={dropped}"
                  + (f" lost={lost_total}" if scen is not None else ""))
    return rows


def run(verbose: bool = True, rounds: int = 12, protocols=("stc",),
        scenarios=()):
    data = make_classification(seed=0, n=6000, n_test=1200)
    train, test = data
    rows = []
    for name in protocols:
        for n_clients, eta in _PARTICIPATION:
            env = FedEnvironment(n_clients=n_clients, participation=eta,
                                 classes_per_client=4, batch_size=10)
            for deadline in _DEADLINES:
                proto = _proto(name)
                tcfg = TrainerConfig(lr=0.06, seed=0)
                if math.isinf(deadline):
                    tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test,
                                          env, proto, tcfg)
                    dropped = 0
                else:
                    tr = BufferedFederatedTrainer(
                        MODEL_ZOO["logreg"], train, test, env, proto, tcfg,
                        latency=_LATENCY, deadline=deadline, max_staleness=6)
                hist = tr.run(rounds, eval_every=rounds)
                if not math.isinf(deadline):
                    dropped = tr.n_dropped
                acc = hist[-1]["acc"]
                dtag = "inf" if math.isinf(deadline) else f"{deadline:g}"
                stem = f"async/{name}/p{int(round(1 / eta))}/d{dtag}"
                note = (f"rounds={rounds} clients={n_clients} "
                        f"measured={tr.measure_bits}")
                rows.append((f"{stem}/acc", acc, note))
                rows.append((f"{stem}/bits_up", tr.bits_up, note))
                rows.append((f"{stem}/dropped", float(dropped), note))
                if verbose:
                    print(f"{stem}: acc={acc:.3f} "
                          f"upMB={tr.bits_up / 8e6:.3f} dropped={dropped}")
    rows += fleet(verbose=verbose)
    for scen in scenarios:
        rows += fleet(verbose=verbose, scenario=scen)
    return rows


if __name__ == "__main__":
    import argparse

    from repro.fed import registered_scenarios

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", action="append", default=[],
                    choices=registered_scenarios(), metavar="NAME",
                    help="also run the fleet sweep under this registered "
                         "scenario (repeatable); rows land under "
                         "async/fleet/stc/c*/<scenario>")
    ap.add_argument("--fleet-only", action="store_true",
                    help="skip the trainer sweep, run only the fleet rows")
    ns = ap.parse_args()
    if ns.fleet_only:
        fleet(verbose=True)
        for scen in ns.scenario:
            fleet(verbose=True, scenario=scen)
    else:
        run(verbose=True, scenarios=ns.scenario)
