"""Wire-format micro-benchmarks: per-bit oracle vs vectorized vs batched
packing at n = 2^20 (written to ``benchmarks/BENCH_wire.json`` by run.py).

Rows (k = nnz of the ternary message):
  wire_perbit_encode   -- per-bit oracle loop (core.golomb, Algorithm 3)
  wire_vector_encode   -- vectorized chunk/scatter packer (core.wire)
  wire_kernel_encode   -- same stream through the Pallas pack_bits backend
  wire_batch8_encode   -- fused (P=8) client-axis pack, TOTAL for 8 clients
  wire_seq8_encode     -- 8 sequential single-client packs (the baseline the
                          batched row must beat)
  wire_vector_decode / wire_perbit_decode -- the matching decoders

The speedup note on the vectorized row is measured against the per-bit
oracle on the same tensor (the ISSUE acceptance row: >= 50x at n=2^20).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import golomb, wire


def _rand_ternary(n: int, p: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = np.zeros(n, np.float32)
    k = max(int(n * p), 1)
    x[rng.choice(n, size=k, replace=False)] = 0.3 * rng.choice(
        [-1.0, 1.0], size=k)
    return x


def _timeit(fn, iters: int) -> float:
    fn()  # warm (jit / cache)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        best = min(best, (time.perf_counter() - t0) / iters)
    return 1e6 * best


def run(verbose=True, n: int = 1 << 20):
    rows = []
    # p=1/400 is the paper's upload sparsity (fused-batch regime); 1/50 the
    # CPU-scale test point; 1/20 a dense downstream/ternquant-like message
    # where the per-nnz cost ratio fully expresses (the >=50x acceptance row)
    for p, tag in ((1 / 400, "p400"), (1 / 50, "p50"), (1 / 20, "p20")):
        x = _rand_ternary(n, p, seed=0)
        X = np.stack([_rand_ternary(n, p, seed=i) for i in range(8)])
        k = int(np.count_nonzero(x))

        us_oracle = _timeit(lambda: golomb.encode_ternary(x, p), iters=1)
        us_vec = _timeit(lambda: wire.encode_ternary_words(x, p), iters=20)
        us_kernel = _timeit(
            lambda: wire.encode_ternary_words(x, p, backend="kernel"),
            iters=5)
        us_batch = _timeit(
            lambda: wire.encode_ternary_words_batch(X, p), iters=5)
        us_seq = _timeit(
            lambda: [wire.encode_ternary_words(X[i], p) for i in range(8)],
            iters=5)

        rows.append((f"wire_perbit_encode/{tag}/n{n}", us_oracle,
                     f"per-bit oracle, k={k}"))
        rows.append((f"wire_vector_encode/{tag}/n{n}", us_vec,
                     f"vectorized packer, {us_oracle / us_vec:.0f}x "
                     f"vs per-bit"))
        rows.append((f"wire_kernel_encode/{tag}/n{n}", us_kernel,
                     "pallas pack_bits backend (CPU = interpret timing)"))
        fused = 8 * k <= wire._FUSED_NNZ_MAX
        rows.append((f"wire_batch8_encode/{tag}/n{n}", us_batch,
                     (f"fused 8-client pack, total; "
                      f"{us_seq / us_batch:.2f}x vs sequential") if fused
                     else (f"above fused-nnz crossover: adaptive per-client "
                           f"fallback, parity with sequential by design "
                           f"({us_seq / us_batch:.2f}x)")))
        rows.append((f"wire_seq8_encode/{tag}/n{n}", us_seq,
                     "8 sequential single-client packs"))

        msg = wire.encode_ternary_words(x, p)
        payload, bit_len, mu, _ = golomb.encode_ternary(x, p)
        us_dec = _timeit(lambda: wire.decode_ternary_words(msg, p), iters=10)
        us_dec_oracle = _timeit(
            lambda: golomb.decode_ternary(payload, bit_len, mu, n, p),
            iters=1)
        rows.append((f"wire_vector_decode/{tag}/n{n}", us_dec,
                     f"{us_dec_oracle / us_dec:.0f}x vs per-bit"))
        rows.append((f"wire_perbit_decode/{tag}/n{n}", us_dec_oracle,
                     "per-bit oracle"))
    if verbose:
        for row in rows:
            print(f"{row[0]},{row[1]:.1f},{row[2]}")
    return rows


if __name__ == "__main__":
    run()
