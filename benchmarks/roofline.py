"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and derives,
per (arch x shape x mesh):

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = wire_bytes_per_chip / link_bw
    ingest term     = measured_server_ingest_bytes_per_round / NIC_bw

The ingest term comes from the dry-run's WireLedger measurement (one
sampled client update encoded through the codec's real wire format, scaled
to the cohort); it is reported alongside the per-step terms but kept out of
``dominant`` because the buffered server overlaps ingest with compute.

cost_analysis() on the SPMD-partitioned executable reports PER-CHIP figures
(verified against analytic parameter/argument sizes in EXPERIMENTS.md
§Dry-run).  Collective result bytes are converted to wire bytes with the
standard ring factors; the group size n is approximated by the mesh axis the
collective most plausibly runs over (model=16) -- noted as approximate.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""

from __future__ import annotations

import glob
import json
import os
import sys

from repro.configs import INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
SERVER_NIC_BW = 12.5e9  # 100 Gb/s front-end NIC: client uploads enter here
DEFAULT_GROUP = 16  # model-axis size; collectives are predominantly TP

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def wire_bytes(collectives: dict, n: int = DEFAULT_GROUP) -> float:
    """Convert result bytes to per-chip wire bytes (ring algorithms)."""
    total = 0.0
    for kind, rec in collectives.items():
        b = rec["bytes"]
        if kind == "all-reduce":
            total += 2.0 * b * (n - 1) / n
        elif kind == "all-gather":
            total += b * (n - 1) / n
        elif kind == "reduce-scatter":
            total += b * (n - 1)          # result is the scattered shard
        elif kind == "all-to-all":
            total += b * (n - 1) / n
        elif kind == "collective-permute":
            total += b
    return total


def model_flops(rec: dict) -> float:
    """MODEL_FLOPS (global): 6·N_active·D train, 2·N_active·D forward-only."""
    cfg = get_config(rec["arch"], rec["shape"])
    shape = INPUT_SHAPES[rec["shape"]]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze(rec: dict) -> dict:
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    t_compute = rec["flops"] / PEAK_FLOPS
    t_memory = rec["bytes_accessed"] / HBM_BW
    t_coll = wire_bytes(rec.get("collectives", {})) / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = rec["flops"] * chips
    # measured server ingest (WireLedger figure recorded by the dry-run):
    # wall time for one round's client uploads to cross the front-end NIC.
    # Reported alongside the per-step terms, not folded into `dominant` --
    # ingest overlaps training steps in the buffered server.
    si = rec.get("server_ingest")
    t_ingest = (si["bytes_up_round"] / SERVER_NIC_BW) if si else 0.0
    return {
        **rec,
        "chips": chips,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "t_ingest_s": t_ingest,
        "ingest_bytes_round": si["bytes_up_round"] if si else 0.0,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
    }


_SUGGEST = {
    "compute": ("reduce recompute (remat policy) / ensure matmul dims are "
                "128-aligned so padded-head waste stops burning MXU cycles"),
    "memory": ("cut activation traffic: chunk the LM head, fuse the STC "
               "residual chain (Pallas kernel), bf16 the gradient tree"),
    "collective": ("overlap the message psum with backward, shrink gathered "
                   "tensors (reduce-scatter the server stage), or move expert "
                   "weights to an all_to_all expert-parallel layout"),
}


def suggestion(a: dict) -> str:
    return _SUGGEST[a["dominant"]]


def load_records(variant: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(os.path.abspath(ART), "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if variant is None and r.get("variant"):
            continue
        if variant is not None and r.get("variant") != variant:
            continue
        recs.append(r)
    return recs


def table(recs) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "ingest s | dominant | MODEL/HLO |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        a = analyze(r)
        ingest = (f"{a['t_ingest_s']:.3e}" if a["t_ingest_s"] else "--")
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {a['t_compute_s']:.3e} | {a['t_memory_s']:.3e} "
            f"| {a['t_collective_s']:.3e} | {ingest} | **{a['dominant']}** "
            f"| {a['useful_ratio']:.3f} |")
    return "\n".join(lines)


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("-")]
    variant = args[0] if args else None
    recs = load_records(variant)
    if not recs:
        print("no dry-run artifacts found -- run repro.launch.dryrun first")
        return
    print(table(recs))
    print()
    for r in recs:
        a = analyze(r)
        print(f"{a['arch']} x {a['shape']} x {a['mesh']}: dominant="
              f"{a['dominant']} -> {suggestion(a)}")
        if a["t_ingest_s"]:
            print(f"  server ingest (measured): "
                  f"{a['ingest_bytes_round'] / 2**20:.2f} MiB/round = "
                  f"{a['t_ingest_s']:.3e} s on the front-end NIC")
    if "--write" in sys.argv:
        out = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "roofline_table.md")
        with open(os.path.abspath(out), "w") as f:
            f.write("# Roofline baseline table (single-pod 16x16 + "
                    "multi-pod 2x16x16)\n\n")
            f.write(table(recs))
            f.write("\n\n## Per-pair bottleneck notes\n\n")
            for r in recs:
                a = analyze(r)
                f.write(f"* **{a['arch']} × {a['shape']} × {a['mesh']}** — "
                        f"dominant {a['dominant']}: {suggestion(a)}\n")
        print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
