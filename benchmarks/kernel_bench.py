"""STC compression micro-benchmarks: kernel path (interpret=True reference
timing on CPU -- the TPU numbers come from the roofline, not wall-clock) and
the pure-jnp operator path, plus the no-flatten tree path used by the
distributed train_step.

Rows (n = flat update length):
  stc_jnp_topk      -- core operator, lax.top_k sort path
  stc_bisect_ref    -- pure-jnp 33-pass bisection oracle
  stc_pallas_interp -- OLD kernel path: 33-pass bisection selection
  stc_hist          -- NEW selector path (≤3 passes; on CPU this times the
                       small-k top_k shortcut, not the Pallas histogram —
                       the histogram kernel itself only pays off on TPU)
  stc_hist_batch8   -- batched (client, block)-grid path over 8 clients of
                       the SAME n; TOTAL launch time, /8 for per-client
  stc_tree          -- no-flatten tree path (histogram selector)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import stc_compress
from repro.core.distributed import stc_compress_tree
from repro.kernels import (stc_compress_batch, stc_compress_kernel,
                           stc_compress_ref)


def _timeit(fn, *args, iters=5):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.time() - t0) / iters


def run(verbose=True):
    rows = []
    rng = np.random.default_rng(0)
    for n in (1 << 16, 1 << 20):
        d = jnp.asarray(rng.standard_normal(n), jnp.float32)
        r = jnp.asarray(rng.standard_normal(n) * 0.1, jnp.float32)

        us = _timeit(lambda a, b: stc_compress(a + b, 1 / 400)[0], d, r)
        rows.append((f"stc_jnp_topk/n{n}", us, "lax.top_k sort path"))

        us = _timeit(lambda a, b: stc_compress_ref(a, b, 1 / 400)[0], d, r)
        rows.append((f"stc_bisect_ref/n{n}", us, "bisection oracle"))

        us = _timeit(
            lambda a, b: stc_compress_kernel(a, b, 1 / 400,
                                             selector="bisect")[0], d, r)
        rows.append((f"stc_pallas_interp/n{n}", us,
                     "33-pass bisection (CPU reference, not TPU perf)"))

        us = _timeit(
            lambda a, b: stc_compress_kernel(a, b, 1 / 400)[0], d, r)
        rows.append((f"stc_hist/n{n}", us,
                     "<=3-pass hist selector (CPU: small-k top_k shortcut)"))

        bsz = 8
        db = jnp.asarray(rng.standard_normal((bsz, n)), jnp.float32)
        rb = jnp.asarray(rng.standard_normal((bsz, n)) * 0.1, jnp.float32)
        us = _timeit(
            lambda a, b: stc_compress_batch(a, b, 1 / 400)[0], db, rb)
        rows.append((f"stc_hist_batch{bsz}/n{n}", us,
                     f"batched client axis, one launch, total for {bsz}"
                     " clients of n"))

        tree = {"a": d.reshape(-1, 256), "b": r}
        tree_fn = jax.jit(lambda t: stc_compress_tree(t, 1 / 400,
                                                      numel=2 * n)[0]["a"])
        us = _timeit(tree_fn, tree)
        rows.append((f"stc_tree/n{2*n}", us, "no-flatten train_step path"))
    if verbose:
        for row in rows:
            print(f"{row[0]},{row[1]:.1f},{row[2]}")
    return rows


if __name__ == "__main__":
    run()
