"""STC compression micro-benchmarks: kernel path (interpret=True reference
timing on CPU -- the TPU numbers come from the roofline, not wall-clock) and
the pure-jnp operator path, plus the no-flatten tree path used by the
distributed train_step."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import stc_compress
from repro.core.distributed import stc_compress_tree
from repro.kernels import stc_compress_kernel, stc_compress_ref


def _timeit(fn, *args, iters=5):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.time() - t0) / iters


def run(verbose=True):
    rows = []
    rng = np.random.default_rng(0)
    for n in (1 << 16, 1 << 20):
        d = jnp.asarray(rng.standard_normal(n), jnp.float32)
        r = jnp.asarray(rng.standard_normal(n) * 0.1, jnp.float32)

        us = _timeit(lambda a, b: stc_compress(a + b, 1 / 400)[0], d, r)
        rows.append((f"stc_jnp_topk/n{n}", us, "lax.top_k sort path"))

        us = _timeit(lambda a, b: stc_compress_ref(a, b, 1 / 400)[0], d, r)
        rows.append((f"stc_bisect_ref/n{n}", us, "bisection oracle"))

        us = _timeit(
            lambda a, b: stc_compress_kernel(a, b, 1 / 400)[0], d, r)
        rows.append((f"stc_pallas_interp/n{n}", us,
                     "interpret=True (CPU reference, not TPU perf)"))

        tree = {"a": d.reshape(-1, 256), "b": r}
        us = _timeit(
            lambda t: stc_compress_tree(t, 1 / 400, numel=2 * n)[0]["a"], tree)
        rows.append((f"stc_tree/n{2*n}", us, "no-flatten train_step path"))
    if verbose:
        for row in rows:
            print(f"{row[0]},{row[1]:.1f},{row[2]}")
    return rows


if __name__ == "__main__":
    run()
