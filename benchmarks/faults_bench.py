"""Chaos benchmark: event-driven training under every registered fault.

    PYTHONPATH=src python -m benchmarks.faults_bench [--smoke]

One hardened :class:`EventDrivenTrainer` run per registered fault class on
the flash-outage fleet (the composed worst-case scenario), against a
no-fault baseline on the same seeds.  Per fault:

  faults/<fault>/acc              -- accuracy after the aggregation budget
  faults/<fault>/bits_up          -- total billed upstream bits (quarantined
                                     and duplicate arrivals bill, so chaos
                                     shows up as wasted bandwidth, not
                                     missing ledger rows)
  faults/<fault>/quarantine_rate  -- quarantined / served events
  faults/<fault>/duplicate_rate   -- duplicates rejected / served events

plus ``faults/resume/params_max_abs_diff``: a server-kill at a fixed event
index followed by a checkpoint restore, reporting the max |param| gap vs
the uninterrupted baseline (the crash-consistency contract says 0.0).

Written to ``benchmarks/BENCH_faults.json`` (unit "mixed" -- report-only in
the regression gate).  The headline reading: corruption faults cost bits
and a little accuracy (quarantined updates are paid for but discarded),
duplicate/replay faults cost nothing but rejected bandwidth, and none of
them crash or wedge the server.

``--smoke`` is the CI lane: a 2-round chaos pass over every registered
fault class plus the kill/resume check, seconds not minutes.
"""

from __future__ import annotations

import sys
import tempfile

import numpy as np

from repro.data import make_classification
from repro.fed import (EventDrivenTrainer, FedEnvironment, LatencyModel,
                       ServerKilled, TrainerConfig, make_fault,
                       registered_faults)
from repro.models.paper_models import MODEL_ZOO

# same heterogeneous straggler fleet as the events bench, so the
# faults/<fault> rows are comparable with the events/<scenario> families
_LATENCY = LatencyModel(mean=0.6, sigma=0.5, hetero=0.4,
                        straggler_frac=0.15, straggler_scale=4.0)
_N_CLIENTS = 100
_ETA = 1 / 10
_AGGREGATIONS = 10
_MAX_STALENESS = 2


def _trainer(train, test, faults, *, n_clients=_N_CLIENTS, **kw):
    from repro.core import make_protocol
    from repro.fed import make_scenario
    env = FedEnvironment(n_clients=n_clients, participation=_ETA,
                         classes_per_client=4, batch_size=10)
    proto = make_protocol("stc", sparsity_up=1 / 50, sparsity_down=1 / 50)
    cohort = env.participants_per_round
    return EventDrivenTrainer(
        MODEL_ZOO["logreg"], train, test, env, proto,
        TrainerConfig(lr=0.06, seed=0, ingest=True),
        scenario=make_scenario("flash-outage"),
        k_arrivals=kw.pop("k_arrivals", cohort),
        concurrency=kw.pop("concurrency", 2 * cohort),
        max_staleness=kw.pop("max_staleness", _MAX_STALENESS),
        faults=faults, **kw)


def _chaos_rows(train, test, aggregations, *, n_clients, verbose):
    """One training run per fault class; ``server-kill`` is exercised by the
    dedicated resume check instead (a mid-sweep kill is not a sweep row)."""
    rows = []
    for name in sorted(registered_faults()):
        if name == "server-kill":
            continue
        tr = _trainer(train, test, make_fault(name), n_clients=n_clients)
        hist = tr.run(aggregations, eval_every=aggregations)
        acc = hist[-1]["acc"]
        st = tr.loop.stats()
        note = (f"aggs={aggregations} clients={n_clients} "
                f"scenario=flash-outage K={tr.k_arrivals} "
                f"max_staleness={tr.max_staleness}")
        stem = f"faults/{name}"
        rows.append((f"{stem}/acc", acc, note))
        rows.append((f"{stem}/bits_up", tr.bits_up, note))
        rows.append((f"{stem}/quarantine_rate", st["quarantine_rate"], note))
        rows.append((f"{stem}/duplicate_rate", st["duplicate_rate"], note))
        if verbose:
            print(f"{stem}: acc={acc:.3f} upMB={tr.bits_up / 8e6:.3f} "
                  f"quarantine={st['quarantine_rate']:.3f} "
                  f"dup={st['duplicate_rate']:.3f}")
    return rows


def _resume_row(train, test, aggregations, *, n_clients, verbose):
    """Kill the server mid-run, restore from the last checkpoint, finish,
    and report the max param gap vs the uninterrupted run (contract: 0)."""
    ref = _trainer(train, test, "none", n_clients=n_clients)
    ref.run(aggregations, eval_every=aggregations)

    with tempfile.NamedTemporaryFile(suffix=".ck") as f:
        killed = _trainer(train, test,
                          make_fault("server-kill", at_event=9),
                          n_clients=n_clients, ckpt_path=f.name,
                          ckpt_every=2)
        try:
            killed.run(aggregations, eval_every=aggregations)
        except ServerKilled:
            pass
        resumed = _trainer(train, test, "none", n_clients=n_clients)
        resumed.restore_checkpoint(f.name)
        while resumed.round < aggregations:
            resumed.run_round()

    gap = float(np.max(np.abs(np.asarray(ref.params_vec)
                              - np.asarray(resumed.params_vec))))
    ledgers_ok = (ref.bits_up == resumed.bits_up
                  and ref.event_log == resumed.event_log)
    note = (f"kill@event9 ckpt_every=2 aggs={aggregations} "
            f"ledgers_identical={ledgers_ok}")
    if verbose:
        print(f"faults/resume: params_max_abs_diff={gap} "
              f"ledgers_identical={ledgers_ok}")
    return [("faults/resume/params_max_abs_diff", gap, note)]


def run(verbose: bool = True, smoke: bool = False):
    if smoke:
        train, test = make_classification(seed=0, n=600, n_test=160)
        rows = _chaos_rows(train, test, 2, n_clients=40, verbose=verbose)
        rows += _resume_row(train, test, 3, n_clients=40, verbose=verbose)
        return rows
    train, test = make_classification(seed=0, n=6000, n_test=1200)
    rows = _chaos_rows(train, test, _AGGREGATIONS, n_clients=_N_CLIENTS,
                       verbose=verbose)
    rows += _resume_row(train, test, _AGGREGATIONS, n_clients=_N_CLIENTS,
                        verbose=verbose)
    return rows


if __name__ == "__main__":
    run(verbose=True, smoke="--smoke" in sys.argv)
