"""Chunked vs flat k-selection/encode micro-benchmarks at n = 2^20.

Rows (written to ``benchmarks/BENCH_chunked.json`` by run.py, P = 8 clients,
p = 1/400 -- the paper's upload sparsity):

  chunked_select_flat      -- ONE flat selection per client over the whole
                              2^20 vector (today's path): select_batch on
                              (8, 2^20) rows with k = 2621
  chunked_select_c16384    -- the same data as (8*64, 2^14) (client, chunk)
                              rows, per-chunk k = 40, ONE batched launch
  chunked_select_c65536    -- chunk = 2^16 (8*16 rows, k = 163)
  chunked_select_whole     -- the chunked driver at chunk = whole-vector
                              (must track chunked_select_flat: same work)
  chunked_encode_flat      -- StcCodec.encode_batch (P, n): selection +
                              ternarize + error feedback, flat
  chunked_encode_pipe16384 -- ChunkedCodec.encode_batch over the 64-chunk
                              spec: the pipelined multi-chunk row (fused
                              per-chunk selection + per-chunk µ/residuals)

The ISSUE acceptance row: chunked batched selection must be no slower than
the flat path at n = 2^20.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (chunk_codec, chunk_spec_from_sizes, get_stc_backend,
                        make_protocol, whole_vector_spec)
from repro.core.residual import stack_states

N = 1 << 20
P = 8
SPARSITY = 1 / 400


def _timeit(fn, iters: int = 5) -> float:
    out = fn()
    jax.block_until_ready(out)          # warm / compile
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return 1e6 * best


def run(verbose: bool = True, n: int = N):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((P, n)), jnp.float32)
    be = get_stc_backend("jnp")
    rows = []

    def row(name, us, note):
        rows.append((name, us, note))
        if verbose:
            print(f"{name:28s} {us:10.1f} us  {note}")

    # -- selection: flat vs chunked, identical total data ------------------
    k_flat = max(int(n * SPARSITY), 1)
    sel_flat = jax.jit(lambda v: be.select_batch(v, k_flat))
    us_flat = _timeit(lambda: sel_flat(x))
    row("chunked_select_flat", us_flat, f"(8, 2^20) k={k_flat}")

    for w in (1 << 14, 1 << 16):
        c = n // w
        k_c = max(int(w * SPARSITY), 1)
        xc = x.reshape(P * c, w)
        sel_c = jax.jit(lambda v, k=k_c: be.select_batch(v, k))
        us_c = _timeit(lambda: sel_c(xc))
        row(f"chunked_select_c{w}", us_c,
            f"({P * c}, {w}) k={k_c}/chunk, "
            f"{us_flat / us_c:.2f}x vs flat")

    sel_w = jax.jit(lambda v: be.select_batch(v, k_flat))
    row("chunked_select_whole", _timeit(lambda: sel_w(x)),
        "chunk = whole vector (same work as flat)")

    # -- full encode: flat codec vs the pipelined multi-chunk codec --------
    stc = make_protocol("stc", sparsity_up=SPARSITY, sparsity_down=SPARSITY)
    st_flat = stack_states(stc.init_client_state(n), P)
    enc_flat = jax.jit(lambda d, s: stc.encode_batch(d, s)[0])
    us_ef = _timeit(lambda: enc_flat(x, st_flat), iters=3)
    row("chunked_encode_flat", us_ef, "StcCodec.encode_batch (P, 2^20)")

    spec = chunk_spec_from_sizes([n], chunk_size=1 << 14)
    cc = chunk_codec(stc, spec)
    st_c = stack_states(cc.init_client_state(n), P)
    enc_c = jax.jit(lambda d, s: cc.encode_batch(d, s)[0])
    us_ec = _timeit(lambda: enc_c(x, st_c), iters=3)
    row("chunked_encode_pipe16384", us_ec,
        f"{spec.n_chunks} chunks/client, {us_ef / us_ec:.2f}x vs flat")

    cw = chunk_codec(stc, whole_vector_spec(n))
    st_w = stack_states(cw.init_client_state(n), P)
    enc_w = jax.jit(lambda d, s: cw.encode_batch(d, s)[0])
    row("chunked_encode_whole", _timeit(lambda: enc_w(x, st_w), iters=3),
        "chunked driver, 1 whole-vector chunk")

    return rows


if __name__ == "__main__":
    run()
