#!/usr/bin/env python
"""Registry smoke target: run the federated example for EVERY registered
codec (including third-party registrations) for 2 rounds each, so a protocol
that breaks the trainer contract fails fast in CI.

    python scripts/smoke_protocols.py [--rounds 2] [--model logreg]

Exits non-zero if any codec fails.
"""

import argparse
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
       "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}


def registered():
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.core import registered_protocols;"
         "print(' '.join(registered_protocols()))"],
        env=ENV, cwd=REPO, capture_output=True, text=True, check=True)
    return out.stdout.split()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--model", default="logreg")
    ap.add_argument("--timeout", type=int, default=600)
    ap.add_argument("--chunks", default=None,
                    help="forwarded to the example: chunked (layer, chunk) "
                         "codec states (int chunk size or 'whole')")
    args = ap.parse_args()

    names = registered()
    mode = f" (chunks={args.chunks})" if args.chunks else ""
    print(f"smoking {len(names)} registered codecs{mode}: {' '.join(names)}")
    failures = []
    for name in names:
        cmd = [sys.executable, os.path.join(REPO, "examples",
                                            "federated_noniid.py"),
               "--rounds", str(args.rounds), "--model", args.model,
               "--protocols", name]
        if args.chunks:
            cmd += ["--chunks", str(args.chunks)]
        t0 = time.time()
        try:
            r = subprocess.run(cmd, env=ENV, cwd=REPO, capture_output=True,
                               text=True, timeout=args.timeout)
        except subprocess.TimeoutExpired:
            print(f"FAIL {name} (timeout after {args.timeout}s)")
            failures.append(name)
            continue
        dt = time.time() - t0
        if r.returncode == 0:
            print(f"OK   {name} ({dt:.0f}s)")
        else:
            tail = (r.stdout + r.stderr)[-800:].replace("\n", " | ")
            print(f"FAIL {name} ({dt:.0f}s): {tail}")
            failures.append(name)
    if failures:
        print(f"\n{len(failures)} codec(s) failed: {' '.join(failures)}")
        return 1
    print("\nall registered codecs passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
