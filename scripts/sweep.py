#!/usr/bin/env python
"""Parallel dry-run sweep driver: one subprocess per (arch, shape, mesh),
N workers, cheap shapes first. Skips combos whose artifact already exists.

    python scripts/sweep.py [--workers 7] [--meshes single multi]
"""

import argparse
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARCHS = ["smollm-135m", "qwen2-0.5b", "mamba2-370m", "granite-moe-3b-a800m",
         "internvl2-2b", "recurrentgemma-2b", "whisper-medium",
         "deepseek-v2-lite-16b", "moonshot-v1-16b-a3b", "phi3-medium-14b"]
SHAPES = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]


def run_one(job):
    arch, shape, multi, protocol = job
    mesh = "2x16x16" if multi else "16x16"
    # "stc" keeps the historical artifact name; other codecs get a suffix
    tag = "" if protocol == "stc" else f"__{protocol}"
    out = os.path.join(REPO, "artifacts", "dryrun",
                       f"{arch}__{shape}__{mesh}{tag}.json")
    if os.path.exists(out):
        return f"SKIP {arch} {shape} {mesh} {protocol}"
    cmd = [sys.executable, "-u", "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--protocol", protocol]
    if tag:
        cmd += ["--variant", protocol]
    if multi:
        cmd.append("--multi-pod")
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    t0 = time.time()
    r = subprocess.run(cmd, env=env, cwd=REPO, capture_output=True,
                       text=True, timeout=7200)
    dt = time.time() - t0
    if r.returncode == 0 and os.path.exists(out):
        return f"OK   {arch} {shape} {mesh} {protocol} ({dt:.0f}s)"
    tail = (r.stdout + r.stderr)[-1200:].replace("\n", " | ")
    return f"FAIL {arch} {shape} {mesh} {protocol} ({dt:.0f}s): {tail}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=7)
    ap.add_argument("--meshes", nargs="+", default=["single", "multi"])
    ap.add_argument("--protocols", nargs="+", default=["stc"],
                    help="registered codec names to sweep (default: stc)")
    args = ap.parse_args()

    jobs = []
    for shape in SHAPES:                       # cheap shapes first
        for arch in ARCHS:                     # small archs first
            for m in args.meshes:
                for proto in args.protocols:
                    jobs.append((arch, shape, m == "multi", proto))

    log = os.path.join(REPO, "artifacts", "sweep_parallel.log")
    done = 0
    with open(log, "a") as f, ThreadPoolExecutor(args.workers) as ex:
        f.write(f"\n==== sweep start: {len(jobs)} jobs ====\n")
        f.flush()
        for res in ex.map(run_one, jobs):
            done += 1
            f.write(f"[{done}/{len(jobs)}] {res}\n")
            f.flush()
        f.write("SWEEP DONE\n")


if __name__ == "__main__":
    main()
