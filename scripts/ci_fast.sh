#!/usr/bin/env bash
# Fast CI lane: lint + the full non-slow test suite + a 2-round end-to-end
# smoke of every registered protocol codec.  (The slow lane is
# `pytest -m slow` plus `python -m benchmarks.run` gated by
# `scripts/check_bench.py` -- see .github/workflows/ci.yml.)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# Surface WHICH stage broke: every stage announces itself, and the EXIT trap
# names the in-flight stage on any nonzero exit, so a red lane is readable
# from the last two log lines instead of a scrollback hunt.
STAGE="setup"
on_exit() {
    code=$?
    if [ "$code" -ne 0 ]; then
        echo "ci_fast: FAILED in stage [$STAGE] (exit $code)" >&2
    else
        echo "ci_fast: all stages passed"
    fi
}
trap on_exit EXIT
stage() { STAGE="$1"; echo "== ci_fast stage: $1 =="; }

stage lint
if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    echo "(ruff not installed; skipping lint -- CI installs it via requirements-ci.txt)"
fi

stage pytest-fast
python -m pytest -m "not slow" -q

stage protocol-smoke
python scripts/smoke_protocols.py

stage protocol-smoke-chunked
python scripts/smoke_protocols.py --chunks 64

stage ingest-smoke
python -m benchmarks.ingest_bench --smoke

stage events-smoke
python -m benchmarks.events_bench --smoke

stage faults-smoke
python -m benchmarks.faults_bench --smoke

stage robust-smoke
python -m benchmarks.robust_bench --smoke

stage adaptive-smoke
python -m benchmarks.adaptive_bench --smoke

stage done
