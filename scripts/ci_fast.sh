#!/usr/bin/env bash
# Fast CI lane: the full non-slow test suite + a 2-round end-to-end smoke of
# every registered protocol codec.  (The slow lane is `pytest -m slow` plus
# `python -m benchmarks.run`.)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m pytest -m "not slow" -q
python scripts/smoke_protocols.py
