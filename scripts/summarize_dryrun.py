#!/usr/bin/env python
"""Generate artifacts/dryrun_summary.md (§Dry-run table) from the artifacts."""

import glob
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    rows = []
    for path in sorted(glob.glob(f"{REPO}/artifacts/dryrun/*.json")):
        r = json.load(open(path))
        if r.get("variant"):
            continue
        mem = r.get("memory", {})
        arg = mem.get("argument_size_in_bytes", 0) / 2**30
        tmp = mem.get("temp_size_in_bytes", 0) / 2**30
        colls = r.get("collectives", {})
        cstr = " ".join(f"{k.split('-')[1] if '-' in k else k}:"
                        f"{v['count']}x/{v['bytes']/1e6:.0f}MB"
                        for k, v in sorted(colls.items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['flops']:.2e} | {r['bytes_accessed']:.2e} "
            f"| {arg:.2f} | {tmp:.1f} | {cstr} | {r['t_compile_s']:.0f}s |")

    out = f"{REPO}/artifacts/dryrun_summary.md"
    with open(out, "w") as f:
        f.write("# Dry-run results: lower+compile per (arch x shape x mesh)\n\n")
        f.write("Per-chip figures from compiled.cost_analysis() / "
                "memory_analysis(); collective result bytes from the "
                "optimized HLO.\n\n")
        f.write("| arch | shape | mesh | FLOPs/chip | bytes/chip "
                "| args GiB | temp GiB* | collectives | compile |\n")
        f.write("|---|---|---|---|---|---|---|---|---|\n")
        f.write("\n".join(rows))
        f.write("\n\n*temp is the CPU-backend buffer-assignment figure "
                "(no cross-region reuse modeling; relative metric -- see "
                "EXPERIMENTS.md §Dry-run).\n")
    print(f"wrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
