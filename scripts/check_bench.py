#!/usr/bin/env python
"""CI bench-regression gate: fresh bench rows vs the committed baseline.

    python scripts/check_bench.py [--ref HEAD] [--tolerance 2.0] [files...]

After the slow lane reruns ``python -m benchmarks.run`` (which overwrites
``benchmarks/BENCH_*.json`` in the working tree), this script compares the
fresh TIMING rows on disk against the committed baseline at ``--ref``
(``git show REF:path``) and fails -- exit 1 -- if any matched row's fresh
median exceeds ``tolerance`` x the baseline median.  Rows present on only
one side are reported but never fail the gate (new/renamed benches must not
brick CI), and only timing files (unit == "us") gate: quality files like
``BENCH_async.json`` carry accuracies/bit counts where "2x" is meaningless.

Names appearing multiple times in one file are median-reduced first, so a
bench may emit repeated measurements of the same row.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import statistics
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_FILES = ("benchmarks/BENCH_stc.json", "benchmarks/BENCH_wire.json",
                 "benchmarks/BENCH_chunked.json",
                 "benchmarks/BENCH_ingest.json",
                 "benchmarks/BENCH_events.json",
                 "benchmarks/BENCH_faults.json",
                 "benchmarks/BENCH_robust.json",
                 "benchmarks/BENCH_adaptive.json")


def row_value(row: dict):
    """A bench row's scalar, whatever key vintage wrote it (None when the
    row carries no recognizable value key -- e.g. a bench family written by
    a newer run that the committed baseline vintage predates -- or when the
    value is null/non-numeric/non-finite: quality benches legitimately emit
    NaN rows such as "bits to an accuracy the run never reached", and those
    must downgrade to report-only warnings, never crash the gate)."""
    for key in ("us", "value"):
        if key in row:
            try:
                val = float(row[key])
            except (TypeError, ValueError):
                return None
            return val if math.isfinite(val) else None
    return None


def medians_by_name(payload: dict, unparsed: list | None = None
                    ) -> dict[str, float]:
    """name -> median value over a payload's (possibly repeated) rows.

    Rows missing a name or value key are SKIPPED (collected into
    ``unparsed`` when given) instead of raising: a bench family present on
    one side only must stay a report-only warning, never a crash."""
    by_name: dict[str, list[float]] = {}
    for row in payload.get("rows", []):
        name = row.get("name")
        val = row_value(row)
        if name is None or val is None:
            if unparsed is not None:
                unparsed.append(name or "<unnamed>")
            continue
        by_name.setdefault(name, []).append(val)
    return {name: statistics.median(vals) for name, vals in by_name.items()}


def compare(baseline: dict[str, float], fresh: dict[str, float],
            tolerance: float) -> tuple[list[str], list[str]]:
    """Returns (report_lines, regression_lines)."""
    report, regressions = [], []
    for name in sorted(set(baseline) | set(fresh)):
        if name not in fresh:
            report.append(f"  MISSING {name} (baseline {baseline[name]:.1f})")
            continue
        if name not in baseline:
            report.append(f"  NEW     {name} = {fresh[name]:.1f}")
            continue
        base, cur = baseline[name], fresh[name]
        ratio = cur / base if base > 0 else float("inf")
        line = f"  {name}: {base:.1f} -> {cur:.1f}  ({ratio:.2f}x)"
        if ratio > tolerance:
            regressions.append(line)
            report.append("X" + line[1:])
        else:
            report.append(line)
    return report, regressions


def load_baseline(path: str, ref: str) -> dict | None:
    """The committed payload at ``ref`` (None when absent there)."""
    proc = subprocess.run(["git", "show", f"{ref}:{path}"], cwd=REPO,
                          capture_output=True, text=True)
    if proc.returncode != 0:
        return None
    return json.loads(proc.stdout)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", default=list(DEFAULT_FILES),
                    help="repo-relative bench JSON files to gate")
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the baseline (default HEAD)")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="fail when fresh/baseline exceeds this (default 2x)")
    args = ap.parse_args(argv)

    files = args.files or list(DEFAULT_FILES)
    failed = False
    for rel in files:
        full = os.path.join(REPO, rel)
        print(f"== {rel} (baseline {args.ref}, tolerance "
              f"{args.tolerance:g}x) ==")
        if not os.path.exists(full):
            print("  no fresh file on disk; did benchmarks.run run? SKIP")
            continue
        with open(full) as f:
            fresh_payload = json.load(f)
        baseline_payload = load_baseline(rel, args.ref)
        if baseline_payload is None:
            # a bench family the fresh run produced but the committed tree
            # does not know yet: report-only, never a failure
            print(f"  no committed baseline at {args.ref}; report-only "
                  "(new bench family, gates from its next commit on)")
            continue
        if fresh_payload.get("unit", "us") != "us":
            print("  non-timing file (unit != us); report only, never gates")
        unparsed_base: list = []
        unparsed_fresh: list = []
        report, regressions = compare(
            medians_by_name(baseline_payload, unparsed_base),
            medians_by_name(fresh_payload, unparsed_fresh),
            args.tolerance)
        for side, names in (("baseline", unparsed_base),
                            ("fresh", unparsed_fresh)):
            for name in names:
                print(f"  WARNING unparsed {side} row {name!r} "
                      "(no us/value key, or null/NaN value); report-only")
        print("\n".join(report))
        if regressions and fresh_payload.get("unit", "us") == "us":
            failed = True
            print(f"  -> {len(regressions)} row(s) regressed beyond "
                  f"{args.tolerance:g}x")
    if failed:
        print("bench regression gate: FAIL")
        return 1
    print("bench regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
