"""Model-layer correctness: chunked attention vs dense reference, SSD vs
naive recurrence, RG-LRU vs sequential loop, and whole-model prefill/decode
consistency for every block family."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (decode_step, encode_frames, forward, init_cache,
                          init_model)
from repro.models.attention import chunked_attention
from repro.models.config import (EncoderConfig, MLAConfig, ModelConfig,
                                 MoEConfig, RGLRUConfig, SSMConfig)
from repro.models.rglru import _gates, rglru_apply, rglru_init
from repro.models.ssm import ssd_scan

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)


def _dense_attention(q, k, v, causal, window=0):
    """O(S^2) reference with GQA."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / jnp.sqrt(jnp.float32(hd))
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= ki <= qi
    if window:
        mask &= ki > qi - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


class TestChunkedAttention:
    @pytest.mark.parametrize("sq,skv,h,kv,chunk", [
        (16, 16, 4, 4, 4), (32, 32, 4, 2, 8), (17, 17, 6, 3, 5),
        (8, 24, 4, 1, 24),
    ])
    def test_vs_dense(self, sq, skv, h, kv, chunk):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, sq, h, 8))
        k = jax.random.normal(ks[1], (2, skv, kv, 8))
        v = jax.random.normal(ks[2], (2, skv, kv, 8))
        causal = sq == skv
        got = chunked_attention(q, k, v, causal=causal, chunk=chunk)
        want = _dense_attention(q, k, v, causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    def test_sliding_window(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 32, 2, 8))
        k = jax.random.normal(ks[1], (1, 32, 2, 8))
        v = jax.random.normal(ks[2], (1, 32, 2, 8))
        got = chunked_attention(q, k, v, causal=True, window=8, chunk=8)
        want = _dense_attention(q, k, v, True, window=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    def test_mla_value_dim_differs(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 8, 4, 12))
        k = jax.random.normal(ks[1], (1, 8, 4, 12))
        v = jax.random.normal(ks[2], (1, 8, 4, 6))     # hdv != hd
        out = chunked_attention(q, k, v, causal=True, chunk=4)
        assert out.shape == (1, 8, 4, 6)


class TestSSD:
    def _naive_ssd(self, xh, dt, A, B, C):
        """Sequential reference:  h' = exp(dt·A)h + dt·B⊗x;  y = C·h."""
        b, s, h, p = xh.shape
        n = B.shape[-1]
        rep = h // B.shape[2]
        Bf = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
        Cf = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
        hst = jnp.zeros((b, h, p, n), jnp.float32)
        ys = []
        for t in range(s):
            da = jnp.exp(dt[:, t] * (-jnp.exp(A))[None, :])        # (b,h)
            hst = (hst * da[..., None, None] +
                   jnp.einsum("bh,bhn,bhp->bhpn", dt[:, t], Bf[:, t],
                              xh[:, t].astype(jnp.float32)))
            ys.append(jnp.einsum("bhn,bhpn->bhp", Cf[:, t], hst))
        return jnp.stack(ys, axis=1)

    @pytest.mark.parametrize("chunk", [2, 4, 8])
    def test_chunked_vs_naive(self, chunk):
        b, s, h, p, n = 2, 16, 4, 8, 4
        ks = jax.random.split(KEY, 5)
        xh = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = jax.random.normal(ks[2], (h,)) * 0.5
        B = jax.random.normal(ks[3], (b, s, 1, n))
        C = jax.random.normal(ks[4], (b, s, 1, n))
        y, _ = ssd_scan(xh, dt, A, B, C, chunk)
        want = self._naive_ssd(xh, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_final_state_continuation(self):
        """Scanning two halves with carried state == scanning the whole."""
        b, s, h, p, n = 1, 16, 2, 4, 4
        ks = jax.random.split(KEY, 5)
        xh = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = jax.random.normal(ks[2], (h,)) * 0.5
        B = jax.random.normal(ks[3], (b, s, 1, n))
        C = jax.random.normal(ks[4], (b, s, 1, n))
        y_full, st_full = ssd_scan(xh, dt, A, B, C, 4)
        y1, st1 = ssd_scan(xh[:, :8], dt[:, :8], A, B[:, :8], C[:, :8], 4)
        y2, st2 = ssd_scan(xh[:, 8:], dt[:, 8:], A, B[:, 8:], C[:, 8:], 4,
                           init_state=st1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                                   rtol=2e-3, atol=2e-3)


class TestRGLRU:
    def test_scan_vs_sequential(self):
        d = 16
        cfg = RGLRUConfig()
        params = rglru_init(KEY, d, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d))
        out = rglru_apply(params, x, cfg, d)

        # sequential reference on the same gates
        u = x @ params["w_in"]
        k = params["conv_w"].shape[0]
        pad = jnp.zeros((2, k - 1, d))
        xp = jnp.concatenate([pad, u], axis=1)
        conv = sum(params["conv_w"][i] * xp[:, i : i + 12] for i in range(k))
        a, bm = _gates(params, conv, cfg)
        h = jnp.zeros((2, d))
        hs = []
        for t in range(12):
            h = a[:, t] * h + bm[:, t]
            hs.append(h)
        want = jnp.stack(hs, 1) @ params["w_out"]
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# whole-model prefill/decode consistency
# ---------------------------------------------------------------------------

V = 61


def _consistency(cfg, frames=None, prefix=None, steps=6, atol=2e-3):
    params = init_model(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, steps), 0, V)
    memory = None
    fw_kwargs = {}
    if frames is not None:
        fw_kwargs["frames"] = frames
        memory = encode_frames(params, cfg, frames.astype(jnp.float32))
    if prefix is not None:
        fw_kwargs["prefix"] = prefix
    logits_full, _ = forward(params, cfg, toks, compute_dtype=jnp.float32,
                             **fw_kwargs)
    if prefix is not None:
        logits_full = logits_full[:, prefix.shape[1]:]
    if prefix is not None:
        pytest.skip("prefix decode offsets covered separately")
    caches = init_cache(cfg, 2, steps + 2, jnp.float32)
    for t in range(steps):
        lg, caches = decode_step(params, cfg, toks[:, t : t + 1], caches,
                                 memory=memory, compute_dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(logits_full[:, t]),
            rtol=5e-3, atol=atol,
            err_msg=f"{cfg.name} decode diverges at step {t}")


class TestDecodeConsistency:
    def test_dense_gqa(self):
        cfg = ModelConfig(name="d", arch_type="dense", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=V,
                          attn_bias=True, remat=False)
        _consistency(cfg)

    def test_mla(self):
        cfg = ModelConfig(name="m", arch_type="dense", n_layers=2, d_model=32,
                          n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=V,
                          block_pattern=("mla",),
                          mla=MLAConfig(kv_lora_rank=16, qk_nope_head_dim=8,
                                        qk_rope_head_dim=4, v_head_dim=8),
                          remat=False)
        _consistency(cfg)

    def test_moe(self):
        cfg = ModelConfig(name="e", arch_type="moe", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=V,
                          moe=MoEConfig(n_experts=4, top_k=2, n_shared=1,
                                        d_expert=16), remat=False)
        _consistency(cfg)

    def test_ssd(self):
        cfg = ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=32,
                          n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=V,
                          block_pattern=("ssd",),
                          ssm=SSMConfig(d_state=8, head_dim=8, chunk=2),
                          remat=False)
        _consistency(cfg, atol=5e-3)

    def test_hybrid_rglru(self):
        cfg = ModelConfig(name="h", arch_type="hybrid", n_layers=3,
                          d_model=32, n_heads=4, n_kv_heads=1, d_ff=64,
                          vocab_size=V,
                          block_pattern=("rglru", "rglru", "local"),
                          sliding_window=4, rglru=RGLRUConfig(), remat=False)
        _consistency(cfg)

    def test_encdec(self):
        cfg = ModelConfig(name="w", arch_type="audio", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=V,
                          mlp_act="gelu",
                          encoder=EncoderConfig(n_layers=2, n_frames=5),
                          remat=False)
        frames = jax.random.normal(jax.random.PRNGKey(3), (2, 5, 32))
        _consistency(cfg, frames=frames)

    def test_sliding_ring_buffer_matches_full(self):
        """Ring-buffer decode == full-cache decode inside the window."""
        base = dict(name="r", arch_type="dense", n_layers=1, d_model=32,
                    n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=V,
                    remat=False)
        cfg_w = ModelConfig(**base, sliding_window=4)
        params = init_model(cfg_w, KEY)
        toks = jax.random.randint(jax.random.PRNGKey(4), (1, 10), 0, V)
        # windowed forward as reference
        ref_logits, _ = forward(params, cfg_w, toks, compute_dtype=jnp.float32)
        caches = init_cache(cfg_w, 1, 10, jnp.float32)  # ring of size 4
        assert caches[0].k.shape[1] == 4 and caches[0].ring
        for t in range(10):
            lg, caches = decode_step(params, cfg_w, toks[:, t : t + 1],
                                     caches, compute_dtype=jnp.float32)
            np.testing.assert_allclose(
                np.asarray(lg[:, 0]), np.asarray(ref_logits[:, t]),
                rtol=5e-3, atol=2e-3, err_msg=f"step {t}")
