"""Error-feedback residual invariants + Protocol interface behavior."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Protocol, encode_ternary, decode_ternary,
                        make_protocol, stc_compress)
from repro.core.residual import compress_with_feedback, init_residual

jax.config.update("jax_platform_name", "cpu")


def _rand(n, seed=0, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(n) * scale, jnp.float32)


class TestErrorFeedback:
    def test_exact_decomposition(self):
        """msg + residual' == update + residual  (no mass lost, Eqs. 9/11)."""
        x = _rand(500, 1)
        state = init_residual(x)
        msg, state2, _ = compress_with_feedback(
            x, state, lambda v: stc_compress(v, 0.02))
        np.testing.assert_allclose(
            np.asarray(msg + state2.residual), np.asarray(x), rtol=1e-5)

    def test_telescoping_sum(self):
        """Over T rounds: Σ msgs + final residual == Σ raw updates."""
        n, rounds = 300, 20
        state = init_residual(jnp.zeros(n))
        total_updates = jnp.zeros(n)
        total_msgs = jnp.zeros(n)
        for t in range(rounds):
            u = _rand(n, seed=t)
            total_updates += u
            msg, state, _ = compress_with_feedback(
                u, state, lambda v: stc_compress(v, 0.05))
            total_msgs += msg
        np.testing.assert_allclose(
            np.asarray(total_msgs + state.residual),
            np.asarray(total_updates), rtol=1e-4, atol=1e-5)

    def test_residual_eventually_transmits(self):
        """A large dropped coordinate must eventually be sent (EF liveness)."""
        n = 100
        state = init_residual(jnp.zeros(n))
        spike = jnp.zeros(n).at[7].set(0.5)  # below top-k of the noise at first
        sent = 0.0
        for t in range(50):
            u = _rand(n, seed=100 + t, scale=1.0) * 0.0 + spike
            msg, state, _ = compress_with_feedback(
                u, state, lambda v: stc_compress(v, 0.02))
            sent += float(msg[7])
        assert sent > 0.5 * 50 * 0.5  # most of the accumulated mass got through


class TestProtocols:
    def test_factory_defaults(self):
        stc = make_protocol("stc")
        assert stc.sparsity_up == pytest.approx(1 / 400)
        assert stc.error_feedback
        with pytest.raises(KeyError):
            make_protocol("nope")

    def test_stc_bits_much_smaller(self):
        n = 865_482  # VGG11* size from the paper
        stc = make_protocol("stc")
        fedavg = make_protocol("fedavg")
        assert stc.upload_bits(n) < fedavg.upload_bits(n) / 500
        assert stc.download_bits(n) < fedavg.download_bits(n) / 500

    def test_topk_downstream_densifies(self):
        """Sec. V-A: upload-only top-k downstream grows with participants."""
        n = 100_000
        topk = make_protocol("topk", sparsity_up=1 / 100)
        d1 = topk.download_bits(n, n_participating=1)
        d200 = topk.download_bits(n, n_participating=200)
        assert d200 > 50 * d1  # effectively dense downstream

    def test_server_aggregate_stc(self):
        p = make_protocol("stc", sparsity_up=0.05, sparsity_down=0.05)
        msgs = jnp.stack([_rand(200, 5), _rand(200, 6)])
        srv = p.init_server_state(200)
        out, srv2, stats = p.aggregate(msgs, srv)
        # output is ternary
        vals = np.unique(np.asarray(out))
        mu = float(stats.mu)
        assert all(np.isclose(v, 0) or np.isclose(abs(v), mu, rtol=1e-5)
                   for v in vals)
        # residual holds the difference exactly
        np.testing.assert_allclose(
            np.asarray(out + srv2.residual),
            np.asarray(jnp.mean(msgs, axis=0)), rtol=1e-5, atol=1e-6)

    def test_wire_roundtrip_through_codec(self):
        """encode -> Golomb encode -> decode == same message."""
        p = make_protocol("stc", sparsity_up=0.02, sparsity_down=0.02)
        st_ = p.init_client_state(400)
        msg, _, _ = p.encode(_rand(400, 9), st_)
        payload, bit_len, mu, n = encode_ternary(np.asarray(msg),
                                                 p.sparsity_up)
        back = decode_ternary(payload, bit_len, mu, n, p.sparsity_up)
        np.testing.assert_allclose(back, np.asarray(msg), rtol=1e-5, atol=1e-7)
        # the codec-level wire API is the same stream
        m = p.encode_wire(np.asarray(msg), direction="up")
        assert m.bit_len == bit_len
        np.testing.assert_allclose(p.decode_wire(m, direction="up"),
                                   np.asarray(msg), rtol=1e-5, atol=1e-7)
