"""The pluggable codec registry: legacy-dispatch equivalence, registration,
the new `ternquant` codec, the deduped topk bit ledger, and the vectorized
partial-participation sync-cost accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Codec, PROTOCOLS, ResidualState, UpdateCache,
                        get_stc_backend, majority_vote_sign, make_protocol,
                        register_protocol, registered_protocols,
                        sign_compress, ternary_quantize, top_k_sparsify)
from repro.core import golomb
from repro.core.protocols import _REGISTRY
from repro.data import make_classification
from repro.fed import FedEnvironment, FederatedTrainer, TrainerConfig
from repro.models.paper_models import MODEL_ZOO

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape) * scale,
        jnp.float32)


@pytest.fixture(scope="module")
def data():
    return make_classification(seed=0, n=1200, n_test=300)


# ---------------------------------------------------------------------------
# legacy equivalence: the registry codecs must reproduce, bit for bit, the
# pre-refactor string-dispatch round (the old fed/loop compress_clients +
# aggregation branches, reimplemented verbatim below as the oracle).
# ---------------------------------------------------------------------------


def _legacy_round(name, proto, deltas, res_sel, server_res):
    """The old `if proto.name == ...` round, spelled out."""
    if name in ("baseline", "fedavg"):
        msgs, new_res = deltas, res_sel
    elif name == "signsgd":
        msgs = jax.vmap(lambda d: sign_compress(d, proto.sign_step)[0])(deltas)
        new_res = res_sel
    elif name == "topk":
        carried = deltas + res_sel
        msgs = jax.vmap(
            lambda c: top_k_sparsify(c, proto.sparsity_up)[0])(carried)
        new_res = carried - msgs
    elif name == "stc":
        be = get_stc_backend(proto.backend)
        msgs, new_res, _ = be.compress_with_residual_batch(
            deltas, res_sel, proto.sparsity_up)
    else:
        raise ValueError(name)

    if name == "signsgd":
        global_delta, new_srv = majority_vote_sign(msgs, proto.sign_step), \
            server_res
    else:
        mean = jnp.mean(msgs, axis=0)
        if name == "stc":
            be = get_stc_backend(proto.backend)
            global_delta, new_srv, _ = be.compress_with_residual(
                mean, server_res, proto.sparsity_down)
        else:
            global_delta, new_srv = mean, server_res
    return msgs, new_res, global_delta, new_srv


class TestLegacyEquivalence:
    @pytest.mark.parametrize("name", PROTOCOLS)
    def test_round_bit_identical(self, name):
        P, n = 4, 600
        proto = make_protocol(name, **(
            dict(sparsity_up=1 / 30, sparsity_down=1 / 30)
            if name == "stc" else
            dict(sparsity_up=1 / 30) if name == "topk" else {}))
        deltas = _rand((P, n), seed=3)
        res_sel = _rand((P, n), seed=4, scale=0.1)
        server_res = _rand((n,), seed=5, scale=0.1)

        ref_msgs, ref_res, ref_gd, ref_srv = _legacy_round(
            name, proto, deltas, res_sel, server_res)

        # codec path: wrap the same raw arrays into the codec's state pytrees
        cstates = (ResidualState(residual=res_sel)
                   if proto.init_client_state(n) is not None else None)
        sstate = (ResidualState(residual=server_res)
                  if proto.init_server_state(n) is not None else None)

        msgs, new_cstates, _ = proto.encode_batch(deltas, cstates)
        gd, new_sstate, _ = proto.aggregate(msgs, sstate)

        np.testing.assert_array_equal(np.asarray(msgs), np.asarray(ref_msgs))
        np.testing.assert_array_equal(np.asarray(gd), np.asarray(ref_gd))
        if new_cstates is not None:
            np.testing.assert_array_equal(
                np.asarray(new_cstates.residual), np.asarray(ref_res))
        if new_sstate is not None:
            np.testing.assert_array_equal(
                np.asarray(new_sstate.residual), np.asarray(ref_srv))

    @pytest.mark.parametrize("name", PROTOCOLS)
    def test_ledger_matches_legacy_formulas(self, name):
        """upload/download bits match the pre-refactor analytic entries."""
        n = 86_548
        proto = make_protocol(name)
        if name in ("baseline", "fedavg"):
            assert proto.upload_bits(n) == golomb.fedavg_message_bits(n)
        elif name == "signsgd":
            assert proto.upload_bits(n) == golomb.signsgd_message_bits(n)
        elif name == "stc":
            assert proto.upload_bits(n) == golomb.stc_message_bits(
                n, proto.sparsity_up)
        assert proto.download_bits(n, n_participating=1) > 0


class TestRegistry:
    def test_all_paper_protocols_registered(self):
        for name in PROTOCOLS:
            assert name in registered_protocols()
        assert "ternquant" in registered_protocols()

    def test_unknown_name_lists_registered(self):
        with pytest.raises(KeyError) as ei:
            make_protocol("nope")
        msg = str(ei.value)
        assert "nope" in msg
        for name in registered_protocols():
            assert name in msg

    def test_duplicate_registration_is_loud(self):
        with pytest.raises(ValueError, match="already registered"):
            @register_protocol(name="stc")
            @dataclasses.dataclass(frozen=True)
            class Impostor(Codec):
                name = "stc"
        assert type(make_protocol("stc")).__name__ == "StcCodec"

    def test_factory_backward_compatible(self):
        stc = make_protocol("stc", sparsity_up=1 / 50, backend="jnp")
        assert stc.sparsity_up == pytest.approx(1 / 50)
        assert stc.sparsity_down == pytest.approx(1 / 400)
        assert stc.error_feedback
        fed = make_protocol("fedavg")
        assert fed.local_iters == 400
        # pre-registry kwargs stay accepted: inert fields drop, contradictory
        # ClassVar overrides and unknown fields are loud
        topk = make_protocol("topk", sparsity_up=1 / 100, error_feedback=True,
                             sparsity_down=1 / 100)
        assert topk.sparsity_up == pytest.approx(1 / 100)
        with pytest.raises(ValueError, match="fixes error_feedback"):
            make_protocol("stc", error_feedback=False)
        with pytest.raises(TypeError, match="no field"):
            make_protocol("stc", sparsity_sideways=0.1)

    def test_custom_codec_end_to_end(self, data):
        """A ≤30-line third-party codec registers and trains via the same
        trainer with zero trainer changes."""

        @register_protocol
        @dataclasses.dataclass(frozen=True)
        class Int8Codec(Codec):                                  # line 1
            """Stateless uniform int8 quantization of the update."""
            name = "int8-test"
            levels: int = 255

            def encode(self, delta, state):
                s = jnp.max(jnp.abs(delta)) + 1e-12
                q = jnp.round(delta / s * (self.levels // 2))
                return q * s / (self.levels // 2), state, None

            def upload_bits(self, numel):
                return 8.0 * numel + 32.0

            def download_bits(self, numel, n_participating=1):
                return 8.0 * numel + 32.0                        # line 14

        try:
            train, test = data
            env = FedEnvironment(n_clients=6, participation=0.5,
                                 classes_per_client=2, batch_size=10)
            tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, env,
                                  make_protocol("int8-test"),
                                  TrainerConfig(lr=0.05))
            tr.run(3, eval_every=3)
            assert np.all(np.isfinite(np.asarray(tr.params_vec)))
            assert tr.bits_up == pytest.approx(
                3 * 3 * (8.0 * tr.numel + 32.0))    # 3 rounds x 3 clients
        finally:
            _REGISTRY.pop("int8-test", None)

    def test_every_registered_codec_runs(self, data):
        """Acceptance: all five paper protocols + ternquant end-to-end."""
        train, test = data
        env = FedEnvironment(n_clients=6, participation=0.5,
                             classes_per_client=2, batch_size=10)
        for name in registered_protocols():
            kw = {"stc": dict(sparsity_up=1 / 20, sparsity_down=1 / 20),
                  "topk": dict(sparsity_up=1 / 20),
                  "fedavg": dict(local_iters=2)}.get(name, {})
            tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, env,
                                  make_protocol(name, **kw),
                                  TrainerConfig(lr=0.05))
            tr.run(2, eval_every=2)
            assert np.all(np.isfinite(np.asarray(tr.params_vec))), name
            assert tr.bits_up > 0 and tr.bits_down > 0, name


class TestTernQuant:
    def test_output_is_ternary(self):
        x = _rand(1000, seed=7)
        out, stats = ternary_quantize(x, 0.75)
        vals = np.unique(np.asarray(out))
        mu = float(stats.mu)
        assert all(np.isclose(v, 0) or np.isclose(abs(v), mu, rtol=1e-5)
                   for v in vals)
        assert 0 < int(stats.nnz) < x.size

    def test_error_feedback_exact(self):
        p = make_protocol("ternquant")
        st = p.init_client_state(400)
        x = _rand(400, seed=8)
        msg, st2, _ = p.encode(x, st)
        np.testing.assert_allclose(np.asarray(msg + st2.residual),
                                   np.asarray(x), rtol=1e-5)

    def test_bits_between_signsgd_and_fedavg(self):
        n = 100_000
        tq = make_protocol("ternquant")
        assert tq.upload_bits(n) == pytest.approx(n * np.log2(3.0) + 32.0)
        assert golomb.signsgd_message_bits(n) < tq.upload_bits(n)
        assert tq.upload_bits(n) < golomb.fedavg_message_bits(n) / 15

    def test_tree_matches_flat(self):
        """ternary_quantize_tree == ternary_quantize on the flattened tree."""
        from repro.core.compression import flatten_pytree
        from repro.core.distributed import ternary_quantize_tree
        tree = {"a": _rand((40, 5), seed=9), "b": _rand(123, seed=10)}
        vec, _ = flatten_pytree(tree)
        flat_out, flat_stats = ternary_quantize(vec, 0.75)
        tree_out, tree_stats = ternary_quantize_tree(tree, 0.75)
        tree_vec = flatten_pytree(tree_out)[0]
        np.testing.assert_allclose(np.asarray(tree_vec), np.asarray(flat_out),
                                   rtol=1e-5, atol=1e-7)
        assert int(tree_stats.nnz) == int(flat_stats.nnz)


class TestTopkLedger:
    def test_upload_is_16bit_positions_plus_fp32_values(self):
        n = 100_000
        topk = make_protocol("topk", sparsity_up=1 / 100)
        k = n // 100
        assert topk.upload_bits(n) == pytest.approx(k * (16.0 + 32.0))

    def test_up_down_share_one_helper(self):
        """download at 1 participant == upload (same sparse-message helper)."""
        n = 50_000
        topk = make_protocol("topk", sparsity_up=1 / 50)
        assert topk.download_bits(n, n_participating=1) == \
            topk.upload_bits(n)

    def test_download_densifies_to_dense_fp32(self):
        n = 10_000
        topk = make_protocol("topk", sparsity_up=1 / 100)
        assert topk.download_bits(n, n_participating=200) == \
            golomb.fedavg_message_bits(n)


class TestVectorizedSyncBits:
    def test_batch_matches_loop(self):
        cache = UpdateCache(numel=10, max_rounds=8)
        for _ in range(5):
            cache.push(np.zeros(10))
        rng = np.random.default_rng(0)
        skipped = rng.integers(0, 12, size=64)
        per_update, model_bits = 123.5, 99_999.0
        loop_total = sum(cache.sync_bits(int(s), per_update, model_bits)
                         for s in skipped)
        batch_total = cache.sync_bits_batch(skipped, per_update, model_bits)
        assert batch_total == pytest.approx(loop_total)

    def test_trainer_ledger_unchanged(self, data):
        """Regression: the vectorized trainer ledger equals a per-client
        replay of cache.sync_bits over the same participation trace."""
        train, test = data
        env = FedEnvironment(n_clients=8, participation=0.25,
                             classes_per_client=2, batch_size=10)
        proto = make_protocol("stc", sparsity_up=1 / 20, sparsity_down=1 / 20)
        tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, env, proto,
                              TrainerConfig(lr=0.05, seed=0))
        # replay the ledger with the scalar API, mirroring EVERY draw the
        # trainer's rng makes (client selection AND per-client batch sampling)
        replay = np.random.default_rng(tr.tcfg.seed + 1)
        cache = UpdateCache(tr.numel, max_rounds=64)
        last_seen = np.zeros(env.n_clients, dtype=np.int64)
        expected_down = 0.0
        p = env.participants_per_round
        per_update = proto.download_bits(tr.numel, n_participating=p)
        model_bits = 32.0 * tr.numel
        need = proto.local_iters * env.batch_size
        for rnd in range(6):
            sel = replay.choice(env.n_clients, size=p, replace=False)
            for cid in sel:            # the _sample_batches draws
                pool = tr.splits[cid]
                replay.choice(pool, size=need, replace=len(pool) < need)
            for cid in sel:            # the old per-client ledger loop
                expected_down += cache.sync_bits(
                    int(rnd - last_seen[cid]), per_update, model_bits)
                last_seen[cid] = rnd
            cache.push(np.zeros(tr.numel, np.float32))
            tr.run_round()
        # the analytic column preserves the pre-wire ledger semantics exactly
        # (tr.bits_down itself is now MEASURED for stc -- see test_wire.py)
        assert tr.bits_down_analytic == pytest.approx(expected_down)
        assert tr.bits_down > 0
