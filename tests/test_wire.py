"""Wire-format subsystem: packed codec vs per-bit oracle (bit-identical),
adversarial round-trips, batched-vs-per-client equivalence, the Pallas
word-packing kernel, the UpdateCache prefix cache, and the measured-bits
ledger cross-check (measured <= Eq. 13/15-style bound) in a real fed run."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal deterministic fallback (see the stub)
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import UpdateCache, golomb, make_protocol, wire


def _random_ternary(n, p, seed):
    rng = np.random.default_rng(seed)
    x = np.zeros(n, np.float32)
    k = max(int(n * p), 1)
    idx = rng.choice(n, size=k, replace=False)
    mu = abs(float(rng.standard_normal())) + 0.1
    x[idx] = mu * rng.choice([-1.0, 1.0], size=k)
    return x


def _assert_stream_identical(msg: wire.WireMessage, x, p):
    """The packed stream must equal the per-bit oracle's, bit for bit."""
    payload, bit_len, mu, _ = golomb.encode_ternary(x, p)
    assert msg.bit_len == bit_len
    np.testing.assert_array_equal(msg.payload_bytes(), payload)
    if msg.nnz:
        assert msg.mu == pytest.approx(mu, rel=1e-6)


class TestPackedVsOracle:
    @given(st.integers(1, 3000), st.floats(0.005, 0.25),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_bit_identical_and_roundtrip(self, n, p, seed):
        x = _random_ternary(n, p, seed)
        msg = wire.encode_ternary_words(x, p)
        _assert_stream_identical(msg, x, p)
        np.testing.assert_allclose(wire.decode_ternary_words(msg, p), x,
                                   atol=1e-6)

    def test_mismatched_b_star(self):
        """Encoding with a p far from the realized sparsity exercises long
        unary runs (multi-chunk codewords) and the b*=0 edge."""
        for p_data, p_wire in [(0.001, 0.9), (0.3, 0.002), (0.05, 0.9),
                               (0.9, 0.005)]:
            x = _random_ternary(4096, p_data, seed=7)
            msg = wire.encode_ternary_words(x, p_wire)
            _assert_stream_identical(msg, x, p_wire)
            np.testing.assert_allclose(wire.decode_ternary_words(msg, p_wire),
                                       x, atol=1e-6)

    def test_empty_and_all_zero(self):
        for n in (0, 1, 100):
            msg = wire.encode_ternary_words(np.zeros(n, np.float32), 0.01)
            assert msg.bit_len == 0 and msg.words.size == 0 and msg.nnz == 0
            np.testing.assert_array_equal(wire.decode_ternary_words(msg, 0.01),
                                          np.zeros(n, np.float32))

    def test_mu_zero_stream(self):
        """µ=0 decodes every coded position to 0 without corrupting state."""
        x = _random_ternary(500, 0.02, seed=3)
        msg = wire.encode_ternary_words(x, 0.02)
        zeroed = wire.WireMessage(msg.words, msg.bit_len, 0.0, msg.numel,
                                  msg.nnz)
        out = wire.decode_ternary_words(zeroed, 0.02)
        np.testing.assert_array_equal(out, np.zeros_like(x))

    def test_odd_tail_lengths(self):
        """bit_len deliberately not a multiple of 8/32: trailing wire bits
        must be zero padding and survive the byte/word round-trip."""
        for n in (33, 63, 65, 129):
            x = np.zeros(n, np.float32)
            x[n - 1] = 0.5           # one maximal gap -> odd stream length
            msg = wire.encode_ternary_words(x, 0.05)
            assert msg.bit_len % 32 != 0
            _assert_stream_identical(msg, x, 0.05)
            np.testing.assert_allclose(wire.decode_ternary_words(msg, 0.05),
                                       x, atol=1e-6)

    def test_single_element_tensor(self):
        x = np.asarray([-0.25], np.float32)
        msg = wire.encode_ternary_words(x, 0.5)
        _assert_stream_identical(msg, x, 0.5)
        np.testing.assert_allclose(wire.decode_ternary_words(msg, 0.5), x)

    def test_b_star_overflow_is_loud(self):
        with pytest.raises(ValueError, match="b\\*"):
            wire.encode_ternary_words(np.zeros(8, np.float32), 1e-12)

    @pytest.mark.slow
    def test_oracle_roundtrip_large(self):
        """Per-bit oracle at n=2^20 (slow lane: the per-bit loop is the
        thing the vectorized packer replaces)."""
        n, p = 1 << 20, 1 / 400
        x = _random_ternary(n, p, seed=0)
        payload, bit_len, mu, n_out = golomb.encode_ternary(x, p)
        dec = golomb.decode_ternary(payload, bit_len, mu, n_out, p)
        np.testing.assert_allclose(dec, x, atol=1e-6)
        msg = wire.encode_ternary_words(x, p)
        assert msg.bit_len == bit_len
        np.testing.assert_array_equal(msg.payload_bytes(), payload)


class TestBatched:
    @given(st.integers(1, 6), st.integers(1, 400), st.floats(0.01, 0.3),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_batch_equals_per_client(self, P, n, p, seed):
        X = np.stack([_random_ternary(n, p, seed + i) for i in range(P)])
        if P > 1:
            X[seed % P] = 0.0        # always exercise an empty client
        batch = wire.encode_ternary_words_batch(X, p)
        assert batch.n_msgs == P
        for i in range(P):
            single = wire.encode_ternary_words(X[i], p)
            m = batch.message(i)
            assert m.bit_len == single.bit_len
            np.testing.assert_array_equal(
                wire.words_to_bits(m.words, m.bit_len),
                wire.words_to_bits(single.words, single.bit_len))
            assert m.mu == pytest.approx(single.mu, rel=1e-6, abs=1e-12)
            assert m.nnz == single.nnz
        np.testing.assert_allclose(
            wire.decode_ternary_words_batch(batch, p), X, atol=1e-6)

    def test_dense_regime_fallback_identical(self):
        """Above the fused-nnz crossover the batch falls back to per-client
        packs; the resulting WireBatch must be indistinguishable."""
        P, n, p = 4, 40_000, 0.25    # 40k nnz total > _FUSED_NNZ_MAX
        X = np.stack([_random_ternary(n, p, i) for i in range(P)])
        assert int(np.count_nonzero(X)) > wire._FUSED_NNZ_MAX
        batch = wire.encode_ternary_words_batch(X, p)
        for i in range(P):
            _assert_stream_identical(batch.message(i), X[i], p)

    def test_all_clients_empty(self):
        batch = wire.encode_ternary_words_batch(np.zeros((3, 50), np.float32),
                                                0.1)
        assert batch.words.size == 0
        assert all(batch.message(i).bit_len == 0 for i in range(3))


class TestBackends:
    def test_kernel_backend_bit_identical(self):
        for n, p in [(257, 0.03), (1000, 0.01), (64, 0.9)]:
            x = _random_ternary(n, p, seed=5)
            a = wire.encode_ternary_words(x, p, backend="numpy")
            b = wire.encode_ternary_words(x, p, backend="kernel")
            assert a.bit_len == b.bit_len
            np.testing.assert_array_equal(a.words, b.words)
        X = np.stack([_random_ternary(500, 0.02, i) for i in range(4)])
        ba = wire.encode_ternary_words_batch(X, 0.02, backend="numpy")
        bb = wire.encode_ternary_words_batch(X, 0.02, backend="kernel")
        np.testing.assert_array_equal(ba.words, bb.words)
        np.testing.assert_array_equal(ba.bit_len, bb.bit_len)

    def test_pack_bits_kernel_vs_ref(self):
        import jax.numpy as jnp
        from repro.kernels import (pack_bits_ref, pack_bits_words,
                                   pack_bits_words_batched)
        rng = np.random.default_rng(0)
        for m in (1, 31, 32, 33, 127, 128, 4097, 65536):
            bits = rng.integers(0, 2, m).astype(np.uint8)
            ref = np.asarray(pack_bits_ref(jnp.asarray(bits)))
            ker = np.asarray(pack_bits_words(jnp.asarray(bits)))
            np.testing.assert_array_equal(ker, ref)
            np.testing.assert_array_equal(
                wire.get_wire_backend("numpy").pack_bits(bits), ref)
        B = 5
        bb = rng.integers(0, 2, (B, 777)).astype(np.uint8)
        out = np.asarray(pack_bits_words_batched(jnp.asarray(bb)))
        for i in range(B):
            np.testing.assert_array_equal(
                out[i], np.asarray(pack_bits_ref(jnp.asarray(bb[i]))))

    def test_unknown_backend_is_loud(self):
        with pytest.raises(ValueError, match="unknown wire backend"):
            wire.get_wire_backend("nope")


class TestSignWire:
    def test_roundtrip_and_exact_size(self):
        rng = np.random.default_rng(0)
        for n in (1, 31, 777):
            x = rng.standard_normal(n).astype(np.float32)
            msg = wire.pack_sign_words(x, 2e-4)
            assert msg.bit_len == n     # exactly 1 bit per coordinate
            back = wire.unpack_sign_words(msg)
            np.testing.assert_allclose(
                back, np.where(x > 0, 2e-4, -2e-4).astype(np.float32))

    def test_codec_measured_equals_analytic(self):
        proto = make_protocol("signsgd")
        msgs = np.sign(np.random.default_rng(1).standard_normal((3, 500))
                       ).astype(np.float32) * proto.sign_step
        assert proto.measured_upload_bits(msgs) == 3 * proto.upload_bits(500)
        assert proto.measured_download_bits(msgs[0]) == proto.download_bits(500)


class TestCodecWireAPI:
    def test_stc_measured_below_bound(self):
        proto = make_protocol("stc", sparsity_up=0.02, sparsity_down=0.02)
        msgs = np.stack([_random_ternary(5000, 0.02, i) for i in range(4)])
        measured = proto.measured_upload_bits(msgs)
        bound = sum(proto.wire_bound_bits(5000, int(np.count_nonzero(m)),
                                          "up") for m in msgs)
        assert 0 < measured <= bound
        gd = _random_ternary(5000, 0.02, 99)
        assert (proto.measured_download_bits(gd)
                <= proto.wire_bound_bits(5000, int(np.count_nonzero(gd)),
                                         "down"))

    def test_wireless_codec_falls_back_to_analytic(self):
        proto = make_protocol("fedavg")
        msgs = np.ones((2, 100), np.float32)
        assert not proto.wire_format
        assert proto.measured_upload_bits(msgs) == 2 * proto.upload_bits(100)
        assert proto.measured_download_bits(msgs[0]) == proto.download_bits(100)

    def test_generic_batch_fallback(self):
        """Codec.encode_wire_batch default (concat of singles) matches the
        per-message streams -- third-party wire codecs get batching free."""
        proto = make_protocol("signsgd")
        msgs = np.sign(np.random.default_rng(2).standard_normal((3, 100))
                       ).astype(np.float32)
        batch = proto.encode_wire_batch(msgs)
        for i in range(3):
            single = proto.encode_wire(msgs[i])
            m = batch.message(i)
            assert m.bit_len == single.bit_len
            np.testing.assert_array_equal(m.words, single.words)


class TestUpdateCachePrefix:
    def test_partial_sum_matches_loop(self):
        rng = np.random.default_rng(0)
        cache = UpdateCache(numel=64, max_rounds=8)
        ups = [rng.standard_normal(64).astype(np.float32) for _ in range(11)]
        for u in ups:
            cache.push(u)
        kept = list(cache._updates)          # newest first, len 8
        for s in range(0, 9):
            got = cache.partial_sum(s)
            want = np.zeros(64, np.float32)
            for t in range(s):
                want += kept[t]
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
        assert cache.partial_sum(9) is None   # staler than the ring buffer

    def test_prefix_cache_invalidated_on_push(self):
        cache = UpdateCache(numel=4, max_rounds=4)
        cache.push(np.ones(4))
        first = cache.partial_sum(1)
        cache.push(2 * np.ones(4))
        np.testing.assert_array_equal(cache.partial_sum(1), 2 * np.ones(4))
        np.testing.assert_array_equal(cache.partial_sum(2), 3 * np.ones(4))
        np.testing.assert_array_equal(first, np.ones(4))  # copy, not a view

    def test_lazy_depth_growth_out_of_order(self):
        """The prefix cache grows to the deepest staleness queried, in any
        query order, without recomputing shallow rows."""
        rng = np.random.default_rng(1)
        cache = UpdateCache(numel=16, max_rounds=8)
        ups = [rng.standard_normal(16).astype(np.float32) for _ in range(6)]
        for u in ups:
            cache.push(u)
        kept = list(cache._updates)
        for s in (3, 1, 5, 2, 6):
            want = np.sum(kept[:s], axis=0, dtype=np.float32)
            np.testing.assert_allclose(cache.partial_sum(s), want,
                                       rtol=1e-5, atol=1e-5)
        assert cache._cum.shape[0] == 6   # grown to the max depth, not 8

    def test_partial_sum_returns_copy(self):
        cache = UpdateCache(numel=4, max_rounds=4)
        cache.push(np.ones(4))
        out = cache.partial_sum(1)
        out += 100.0
        np.testing.assert_array_equal(cache.partial_sum(1), np.ones(4))


class TestMeasuredLedgerIntegration:
    def test_fed_run_measured_within_bounds(self):
        """Full fed/loop.py STC run: measured upload/download bits per round
        satisfy measured <= the deterministic Eq. 13 / Eq. 15-style analytic
        bound, and stay within sanity range of the Eq. 17 expectation."""
        from repro.data import make_classification
        from repro.fed import FedEnvironment, FederatedTrainer, TrainerConfig
        from repro.models.paper_models import MODEL_ZOO

        train, test = make_classification(seed=0, n=1500, n_test=300)
        env = FedEnvironment(n_clients=8, participation=0.5,
                             classes_per_client=2, batch_size=10)
        proto = make_protocol("stc", sparsity_up=1 / 20, sparsity_down=1 / 20)
        tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, env, proto,
                              TrainerConfig(lr=0.05))
        assert tr.measure_bits           # auto-on: stc has a wire format
        tr.run(6, eval_every=3)

        assert len(tr.wire_log) == 6
        for row in tr.wire_log:
            assert 0 < row["bits_up"] <= row["bits_up_bound"]
            assert (0 < row["bits_down_per_update"]
                    <= row["bits_down_per_update_bound"])
        # totals: measured tracks the analytic expectation (loose sanity)
        assert tr.bits_up == pytest.approx(tr.bits_up_analytic, rel=0.5)
        assert tr.bits_down > 0 and tr.bits_down_analytic > 0
        h = tr.history[-1]
        assert h["measured"] and h["bits_up"] == tr.bits_up

    def test_measure_bits_off_reproduces_analytic_ledger(self):
        from repro.data import make_classification
        from repro.fed import FedEnvironment, FederatedTrainer, TrainerConfig
        from repro.models.paper_models import MODEL_ZOO

        train, test = make_classification(seed=0, n=800, n_test=200)
        env = FedEnvironment(n_clients=4, participation=0.5,
                             classes_per_client=2, batch_size=10)
        proto = make_protocol("stc", sparsity_up=1 / 20, sparsity_down=1 / 20)
        tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, env, proto,
                              TrainerConfig(lr=0.05, measure_bits=False))
        tr.run(3, eval_every=3)
        assert tr.wire_log == []
        assert tr.bits_up == tr.bits_up_analytic
        assert tr.bits_down == tr.bits_down_analytic

    def test_mesh_trainer_wire_ledger(self):
        """launch/train.py: measure_wire threads (msgs, global_delta) out of
        the step and the WireLedger accounts measured bits (no-mesh path)."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.data import make_lm_tokens
        from repro.launch.train import (TrainConfig, WireLedger, codec_for,
                                        init_train_state, make_train_step)

        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("model",))
        cfg = get_smoke_config("smollm-135m")
        tc = TrainConfig(protocol="stc", lr=0.05, sparsity_up=1 / 50,
                         sparsity_down=1 / 50, measure_wire=True)
        state = init_train_state(cfg, tc, n_clients=1,
                                 key=jax.random.PRNGKey(0))
        toks = make_lm_tokens(n_tokens=2 * 128 + 1, vocab=cfg.vocab_size)
        batch = {"tokens": jnp.asarray(toks[:-1].reshape(2, 128)),
                 "labels": jnp.asarray(toks[1:].reshape(2, 128))}
        step = make_train_step(cfg, mesh, tc)
        ledger = WireLedger(codec_for(tc), cfg.param_count())
        for _ in range(2):
            state, metrics, (msgs, gd) = step(state, batch)
            ledger.record_round(msgs, gd)
        s = ledger.summary()
        assert s["rounds"] == 2
        assert 0 < s["bits_up"] < s["bits_up_analytic"] * 1.5
        assert 0 < s["bits_down"] < s["bits_down_analytic"] * 1.5
