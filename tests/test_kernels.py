"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes, plus equivalence with the core operators."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import stc_compress
from repro.kernels import (stc_apply, stc_compress_kernel, stc_compress_ref,
                           threshold_stats, topk_threshold)
from repro.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")

SHAPES = [64, 1000, 4096, 8192, 65536, 100_003]   # incl. non-aligned sizes
DTYPES = [jnp.float32, jnp.bfloat16]
SELECTORS = ["hist", "bisect"]


def _rand(n, seed=0, dtype=jnp.float32):
    x = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
    return jnp.asarray(x, dtype)


class TestThresholdStats:
    @pytest.mark.parametrize("n", SHAPES)
    def test_vs_ref(self, n):
        x = _rand(n, n)
        t = jnp.float32(0.8)
        cnt_k, sum_k = threshold_stats(x, t, block_rows=64)
        cnt_r, sum_r = kref.threshold_stats_ref(x, t)
        assert int(cnt_k) == int(cnt_r)
        np.testing.assert_allclose(float(sum_k), float(sum_r), rtol=1e-5)

    def test_padding_not_counted(self):
        """Zero padding must not inflate the count at threshold 0."""
        x = jnp.abs(_rand(100, 3)) + 1.0       # all entries >= 1
        cnt, _ = threshold_stats(x, jnp.float32(0.0), block_rows=8)
        assert int(cnt) == 100                  # not 8*128-padded count


class TestTopkThreshold:
    @pytest.mark.parametrize("n", SHAPES)
    @pytest.mark.parametrize("p", [0.001, 0.01, 0.1])
    def test_selects_k(self, n, p):
        x = _rand(n, seed=n + int(p * 1e4))
        k = max(int(n * p), 1)
        t, cnt, s = topk_threshold(x, k, block_rows=64)
        assert int(cnt) == k                    # continuous data: exact
        # threshold matches the kth magnitude from a sort
        kth = np.sort(np.abs(np.asarray(x)))[-k]
        assert float(t) <= kth + 1e-6
        assert int(np.sum(np.abs(np.asarray(x)) >= float(t))) == k


class TestFusedSTC:
    @pytest.mark.parametrize("n", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_kernel_vs_ref(self, n, dtype):
        d = _rand(n, 1, dtype)
        r = _rand(n, 2) * 0.1
        tk, rk, muk, thk, ck = stc_compress_kernel(
            d.astype(jnp.float32), r, 0.01, block_rows=64)
        tr, rr, mur, thr, cr = stc_compress_ref(d.astype(jnp.float32), r, 0.01)
        np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), atol=1e-6)
        np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), atol=1e-6)
        assert int(ck) == int(cr)

    @pytest.mark.parametrize("n", [1000, 8192])
    @pytest.mark.parametrize("selector", SELECTORS)
    def test_kernel_vs_core_operator(self, n, selector):
        """Kernel path == core.stc_compress on carried = delta + residual."""
        d = _rand(n, 3)
        r = _rand(n, 4) * 0.05
        tk, rk, muk, _, ck = stc_compress_kernel(d, r, 0.02, block_rows=64,
                                                 selector=selector)
        tc, stats = stc_compress(d + r, 0.02)
        np.testing.assert_allclose(np.asarray(tk), np.asarray(tc), atol=1e-5)
        assert int(ck) == int(stats.nnz)
        # error feedback exactness
        np.testing.assert_allclose(np.asarray(tk + rk), np.asarray(d + r),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("selector", SELECTORS)
    def test_block_shape_sweep(self, selector):
        """Result must be independent of the BlockSpec tiling."""
        d, r = _rand(10_000, 5), _rand(10_000, 6) * 0.1
        outs = []
        for br in (8, 64, 256, 512):
            t, _, _, _, _ = stc_compress_kernel(d, r, 0.01, block_rows=br,
                                                selector=selector)
            outs.append(np.asarray(t))
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], atol=1e-6)

    def test_fused_apply_direct(self):
        """stc_apply reads the carried vector once (no delta/residual pair)."""
        d, r = _rand(4096, 7), _rand(4096, 8) * 0.1
        carried = d + r
        t = jnp.float32(1.5)
        mu = jnp.float32(2.0)
        tern, res = stc_apply(carried, t, mu, block_rows=32)
        tern_r, res_r = kref.stc_apply_ref(carried, t, mu)
        np.testing.assert_allclose(np.asarray(tern), np.asarray(tern_r),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(res), np.asarray(res_r),
                                   atol=1e-6)
        # against the legacy (delta, residual) oracle form as well
        tern_l, res_l = kref.stc_fused_ref(d, r, t, mu)
        np.testing.assert_allclose(np.asarray(tern), np.asarray(tern_l),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(res), np.asarray(res_l),
                                   atol=1e-6)
