"""Fault-injection layer + server hardening (repro.fed.faults / events).

The contracts under test:

* every fault decision is a pure function of ``(fault seed, dseq)`` --
  same seed, same chaos, and a no-fault run is bit-identical to
  ``faults=None``;
* admission control rejects duplicates/replays by ``(client,
  dispatch_version)`` and quarantines corrupt payloads with a typed
  ``WireDecodeError``, billing their upstream bits but giving them ZERO
  aggregate weight (the honest-ledger rule);
* random byte-level mutation of a valid wire payload NEVER escapes the
  decoder as silent garbage or a non-``WireDecodeError`` exception, on
  the numpy AND kernel backends alike;
* a server kill + checkpoint restore resumes bit-identically to an
  uninterrupted run (params, measured/analytic ledgers, event +
  quarantine logs) for stc and signsgd;
* the optional ``norm_bound`` screen clips/rejects outliers identically
  on the jitted combine and the streaming ingest paths.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import make_protocol
from repro.core import wire
from repro.core.wire import WireDecodeError
from repro.data import make_classification
from repro.fed import (EventDrivenTrainer, EventLoop, FedEnvironment,
                       LatencyModel, ServerKilled, TrainerConfig, make_fault,
                       registered_faults, simulate_scenario)
from repro.fed.faults import BitFlipFault, CorruptPayload, DuplicateFault
from repro.fed.scenarios import (ComposedScenario, FlashCrowdScenario,
                                 RegionalOutageScenario, SteadyScenario,
                                 make_scenario)
from repro.models.paper_models import MODEL_ZOO


@pytest.fixture(scope="module")
def data():
    return make_classification(seed=0, n=900, n_test=240)


def _env(n_clients=8, participation=0.25):
    return FedEnvironment(n_clients=n_clients, participation=participation,
                          classes_per_client=2, batch_size=10)


def _trainer(data, protocol="stc", *, ingest=True, faults=None, **kw):
    train, test = data
    proto = (make_protocol("stc", sparsity_up=1 / 20, sparsity_down=1 / 20)
             if protocol == "stc" else make_protocol(protocol))
    return EventDrivenTrainer(
        MODEL_ZOO["logreg"], train, test, _env(), proto,
        TrainerConfig(seed=0, ingest=ingest), scenario="flash-outage",
        k_arrivals=2, concurrency=4, max_staleness=3, faults=faults, **kw)


# ---------------------------------------------------------------------------
# fault registry + per-class determinism
# ---------------------------------------------------------------------------


class TestFaultRegistry:
    def test_all_classes_registered(self):
        assert set(registered_faults()) >= {
            "none", "bit-flip", "truncate", "duplicate", "replay",
            "client-crash", "server-kill"}

    def test_unknown_fault_is_loud(self):
        with pytest.raises(KeyError, match="unknown fault"):
            make_fault("nope")

    def test_typed_validation(self):
        with pytest.raises(ValueError, match="prob"):
            make_fault("bit-flip", prob=1.5)
        with pytest.raises(ValueError, match="n_bits"):
            make_fault("bit-flip", n_bits=0)
        with pytest.raises(ValueError, match="at_event"):
            make_fault("server-kill", at_event=-1)

    def test_decisions_deterministic_in_seed_and_dseq(self):
        fm = make_fault("client-crash", prob=0.5, seed=9)
        a = [fm.crash(fm.rng(d)) for d in range(64)]
        b = [fm.crash(fm.rng(d)) for d in range(64)]
        assert a == b and any(a) and not all(a)
        # a different model seed gives a different failure pattern
        c = [make_fault("client-crash", prob=0.5, seed=10).crash(
            make_fault("client-crash", prob=0.5, seed=10).rng(d))
            for d in range(64)]
        assert a != c

    @pytest.mark.parametrize("fault", sorted(registered_faults()))
    def test_every_fault_simulates_deterministically(self, fault):
        """Model-free chaos: every scenario x fault combination replays
        exactly from the seeds, and event conservation holds with the
        injected (duplicate/replay) deliveries accounted."""
        kw = dict(n_clients=64, cohort=8, max_staleness=3, aggregations=6,
                  faults=fault, seed=3)
        s1 = simulate_scenario("flash-outage", **kw)
        s2 = simulate_scenario("flash-outage", **kw)
        assert s1 == s2
        assert (s1["arrived"] + s1["dropped"] + s1["lost"] + s1["duplicates"]
                + s1["quarantined"] + s1["pending"]
                == s1["dispatched"] + s1["injected"])

    def test_no_fault_is_bit_identical_to_none(self):
        kw = dict(n_clients=32, cohort=4, aggregations=4, seed=1)
        assert (simulate_scenario("steady", **kw)
                == simulate_scenario("steady", faults="none", **kw))

    def test_corrupt_hooks_cover_payload_types(self):
        fm = BitFlipFault(prob=1.0, seed=0)
        rng = fm.rng(0)
        msg = wire.encode_ternary_words(
            np.asarray([0, 1, 0, -1, 0, 0, 1, 0] * 8, np.float32), 1 / 8)
        assert not np.array_equal(np.asarray(fm.corrupt(msg, fm.rng(0)).words),
                                  np.asarray(msg.words))
        dense = fm.corrupt(np.zeros(32, np.float32), rng)
        assert not np.all(np.isfinite(dense))
        assert isinstance(fm.corrupt(object(), rng), CorruptPayload)


# ---------------------------------------------------------------------------
# composed scenarios (satellite: outage during a flash crowd)
# ---------------------------------------------------------------------------


class TestComposedScenario:
    def test_flash_outage_registered_and_composes_hooks(self):
        s = make_scenario("flash-outage")
        assert isinstance(s, ComposedScenario)
        fc, ro = FlashCrowdScenario(), RegionalOutageScenario()
        t = fc.start + 0.1            # inside the surge window
        assert s.latency_scale(t) == fc.latency_scale(t) * ro.latency_scale(t)
        ids = np.arange(16)
        pa = np.asarray(fc.loss_prob(t, ids))
        pb = np.asarray(ro.loss_prob(t, ids))
        np.testing.assert_allclose(np.asarray(s.loss_prob(t, ids)),
                                   1.0 - (1.0 - pa) * (1.0 - pb))

    def test_loss_union_not_product(self):
        """A one-sided outage must survive composition with a lossless
        scenario (a literal product would nullify it)."""
        s = ComposedScenario(a=SteadyScenario(),
                             b=RegionalOutageScenario(loss=0.9))
        ids = np.arange(8)
        lp = np.asarray(s.loss_prob(0.1, ids))     # inside the outage window
        assert lp.max() == pytest.approx(0.9)

    def test_typed_validation(self):
        with pytest.raises(TypeError, match="must be a Scenario"):
            ComposedScenario(a="steady", b=SteadyScenario())

    def test_deadline_elementwise_min(self):
        base = make_scenario("adaptive-deadline", factor=2.0)
        tight = make_scenario("adaptive-deadline", factor=1.0)
        comp = ComposedScenario(a=base, b=tight)
        ids, scales = np.arange(4), np.ones(4)
        np.testing.assert_allclose(
            comp.client_deadline(ids, scales),
            np.minimum(base.client_deadline(ids, scales),
                       tight.client_deadline(ids, scales)))
        none_side = ComposedScenario(a=SteadyScenario(), b=tight)
        np.testing.assert_allclose(none_side.client_deadline(ids, scales),
                                   tight.client_deadline(ids, scales))


# ---------------------------------------------------------------------------
# wire fuzz: corruption never escapes the typed error
# ---------------------------------------------------------------------------


class TestWireFuzz:
    @pytest.mark.parametrize("backend", ["numpy", "kernel"])
    def test_mutations_quarantine_or_decode_clean(self, backend):
        """Random word/field mutations of valid payloads either raise
        WireDecodeError or decode to a WELL-FORMED field set (sorted
        unique in-range positions, +/-1 signs, count == nnz) -- never
        silent garbage, never a different exception type."""
        rng = np.random.default_rng(0)
        p = 1 / 16
        escaped, caught = 0, 0
        for trial in range(60):
            n = int(rng.integers(64, 2048))
            x = np.zeros(n, np.float32)
            k = max(1, int(n * p))
            idx = rng.choice(n, size=k, replace=False)
            x[idx] = rng.choice([-1.0, 1.0], size=k)
            msg = wire.encode_ternary_words(x, p, backend=backend)
            words = np.asarray(msg.words).copy()
            mode = trial % 3
            if mode == 0 and words.size:          # flip random bits
                i = rng.integers(0, words.size, 4)
                words[i] ^= (np.uint32(1) << rng.integers(0, 32, 4)
                             .astype(np.uint32))
                bad = msg._replace(words=words)
            elif mode == 1 and words.size:        # truncate the buffer
                bad = msg._replace(words=words[: words.size // 2])
            else:                                 # corrupt the side info
                bad = msg._replace(nnz=int(msg.nnz) + int(rng.integers(1, 5)))
            try:
                pos, signs = wire.decode_ternary_fields(bad, p,
                                                        backend=backend)
            except WireDecodeError:
                caught += 1
                continue
            # survived decode: must be fully well-formed
            escaped += 1
            assert pos.size == int(bad.nnz)
            assert np.all((pos >= 0) & (pos < bad.numel))
            assert np.all(np.diff(pos) > 0)       # sorted, unique
            assert np.all(np.isin(signs, [-1.0, 1.0]))
        assert caught > 0          # the fuzzer does reach the typed error

    @pytest.mark.parametrize("backend", ["numpy", "kernel"])
    def test_corruption_classes_same_typed_error(self, backend):
        """Truncation, nnz overflow and dangling unary runs raise the SAME
        typed WireDecodeError on both decode backends."""
        x = np.zeros(512, np.float32)
        x[[3, 77, 301]] = 1.0
        msg = wire.encode_ternary_words(x, 1 / 64, backend=backend)
        words = np.asarray(msg.words)
        cases = [
            msg._replace(words=words[: words.size // 2]),     # truncated
            msg._replace(nnz=int(msg.nnz) + 3),               # nnz mismatch
            msg._replace(bit_len=int(msg.bit_len) + 64),      # dangling bits
        ]
        for bad in cases:
            with pytest.raises(WireDecodeError):
                wire.decode_ternary_fields(bad, 1 / 64, backend=backend)

    def test_sign_plane_validation(self):
        sp = make_protocol("signsgd")
        msg = sp.encode_wire(np.ones(100, np.float32))
        sp.validate_wire(msg)
        with pytest.raises(WireDecodeError, match="bit_len != numel"):
            sp.validate_wire(msg._replace(bit_len=64))


# ---------------------------------------------------------------------------
# admission control: duplicates, replays, quarantine accounting
# ---------------------------------------------------------------------------


class TestAdmissionControl:
    def test_duplicate_rejected_by_dispatch_version(self):
        """With certain duplication every key is admitted at most once."""
        loop = EventLoop(SteadyScenario(), 16, cohort=4, k_arrivals=4,
                         concurrency=16, max_staleness=8, seed=0,
                         faults=DuplicateFault(prob=1.0, seed=0))
        rng = np.random.default_rng(1)
        for _ in range(4):
            loop.dispatch(rng.choice(16, size=4, replace=False))
        admitted = set()
        while len(loop.clock):
            ev = loop.step()
            if ev.kind in ("arrival", "drop"):
                key = None
                for rec in [ev]:
                    key = (rec.client, rec.dseq)
                assert key not in admitted
                admitted.add(key)
        assert loop.n_injected == 16 and loop.n_duplicates == 16
        assert loop.n_arrived + loop.n_dropped == 16

    def test_replay_of_lost_original_is_not_duplicate(self):
        """A replayed copy of a LOST dispatch is that key's first delivery:
        it runs the normal staleness screen instead of dedup."""
        ids = np.arange(8)
        loop = EventLoop(SteadyScenario(), 8, cohort=8, k_arrivals=64,
                         concurrency=64, max_staleness=8, seed=0,
                         faults=make_fault("replay", prob=1.0))
        loop.dispatch(ids)                       # no previous dispatch yet
        loop.dispatch(ids)                       # replays copy dispatch #1
        while len(loop.clock):
            loop.step()
        # 16 dispatched + up to 8 injected replays, every one served
        assert loop.n_injected == 8
        assert (loop.n_arrived + loop.n_dropped + loop.n_lost
                + loop.n_duplicates == 24)
        assert loop.n_duplicates == 8            # originals all arrived first

    def test_quarantine_bills_bits_but_never_aggregates(self, data):
        """The honest-ledger rule end to end: every quarantined event bills
        positive upstream bits, the total ledger is exactly the per-event
        sum, and quarantined payloads never enter an aggregation."""
        tr = _trainer(data, faults=make_fault("truncate", prob=0.5))
        for _ in range(4):
            tr.run_round()
        quar = [r for r in tr.event_log if r["kind"] == "quarantine"]
        assert quar and tr.loop.n_quarantined == len(quar)
        assert all(r["bits_up"] > 0 for r in quar)
        assert len(tr.loop.quarantine_log) == len(quar)
        assert all("corrupt" in q["reason"] or "truncated" in q["reason"]
                   for q in tr.loop.quarantine_log)
        # ledger == sum of per-event bills (arrival + drop + quarantine +
        # duplicate rows; lost rows bill 0)
        billed = sum(r["bits_up"] for r in tr.event_log
                     if r["kind"] != "dispatch")
        assert tr.bits_up == pytest.approx(billed)
        # aggregations consumed only admitted arrivals
        assert sum(a["aggregated"] for a in tr.agg_log) == tr.loop.n_arrived

    @pytest.mark.parametrize("fault", sorted(registered_faults()))
    def test_trainer_survives_every_fault_class(self, data, fault):
        fm = (make_fault(fault) if fault != "server-kill"
              else make_fault(fault, at_event=10 ** 9))
        tr = _trainer(data, faults=fm)
        for _ in range(3):
            tr.run_round()
        assert tr.round == 3
        assert np.all(np.isfinite(np.asarray(tr.params_vec)))

    def test_dense_mode_quarantines_without_stack_crash(self, data):
        """Dense (non-ingest) payload path: truncated/NaN payloads must
        quarantine via the size/finiteness screen, never reach np.stack."""
        tr = _trainer(data, ingest=False,
                      faults=make_fault("bit-flip", prob=0.7))
        for _ in range(3):
            tr.run_round()
        assert tr.loop.n_quarantined > 0
        assert np.all(np.isfinite(np.asarray(tr.params_vec)))


# ---------------------------------------------------------------------------
# kill + crash-consistent resume
# ---------------------------------------------------------------------------


class TestKillAndResume:
    @pytest.mark.parametrize("protocol", ["stc", "signsgd"])
    def test_kill_and_resume_bit_identical(self, data, protocol, tmp_path):
        ck = str(tmp_path / f"{protocol}.ck")
        ref = _trainer(data, protocol, faults="none")
        for _ in range(4):
            ref.run_round()

        killed = _trainer(data, protocol,
                          faults=make_fault("server-kill", at_event=9),
                          ckpt_path=ck, ckpt_every=2)
        with pytest.raises(ServerKilled, match="at_event=9"):
            while killed.round < 4:
                killed.run_round()

        resumed = _trainer(data, protocol, faults="none")
        resumed.restore_checkpoint(ck)
        assert resumed.n_events_served in (8, 9)   # a pre-kill boundary
        while resumed.round < 4:
            resumed.run_round()

        np.testing.assert_array_equal(np.asarray(ref.params_vec),
                                      np.asarray(resumed.params_vec))
        assert (ref.bits_up, ref.bits_down, ref.bits_up_analytic,
                ref.bits_down_analytic) == (
            resumed.bits_up, resumed.bits_down, resumed.bits_up_analytic,
            resumed.bits_down_analytic)
        assert ref.event_log == resumed.event_log
        assert ref.agg_log == resumed.agg_log
        assert ref.wire_log == resumed.wire_log
        assert ref.loop.quarantine_log == resumed.loop.quarantine_log
        assert ref.loop.stats() == resumed.loop.stats()

    def test_checkpoint_roundtrip_mid_chaos(self, data, tmp_path):
        """Checkpoint/restore under an ACTIVE corruption fault preserves the
        quarantine log and admission state exactly."""
        ck = str(tmp_path / "chaos.ck")
        fm = make_fault("truncate", prob=0.5)
        a = _trainer(data, faults=fm)
        for _ in range(2):
            a.run_round()
        a.save_checkpoint(ck)
        for _ in range(2):
            a.run_round()

        b = _trainer(data, faults=fm)
        b.restore_checkpoint(ck)
        for _ in range(2):
            b.run_round()
        np.testing.assert_array_equal(np.asarray(a.params_vec),
                                      np.asarray(b.params_vec))
        assert a.loop.quarantine_log == b.loop.quarantine_log
        assert a.loop.stats() == b.loop.stats()
        assert a.event_log == b.event_log


# ---------------------------------------------------------------------------
# norm-bound screening (Codec.aggregate / ingest hardening hook)
# ---------------------------------------------------------------------------


class TestNormScreening:
    def test_policy_validation(self):
        with pytest.raises(ValueError, match="norm_policy"):
            make_protocol("stc", norm_bound=1.0, norm_policy="zap")
        with pytest.raises(ValueError, match="norm_bound"):
            make_protocol("stc", norm_bound=-1.0)

    def test_clip_and_reject_match_combine_oracle(self):
        """The streaming ingest screen must agree with the jitted combine
        screen (same clip scales, same rejections)."""
        rng = np.random.default_rng(0)
        numel = 256
        msgs = np.zeros((4, numel), np.float32)
        for i, scale in enumerate([0.1, 0.5, 2.0, 8.0]):
            k = 16
            idx = rng.choice(numel, size=k, replace=False)
            msgs[i, idx] = scale * rng.choice([-1.0, 1.0], size=k)
        bound = float(np.linalg.norm(msgs[1]) * 1.01)   # rows 2,3 exceed it
        for policy in ("clip", "reject"):
            proto = make_protocol("ternquant", norm_bound=bound,
                                  norm_policy=policy)
            combined = np.asarray(proto.combine(
                np.asarray(msgs), mask=np.ones(4, np.float32),
                staleness=np.zeros(4, np.float32)))
            acc = proto.make_ingest(numel)
            for row in msgs:
                proto.ingest_dense(acc, row, 1.0)
            np.testing.assert_allclose(np.asarray(acc.combined()), combined,
                                       atol=1e-6)
            if policy == "reject":
                assert acc.n_screened == 2

    def test_wire_norm_screen_rejects_outlier_stc(self):
        """An stc message whose mu*sqrt(nnz) norm exceeds the bound is
        rejected on the wire ingest path: bits billed, zero weight."""
        numel, k = 512, 8
        proto = make_protocol("stc", sparsity_up=k / numel,
                              norm_bound=0.5, norm_policy="reject")
        small = np.zeros(numel, np.float32)
        small[:k] = 0.05 * np.asarray([1, -1] * (k // 2))
        big = np.zeros(numel, np.float32)
        big[:k] = 9.0 * np.asarray([1, -1] * (k // 2))
        acc = proto.make_ingest(numel)
        proto.ingest_wire(acc, proto.encode_wire(small), 1.0)
        proto.ingest_wire(acc, proto.encode_wire(big), 1.0)
        assert acc.n_screened == 1
        assert acc.weight_mass == pytest.approx(1.0)     # big carries 0
        assert acc.stream_bits > 0                        # both billed
        out = np.asarray(acc.combined())
        assert np.abs(out).max() == pytest.approx(0.05, rel=1e-5)

    def test_screen_off_is_bitwise_inert(self):
        """norm_bound=None keeps the fast combine path bit-identical."""
        rng = np.random.default_rng(3)
        msgs = rng.standard_normal((5, 64)).astype(np.float32)
        base = make_protocol("stc")
        assert base.norm_bound is None
        import jax.numpy as jnp
        np.testing.assert_array_equal(
            np.asarray(base.combine(jnp.asarray(msgs))),
            np.asarray(jnp.mean(jnp.asarray(msgs), axis=0)))


# ---------------------------------------------------------------------------
# stats guards (satellite: zero-division hardening)
# ---------------------------------------------------------------------------


class TestStatsGuards:
    def test_zero_arrival_stats_are_finite(self):
        loop = EventLoop(SteadyScenario(), 8, cohort=2, k_arrivals=2,
                         concurrency=4, max_staleness=1, seed=0)
        st = loop.stats()
        assert st["mean_staleness"] == 0.0
        assert st["drop_rate"] == 0.0
        assert st["quarantine_rate"] == 0.0
        assert st["duplicate_rate"] == 0.0
        assert st["aggs_per_time"] == 0.0
        assert all(np.isfinite(v) for v in st.values()
                   if isinstance(v, (int, float)))
