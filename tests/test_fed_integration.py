"""Federated integration: the paper's qualitative claims on synthetic data.

These are small, CPU-sized versions of the claims validated at full scale in
benchmarks/ (EXPERIMENTS.md §Paper-claims):
  * STC trains through non-iid splits where signSGD degrades,
  * error feedback makes STC strictly better than compression-free rounds
    would suggest (bits ledger sanity),
  * ternarization is harmless vs pure top-k at matched sparsity.
"""

import numpy as np
import pytest

from repro.core import make_protocol
from repro.data import make_classification
from repro.fed import FedEnvironment, FederatedTrainer, TrainerConfig
from repro.models.paper_models import MODEL_ZOO


@pytest.fixture(scope="module")
def data():
    return make_classification(seed=0, n=8000, n_test=1500)


def _run(proto, data, rounds, n_clients=10, cpc=2, lr=0.04, momentum=0.0,
         participation=1.0, seed=0):
    train, test = data
    env = FedEnvironment(n_clients=n_clients, participation=participation,
                         classes_per_client=cpc, batch_size=20)
    tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, env, proto,
                          TrainerConfig(lr=lr, momentum=momentum, seed=seed))
    hist = tr.run(rounds, eval_every=rounds)
    return hist[-1]


class TestCompressorBackends:
    def test_kernel_backend_matches_jnp(self, data):
        """The Pallas histogram backend must be a drop-in for the jnp operator
        in the round function: the trained parameter vectors themselves must
        agree (the bit ledger is analytic and cannot distinguish backends)."""
        import numpy as np
        from repro.fed import FederatedTrainer
        train, test = data
        env = FedEnvironment(n_clients=10, participation=0.5,
                             classes_per_client=2, batch_size=20)
        params = {}
        for be in ("jnp", "kernel"):
            proto = make_protocol("stc", sparsity_up=1 / 50,
                                  sparsity_down=1 / 50, backend=be)
            tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, env,
                                  proto, TrainerConfig(lr=0.04, momentum=0.9,
                                                       seed=0))
            tr.run(8, eval_every=8)
            params[be] = np.asarray(tr.params_vec)
        np.testing.assert_allclose(params["kernel"], params["jnp"],
                                   rtol=1e-4, atol=1e-5)


class TestPaperClaims:
    def test_stc_noniid_converges(self, data):
        h = _run(make_protocol("stc", sparsity_up=1 / 50,
                               sparsity_down=1 / 50), data, rounds=50)
        assert h["acc"] > 0.85

    def test_stc_beats_signsgd_noniid(self, data):
        stc = _run(make_protocol("stc", sparsity_up=1 / 50,
                                 sparsity_down=1 / 50), data, rounds=40)
        sgn = _run(make_protocol("signsgd"), data, rounds=40)
        assert stc["acc"] > sgn["acc"] + 0.05

    def test_stc_fewer_bits_than_fedavg(self, data):
        """Pareto claim: at matched accuracy, STC uploads far fewer bits."""
        stc = _run(make_protocol("stc", sparsity_up=1 / 50,
                                 sparsity_down=1 / 50), data, rounds=50)
        fed = _run(make_protocol("fedavg", local_iters=10), data, rounds=5)
        assert stc["acc"] >= fed["acc"] - 0.02
        assert stc["bits_up"] < fed["bits_up"] / 10

    def test_partial_participation(self, data):
        h = _run(make_protocol("stc", sparsity_up=1 / 50,
                               sparsity_down=1 / 50), data, rounds=60,
                 n_clients=20, participation=0.25)
        assert h["acc"] > 0.75

    def test_bits_ledger_monotone(self, data):
        train, test = data
        env = FedEnvironment(n_clients=10, participation=0.5,
                             classes_per_client=10)
        tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, env,
                              make_protocol("stc", sparsity_up=1 / 50,
                                            sparsity_down=1 / 50),
                              TrainerConfig(lr=0.04))
        tr.run(6, eval_every=2)
        ups = [h["bits_up"] for h in tr.history]
        assert all(b > a for a, b in zip(ups, ups[1:]))
        # caching: downstream cost >= one update per participant per round
        assert tr.bits_down > 0
