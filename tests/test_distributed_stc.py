"""Tree-level STC (core.distributed) vs the flat oracle, + environment split."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import stc_compress, flatten_pytree
from repro.core.distributed import stc_compress_tree, tree_numel
from repro.fed.environment import FedEnvironment, split_data, volume_fractions

jax.config.update("jax_platform_name", "cpu")


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.standard_normal((37, 13)), jnp.float32),
        "b": [jnp.asarray(rng.standard_normal(211), jnp.float32),
              jnp.asarray(rng.standard_normal((5, 7, 11)), jnp.float32)],
    }


class TestTreeSTC:
    @pytest.mark.parametrize("p", [0.005, 0.02, 0.1])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_matches_flat_oracle(self, p, seed):
        tree = _tree(seed)
        tern_tree, stats = stc_compress_tree(tree, p)
        vec, spec = flatten_pytree(tree)
        tern_flat, fstats = stc_compress(vec, p)
        got, _ = flatten_pytree(tern_tree)
        np.testing.assert_allclose(np.asarray(got), np.asarray(tern_flat),
                                   atol=2e-5)
        assert int(stats.nnz) == int(fstats.nnz)
        np.testing.assert_allclose(float(stats.mu), float(fstats.mu),
                                   rtol=1e-4)

    def test_numel(self):
        tree = _tree()
        assert tree_numel(tree) == 37 * 13 + 211 + 5 * 7 * 11

    def test_global_competition(self):
        """Leaves with tiny values must lose to leaves with big values."""
        tree = {"small": jnp.full((100,), 1e-4),
                "big": jnp.linspace(1.0, 2.0, 100)}
        tern, stats = stc_compress_tree(tree, 0.1)  # k = 20
        assert float(jnp.sum(jnp.abs(tern["small"]))) == 0.0
        assert int(jnp.sum(tern["big"] != 0)) >= 20


class TestEnvironment:
    def test_volume_fractions_sum(self):
        phi = volume_fractions(50, 0.9)
        assert phi.sum() == pytest.approx(1.0)
        assert phi.min() > 0

    def test_split_classes_per_client(self):
        labels = np.repeat(np.arange(10), 500)
        env = FedEnvironment(n_clients=20, classes_per_client=2)
        splits = split_data(labels, env, seed=0)
        for s in splits:
            assert len(set(labels[s])) <= 2
            assert len(s) > 0

    def test_split_disjoint(self):
        labels = np.repeat(np.arange(10), 300)
        env = FedEnvironment(n_clients=10, classes_per_client=5)
        splits = split_data(labels, env, seed=1)
        all_idx = np.concatenate(splits)
        assert len(all_idx) == len(set(all_idx))  # non-overlapping

    def test_unbalanced_split_sizes(self):
        labels = np.repeat(np.arange(10), 1000)
        env = FedEnvironment(n_clients=20, classes_per_client=10,
                             balancedness=0.9)
        splits = split_data(labels, env, seed=2)
        sizes = np.array([len(s) for s in splits])
        assert sizes[0] > sizes[-1]  # γ<1 concentrates data on early clients
