"""Fused decode→aggregate ingest path: bitwise-identity properties.

The server-side contract under test (core/ingest.py + the codec ingest
API): feeding a round's WIRE messages through ``ingest_wire_batch`` into
one O(numel) accumulator produces EXACTLY -- bit for bit -- the state the
dense oracle produces (``decode_wire`` each message, ``ingest_dense`` it),
for every registered codec with an ingest path, under masked/staleness-
weighted rounds, ragged chunk boundaries and empty clients.  Plus: the
streaming kernel-backend decode is bit-identical to the per-bit oracle on
adversarial streams (>= 32-one unary runs, mu = 0), corrupted payloads
raise typed ``WireDecodeError`` on both backends, and the trainers' opt-in
ingest mode reproduces the dense aggregation path end to end.
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal deterministic fallback (see the stub)
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import (WireDecodeError, chunk_codec, chunk_spec_from_sizes,
                        make_protocol, registered_protocols, wire)

DEMO = {"stc": dict(sparsity_up=1 / 8, sparsity_down=1 / 8)}


def _codec(name):
    return make_protocol(name, **DEMO.get(name, {}))


def _ingest_codecs():
    return [n for n in registered_protocols() if _codec(n).supports_ingest]


def _round_msgs(codec, P, numel, seed):
    """One round of REAL client messages (codec-compressed updates); client
    P-1 is empty (all-zero update -- an empty wire message for stc)."""
    rng = np.random.default_rng(seed)
    deltas = rng.standard_normal((P, numel)).astype(np.float32)
    deltas[P - 1] = 0.0
    states = codec.init_client_state(numel)
    if states is not None:
        import jax
        states = jax.tree.map(
            lambda leaf: jnp.stack([leaf] * P), states)
    msgs, _, _ = codec.encode_batch(jnp.asarray(deltas), states)
    return np.asarray(msgs)


def _weights(codec, P, seed):
    """Masked + staleness-decayed combining weights, fp64 host-side."""
    rng = np.random.default_rng(seed + 7)
    mask = (rng.random(P) < 0.7).astype(np.float32)
    mask[0] = 1.0                       # at least one arrival
    stal = rng.integers(0, 4, size=P)
    w = codec.participation_weights(jnp.asarray(mask), jnp.asarray(stal))
    return np.asarray(w, np.float64)


def _assert_fused_is_oracle(codec, numel, seed, P=4):
    msgs = _round_msgs(codec, P, numel, seed)
    w = _weights(codec, P, seed)
    state = codec.init_server_state(numel)

    if codec.wire_format:
        batch = codec.encode_wire_batch(msgs, direction="up")
        fused = codec.make_ingest(numel)
        codec.ingest_wire_batch(fused, batch, w, direction="up")
        dense_rows = [codec.decode_wire(batch.message(i), direction="up")
                      for i in range(P)]
    else:
        fused = codec.make_ingest(numel)
        for i in range(P):
            codec.ingest_dense(fused, msgs[i], float(w[i]))
        dense_rows = list(msgs)

    oracle = codec.make_ingest(numel)
    for i in range(P):
        codec.ingest_dense(oracle, dense_rows[i], float(w[i]))

    assert np.array_equal(fused.sum, oracle.sum)
    assert fused.weight_mass == oracle.weight_mass
    gd_f, st_f, _ = codec.aggregate_ingest(fused, state)
    gd_o, st_o, _ = codec.aggregate_ingest(oracle, state)
    assert np.array_equal(np.asarray(gd_f), np.asarray(gd_o))
    if st_f is not None:
        import jax
        for a, b in zip(jax.tree.leaves(st_f), jax.tree.leaves(st_o)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


class TestFusedMatchesOracle:
    @pytest.mark.parametrize("name", sorted(set(_ingest_codecs())))
    def test_registry_codecs(self, name):
        _assert_fused_is_oracle(_codec(name), numel=257, seed=0)

    @given(st.integers(40, 400), st.integers(0, 10 ** 6))
    @settings(max_examples=15, deadline=None)
    def test_stc_property(self, numel, seed):
        _assert_fused_is_oracle(_codec("stc"), numel, seed)

    def test_empty_round(self):
        codec = _codec("stc")
        acc = codec.make_ingest(64)
        gd, _, _ = codec.aggregate_ingest(acc, codec.init_server_state(64))
        # no arrivals: the combined mean is zero (guarded denominator)
        assert np.all(np.isfinite(np.asarray(gd)))

    def test_unsupported_codec_is_loud(self):
        codec = _codec("topk")
        assert not codec.supports_ingest
        with pytest.raises(NotImplementedError):
            codec.finalize_ingest(jnp.zeros(8), None)

    def test_blocked_decode_matches_one_shot(self):
        # the bounded-workspace block loop must not change anything: force
        # single-message blocks and compare against one big block
        codec = _codec("stc")
        msgs = _round_msgs(codec, 4, 300, 3)
        w = _weights(codec, 4, 3)
        batch = codec.encode_wire_batch(msgs, direction="up")
        one = codec.make_ingest(300)
        codec.ingest_wire_batch(one, batch, w, direction="up")
        small = codec.make_ingest(300)
        try:
            type(codec).ingest_block_words = 1
            codec.ingest_wire_batch(small, batch, w, direction="up")
        finally:
            type(codec).ingest_block_words = 1 << 16
        assert np.array_equal(one.sum, small.sum)


class TestChunkedIngest:
    # ragged everything: uneven layers, chunk boundary mid-layer, empty layer
    @pytest.mark.parametrize("sizes,chunk", [
        ([40, 0, 33, 27], 13), ([7, 19, 5], 31), ([64], 64), ([2, 61], 1),
    ])
    @pytest.mark.parametrize("name", ["stc", "signsgd"])
    def test_ragged_chunks(self, name, sizes, chunk):
        spec = chunk_spec_from_sizes(sizes, chunk_size=chunk)
        codec = chunk_codec(_codec(name), spec)
        assert codec.supports_ingest
        _assert_fused_is_oracle(codec, spec.numel, seed=5)

    def test_single_message_path(self):
        spec = chunk_spec_from_sizes([40, 0, 33, 27], chunk_size=13)
        codec = chunk_codec(_codec("stc"), spec)
        msgs = _round_msgs(codec, 3, spec.numel, 1)
        w = _weights(codec, 3, 1)
        batch = codec.encode_wire_batch(msgs, direction="up")
        a = codec.make_ingest(spec.numel)
        codec.ingest_wire_batch(a, batch, w, direction="up")
        b = codec.make_ingest(spec.numel)
        for i in range(3):
            codec.ingest_wire(b, batch.message(i), float(w[i]),
                              direction="up")
        assert np.array_equal(a.sum, b.sum)
        assert a.stream_bits == b.stream_bits


class TestKernelDecode:
    @given(st.integers(64, 2048), st.integers(0, 10 ** 6))
    @settings(max_examples=10, deadline=None)
    def test_bit_identity_vs_numpy(self, numel, seed):
        rng = np.random.default_rng(seed)
        x = np.zeros(numel, np.float32)
        k = max(numel // 20, 1)
        x[rng.choice(numel, size=k, replace=False)] = \
            rng.choice((-1.0, 1.0), size=k)
        msg = wire.encode_ternary_words(x, 0.05)
        pa, sa = wire.decode_ternary_fields(msg, 0.05, backend="numpy")
        pb, sb = wire.decode_ternary_fields(msg, 0.05, backend="kernel")
        assert np.array_equal(pa, pb) and np.array_equal(sa, sb)

    @pytest.mark.parametrize("backend", ["numpy", "kernel"])
    def test_long_unary_run(self, backend):
        # a single nonzero at the very end of a big tensor forces a unary
        # run far past one 32-bit word (q >= 32 ones before the terminator)
        n = 1 << 15
        x = np.zeros(n, np.float32)
        x[n - 1] = 1.0
        p = 1 / 400
        msg = wire.encode_ternary_words(x, p)
        out = wire.decode_ternary_words(msg, p, backend=backend)
        assert np.array_equal(out, np.sign(x) * np.float32(msg.mu))

    @pytest.mark.parametrize("backend", ["numpy", "kernel"])
    def test_mu_zero(self, backend):
        x = np.zeros(128, np.float32)
        x[[3, 77]] = (1.0, -1.0)
        msg = wire.encode_ternary_words(x, 1 / 8)._replace(mu=0.0)
        pos, signs = wire.decode_ternary_fields(msg, 1 / 8, backend=backend)
        assert np.array_equal(pos, [3, 77])
        assert np.array_equal(wire.decode_ternary_words(msg, 1 / 8,
                                                        backend=backend),
                              np.zeros(128, np.float32))


class TestWireDecodeError:
    def _msg(self):
        x = np.zeros(200, np.float32)
        x[[5, 60, 150]] = (1.0, -1.0, 1.0)
        return wire.encode_ternary_words(x, 1 / 16)

    @pytest.mark.parametrize("backend", ["numpy", "kernel"])
    def test_truncated_codeword(self, backend):
        msg = self._msg()._replace(bit_len=3)
        with pytest.raises(WireDecodeError):
            wire.decode_ternary_fields(msg, 1 / 16, backend=backend)

    @pytest.mark.parametrize("backend", ["numpy", "kernel"])
    def test_no_terminator(self, backend):
        msg = self._msg()
        bad = msg._replace(
            words=np.full_like(msg.words, np.uint32(0xFFFFFFFF)))
        with pytest.raises(WireDecodeError):
            wire.decode_ternary_fields(bad, 1 / 16, backend=backend)

    @pytest.mark.parametrize("backend", ["numpy", "kernel"])
    def test_position_overflow(self, backend):
        msg = self._msg()._replace(numel=32)
        with pytest.raises(WireDecodeError):
            wire.decode_ternary_fields(msg, 1 / 16, backend=backend)

    def test_bit_len_past_buffer(self):
        msg = self._msg()
        bad = msg._replace(bit_len=32 * msg.words.size + 1)
        with pytest.raises(WireDecodeError):
            wire.decode_ternary_fields(bad, 1 / 16)

    def test_batch_raises_too(self):
        batch = wire.concat_messages([self._msg(), self._msg()])
        bad = batch._replace(bit_len=np.asarray([batch.bit_len[0], 3]))
        with pytest.raises(WireDecodeError):
            wire.decode_ternary_fields_batch(bad, 1 / 16)

    def test_error_is_a_valueerror(self):
        assert issubclass(WireDecodeError, ValueError)


class TestTrainerIngestMode:
    """Opt-in ``TrainerConfig(ingest=True)`` reproduces dense aggregation."""

    def _parts(self):
        from repro.data import make_classification
        from repro.fed import FedEnvironment
        from repro.models.paper_models import MODEL_ZOO
        data = make_classification(seed=0, n=600, n_test=160)
        env = FedEnvironment(n_clients=6, participation=0.5,
                             classes_per_client=2, batch_size=10)
        return MODEL_ZOO["logreg"], data, env

    @pytest.mark.parametrize("name", ["stc", "signsgd"])
    def test_sync_matches_dense(self, name):
        from repro.fed import FederatedTrainer, TrainerConfig
        model, (train, test), env = self._parts()
        accs, bits = [], []
        for ingest in (False, True):
            tr = FederatedTrainer(model, train, test, env, _codec(name),
                                  TrainerConfig(lr=0.05, seed=0,
                                                ingest=ingest))
            hist = tr.run(2, eval_every=2)
            accs.append(hist[-1]["acc"])
            bits.append(tr.bits_up)
        assert accs[0] == accs[1]
        assert bits[0] == bits[1]

    def test_buffered_matches_dense(self):
        from repro.fed import (BufferedFederatedTrainer, LatencyModel,
                               TrainerConfig)
        model, (train, test), env = self._parts()
        lat = LatencyModel(mean=0.4, sigma=0.4, hetero=0.3,
                           straggler_frac=0.2, straggler_scale=3.0)
        accs, bits = [], []
        for ingest in (False, True):
            tr = BufferedFederatedTrainer(
                model, train, test, env, _codec("stc"),
                TrainerConfig(lr=0.05, seed=0, ingest=ingest),
                latency=lat, deadline=0.8, max_staleness=4)
            hist = tr.run(3, eval_every=3)
            accs.append(hist[-1]["acc"])
            bits.append(tr.bits_up)
        assert accs[0] == pytest.approx(accs[1], abs=1e-6)
        assert bits[0] == pytest.approx(bits[1])

    def test_ingest_true_on_unsupported_codec_is_loud(self):
        from repro.fed import FederatedTrainer, TrainerConfig
        model, (train, test), env = self._parts()
        with pytest.raises(ValueError, match="no ingest path"):
            FederatedTrainer(model, train, test, env, _codec("topk"),
                             TrainerConfig(ingest=True))
