"""Per-architecture smoke tests: a REDUCED variant of each assigned arch
(<=2-3 layers, d_model<=512, <=4 experts) runs one forward + one train step
on CPU, asserting output shapes and no NaNs; plus one decode step."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, input_specs
from repro.models import decode_step, forward, init_cache, init_model, lm_loss
from repro.optim import sgd_apply, sgd_init

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)
B, S = 2, 32

# the 10-arch zoo sweep dominates suite wall-clock; `pytest -m "not slow"`
# is the fast inner loop, full `pytest` stays the tier-1 gate
pytestmark = pytest.mark.slow


def _batch(cfg):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    extras = {}
    if cfg.encoder is not None:
        extras["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder.n_frames, cfg.d_model)) * 0.1
    if cfg.n_prefix_tokens:
        extras["prefix"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_prefix_tokens, cfg.d_model)) * 0.1
    return toks, extras


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestSmoke:
    def test_reduced_config_limits(self, arch):
        cfg = get_smoke_config(arch)
        assert cfg.n_layers <= 3
        assert cfg.d_model <= 512
        if cfg.moe is not None:
            assert cfg.moe.n_experts <= 4

    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        params = init_model(cfg, KEY)
        toks, extras = _batch(cfg)
        logits, aux = forward(params, cfg, toks, compute_dtype=jnp.float32,
                              **extras)
        s_total = S + (cfg.n_prefix_tokens or 0)
        assert logits.shape == (B, s_total, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert bool(jnp.isfinite(aux))

    def test_train_step_decreases_loss(self, arch):
        cfg = get_smoke_config(arch)
        params = init_model(cfg, KEY)
        toks, extras = _batch(cfg)

        def loss_fn(p):
            return lm_loss(p, cfg, toks, toks, compute_dtype=jnp.float32,
                           **extras)

        opt = sgd_init(params)
        l0, g = jax.value_and_grad(loss_fn)(params)
        assert bool(jnp.isfinite(l0))
        for x in jax.tree.leaves(g):
            assert bool(jnp.all(jnp.isfinite(x))), f"{arch}: non-finite grad"
        params, opt = sgd_apply(params, g, opt, lr=0.1, momentum=0.0)
        l1 = loss_fn(params)
        assert bool(jnp.isfinite(l1))
        assert float(l1) < float(l0) + 1e-3  # one step should not blow up

    def test_decode_step(self, arch):
        cfg = get_smoke_config(arch)
        params = init_model(cfg, KEY)
        toks, extras = _batch(cfg)
        memory = None
        if cfg.encoder is not None:
            from repro.models import encode_frames
            memory = encode_frames(params, cfg,
                                   extras["frames"].astype(jnp.float32))
        caches = init_cache(cfg, B, 16, jnp.float32)
        lg, caches2 = decode_step(params, cfg, toks[:, :1], caches,
                                  memory=memory, compute_dtype=jnp.float32)
        assert lg.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(lg)))

    def test_full_config_is_exact_assignment(self, arch):
        """The FULL config must carry the exact assigned hyperparameters."""
        cfg = get_config(arch)
        expected = {
            "deepseek-v2-lite-16b": (27, 2048, 16, 102400),
            "moonshot-v1-16b-a3b": (48, 2048, 16, 163840),
            "granite-moe-3b-a800m": (32, 1536, 24, 49155),
            "smollm-135m": (30, 576, 9, 49152),
            "qwen2-0.5b": (24, 896, 14, 151936),
            "whisper-medium": (24, 1024, 16, 51865),
            "recurrentgemma-2b": (26, 2560, 10, 256000),
            "mamba2-370m": (48, 1024, 1, 50280),
            "phi3-medium-14b": (40, 5120, 40, 100352),
            "internvl2-2b": (24, 2048, 16, 92553),
        }[arch]
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads,
                cfg.vocab_size) == expected

    def test_long_500k_variant_subquadratic(self, arch):
        """long_500k must resolve to a sub-quadratic config."""
        cfg = get_config(arch, "long_500k")
        subq = (cfg.sliding_window > 0 or
                all(k in ("ssd", "rglru") or k == "local"
                    for k in cfg.block_pattern) or
                cfg.arch_type in ("ssm",))
        assert subq, f"{arch} long_500k config is still quadratic"


class TestInputSpecs:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    @pytest.mark.parametrize("shape", ["train_4k", "prefill_32k",
                                       "decode_32k", "long_500k"])
    def test_specs_no_allocation(self, arch, shape):
        cfg = get_config(arch, shape)
        specs = input_specs(cfg, shape)
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)
        if shape == "train_4k":
            assert specs["tokens"].shape == (256, 4096)
        if shape == "decode_32k":
            assert specs["token"].shape == (128, 1)
        if shape == "long_500k":
            assert specs["token"].shape == (1, 1)
