"""Async buffered aggregation: arrival simulation, the masked/staleness-
weighted Codec API, the BufferedFederatedTrainer deadline edge cases, and
the scripts/check_bench.py regression gate.

The load-bearing guarantee: with ``deadline=inf`` (every client on time) the
buffered trainer runs the SAME compiled phases on the SAME inputs as the
synchronous trainer, so params and both ledgers must match bit for bit."""

import dataclasses
import importlib.util
import math
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Codec, make_protocol
from repro.core.compression import get_stc_backend, majority_vote_sign
from repro.core.protocols import _REGISTRY
from repro.data import make_classification
from repro.fed import (ArrivalSimulator, BufferedFederatedTrainer,
                       FedEnvironment, FederatedTrainer, LatencyModel,
                       TrainerConfig)
from repro.models.paper_models import MODEL_ZOO

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def data():
    return make_classification(seed=0, n=900, n_test=240)


def _env(n_clients=6, participation=0.5):
    return FedEnvironment(n_clients=n_clients, participation=participation,
                          classes_per_client=2, batch_size=10)


def _stc():
    return make_protocol("stc", sparsity_up=1 / 20, sparsity_down=1 / 20)


# ---------------------------------------------------------------------------
# arrival simulator
# ---------------------------------------------------------------------------


class TestArrivalSimulator:
    def test_deadline_inf_everything_on_time(self):
        sim = ArrivalSimulator(LatencyModel(mean=100.0, sigma=2.0),
                               n_clients=8, deadline=math.inf, seed=0)
        sim.dispatch(0, [3, 1, 4], ["a", "b", "c"])
        got = sim.collect(0)
        assert [(a.client, a.sent_round, a.payload) for a in got] == \
            [(3, 0, "a"), (1, 0, "b"), (4, 0, "c")]
        assert sim.pending_count() == 0

    def test_deterministic_bucketing_and_carryover(self):
        # sigma=0 -> latency == mean * scale == 1.7 exactly: one round late
        sim = ArrivalSimulator(LatencyModel(mean=1.7, sigma=0.0),
                               n_clients=4, deadline=1.0, seed=0)
        lats = sim.dispatch(0, [0, 1], ["x", "y"])
        np.testing.assert_allclose(lats, 1.7)
        assert sim.collect(0) == []          # round 0: still in flight
        assert sim.pending_count() == 2       # the buffer carries them over
        got = sim.collect(1)                  # round 1: both land, staleness 1
        assert [(a.client, a.sent_round) for a in got] == [(0, 0), (1, 0)]
        assert sim.pending_count() == 0

    def test_collect_orders_oldest_dispatch_first(self):
        sim = ArrivalSimulator(LatencyModel(mean=1.5, sigma=0.0),
                               n_clients=4, deadline=1.0, seed=0)
        sim.dispatch(0, [0], ["old"])          # lands in round 1
        sim.dispatch(1, [1], ["new"])          # lands in round 2
        got = sim.collect(2)
        assert [a.payload for a in got] == ["old", "new"]
        assert [a.sent_round for a in got] == [0, 1]

    def test_rejects_bad_deadline_and_mismatched_payloads(self):
        with pytest.raises(ValueError, match="deadline"):
            ArrivalSimulator(LatencyModel(), n_clients=2, deadline=0.0)
        sim = ArrivalSimulator(LatencyModel(), n_clients=2)
        with pytest.raises(ValueError, match="payloads"):
            sim.dispatch(0, [0, 1], ["only-one"])

    def test_straggler_population_is_persistent(self):
        lm = LatencyModel(mean=1.0, sigma=0.0, straggler_frac=0.5,
                          straggler_scale=10.0)
        scales = lm.client_scales(64, seed=3)
        slow = scales > 5.0
        assert 0 < slow.sum() < 64            # both populations exist
        np.testing.assert_array_equal(scales, lm.client_scales(64, seed=3))


# ---------------------------------------------------------------------------
# masked / staleness-weighted codec API
# ---------------------------------------------------------------------------


class TestMaskedCodecAPI:
    def test_weighted_mean_matches_reference(self):
        c = make_protocol("baseline")
        msgs = jnp.asarray(
            np.random.default_rng(0).standard_normal((4, 64)), jnp.float32)
        mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
        stal = jnp.asarray([0.0, 2.0, 0.0, 1.0])
        w = np.asarray(c.participation_weights(mask, stal))
        np.testing.assert_allclose(
            w, [1.0, 3.0 ** -0.5, 0.0, 2.0 ** -0.5], rtol=1e-6)
        got, _, _ = c.aggregate(msgs, None, mask=mask, staleness=stal)
        expect = (np.asarray(msgs) * w[:, None]).sum(0) / w.sum()
        np.testing.assert_allclose(np.asarray(got), expect, rtol=1e-6)

    def test_staleness_decay_zero_ignores_age(self):
        c = make_protocol("baseline", staleness_decay=0.0)
        w = np.asarray(c.participation_weights(
            jnp.ones(3), jnp.asarray([0.0, 5.0, 50.0])))
        np.testing.assert_allclose(w, 1.0)

    def test_zero_mask_combines_to_zero(self):
        c = make_protocol("baseline")
        msgs = jnp.ones((3, 10), jnp.float32)
        got, _, _ = c.aggregate(msgs, None, mask=jnp.zeros(3),
                                staleness=jnp.zeros(3))
        assert np.all(np.asarray(got) == 0.0)

    def test_all_ones_mask_matches_plain_mean(self):
        """Weight math sanity: all-ones mask + zero staleness == the plain
        mean up to summation order (the BIT-FOR-BIT guarantee lives at the
        trainer level, where sync and buffered run the SAME jitted phase --
        see TestBufferedTrainer.test_deadline_inf_bit_identical...)."""
        for name in ("baseline", "signsgd"):
            c = make_protocol(name)
            msgs = jnp.asarray(np.random.default_rng(1)
                               .standard_normal((5, 257)), jnp.float32)
            ref, _, _ = c.aggregate(msgs, None)
            got, _, _ = c.aggregate(msgs, None, mask=jnp.ones(5),
                                    staleness=jnp.zeros(5))
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=1e-6, atol=1e-7)

    def test_signsgd_weighted_vote_drops_masked_clients(self):
        s = make_protocol("signsgd")
        sm = jnp.asarray(np.sign(np.random.default_rng(2)
                                 .standard_normal((3, 40))) * s.sign_step,
                         jnp.float32)
        out, _, _ = s.aggregate(sm, None, mask=jnp.asarray([1.0, 0.0, 0.0]),
                                staleness=jnp.zeros(3))
        np.testing.assert_allclose(np.asarray(out),
                                   s.sign_step * np.sign(np.asarray(sm)[0]),
                                   rtol=1e-6)

    def test_stc_masked_aggregate_compresses_weighted_mean(self):
        stc = _stc()
        msgs = jnp.asarray(np.random.default_rng(3)
                           .standard_normal((4, 100)), jnp.float32)
        mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
        stal = jnp.asarray([0.0, 0.0, 2.0, 1.0])
        st = stc.init_server_state(100)
        got, _, _ = stc.aggregate(msgs, st, mask=mask, staleness=stal)
        w = np.asarray(stc.participation_weights(mask, stal))
        mean = (np.asarray(msgs) * w[:, None]).sum(0) / w.sum()
        ref, _, _ = get_stc_backend("jnp").compress_with_residual(
            jnp.asarray(mean), st.residual, stc.sparsity_down)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-7)

    def test_majority_vote_weights_break_ties(self):
        stacked = jnp.asarray([[1.0], [-1.0], [-1.0]])
        plain = majority_vote_sign(stacked, 1.0)
        assert float(plain[0]) == -1.0
        weighted = majority_vote_sign(stacked, 1.0,
                                      weights=jnp.asarray([5.0, 1.0, 1.0]))
        assert float(weighted[0]) == 1.0

    def test_tree_reduce_masked_no_axes(self):
        c = make_protocol("baseline")
        tree = {"a": jnp.full((2, 3), 4.0)}
        kept = c.tree_reduce(tree, (), 1, mask=jnp.asarray([1.0]),
                             staleness=jnp.asarray([3.0]))
        np.testing.assert_allclose(np.asarray(kept["a"]), 4.0)  # w*t/w
        dropped = c.tree_reduce(tree, (), 1, mask=jnp.asarray([0.0]))
        assert np.all(np.asarray(dropped["a"]) == 0.0)


# ---------------------------------------------------------------------------
# buffered trainer: equivalence + deadline edge cases
# ---------------------------------------------------------------------------


class TestBufferedTrainer:
    @pytest.mark.parametrize("name", ["stc", "signsgd"])
    def test_deadline_inf_bit_identical_to_synchronous(self, data, name):
        """Acceptance: deadline=inf + everyone on time == FederatedTrainer,
        bit for bit, params AND both ledgers (measured stc, analytic sign)."""
        train, test = data
        kw = {"stc": dict(sparsity_up=1 / 20, sparsity_down=1 / 20)}
        rounds = 4
        sync = FederatedTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                                make_protocol(name, **kw.get(name, {})),
                                TrainerConfig(lr=0.05, seed=0))
        sync.run(rounds, eval_every=2)
        buf = BufferedFederatedTrainer(
            MODEL_ZOO["logreg"], train, test, _env(),
            make_protocol(name, **kw.get(name, {})),
            TrainerConfig(lr=0.05, seed=0),
            latency=LatencyModel(mean=3.0, sigma=1.0), deadline=math.inf)
        buf.run(rounds, eval_every=2)
        np.testing.assert_array_equal(np.asarray(sync.params_vec),
                                      np.asarray(buf.params_vec))
        assert sync.bits_up == buf.bits_up
        assert sync.bits_down == buf.bits_down
        assert sync.wire_log == buf.wire_log
        for hs, hb in zip(sync.history, buf.history):
            for key in hs:          # shared columns identical
                assert hs[key] == hb[key], key

    def test_zero_arrival_round_freezes_server(self, data):
        """Nothing lands by the deadline: params + server codec state are
        untouched and the ledger logs 0 upstream bits."""
        train, test = data
        tr = BufferedFederatedTrainer(
            MODEL_ZOO["logreg"], train, test, _env(), _stc(),
            TrainerConfig(lr=0.05, seed=0),
            latency=LatencyModel(mean=50.0, sigma=0.0), deadline=1.0,
            max_staleness=100)
        params0 = np.asarray(tr.params_vec).copy()
        server_res0 = np.asarray(tr.server_state.residual).copy()
        tr.run_round()
        assert tr.bits_up == 0.0
        assert tr.wire_log == []            # nothing measured
        np.testing.assert_array_equal(np.asarray(tr.params_vec), params0)
        np.testing.assert_array_equal(np.asarray(tr.server_state.residual),
                                      server_res0)
        assert tr.sim.pending_count() == tr.env.participants_per_round
        assert tr.arrival_log[-1]["arrived"] == 0

    def test_staleness_beyond_horizon_is_dropped(self, data):
        """Updates arriving staler than max_staleness never aggregate; their
        upload bits still count (the bytes did reach the server)."""
        train, test = data
        # latency 1.5 deadlines, sigma=0: EVERY update lands one round late
        tr = BufferedFederatedTrainer(
            MODEL_ZOO["logreg"], train, test, _env(), _stc(),
            TrainerConfig(lr=0.05, seed=0),
            latency=LatencyModel(mean=1.5, sigma=0.0), deadline=1.0,
            max_staleness=0)
        params0 = np.asarray(tr.params_vec).copy()
        tr.run(3, eval_every=3)
        assert tr.n_dropped == 2 * tr.env.participants_per_round
        np.testing.assert_array_equal(np.asarray(tr.params_vec), params0)
        assert tr.bits_up > 0.0             # dropped arrivals still uploaded
        # same network, horizon 1: the late updates now aggregate
        tr2 = BufferedFederatedTrainer(
            MODEL_ZOO["logreg"], train, test, _env(), _stc(),
            TrainerConfig(lr=0.05, seed=0),
            latency=LatencyModel(mean=1.5, sigma=0.0), deadline=1.0,
            max_staleness=1)
        tr2.run(3, eval_every=3)
        assert tr2.n_dropped == 0
        assert not np.array_equal(np.asarray(tr2.params_vec), params0)
        assert tr2.arrival_log[-1]["staleness_max"] == 1

    def test_lossy_network_still_trains(self, data):
        train, test = data
        lat = LatencyModel(mean=1.2, sigma=0.6, hetero=0.5,
                           straggler_frac=0.2, straggler_scale=4.0)
        tr = BufferedFederatedTrainer(
            MODEL_ZOO["logreg"], train, test, _env(n_clients=8), _stc(),
            TrainerConfig(lr=0.05, seed=0), latency=lat, deadline=1.0,
            max_staleness=3)
        hist = tr.run(6, eval_every=6)
        assert np.all(np.isfinite(np.asarray(tr.params_vec)))
        assert hist[-1]["acc"] > 0.2
        for row in tr.arrival_log:          # conservation per round
            assert row["aggregated"] + row["dropped"] == row["arrived"]

    def test_chunked_deadline_inf_bit_identical_to_synchronous(self, data):
        """Acceptance (ISSUE 5): the deadline=inf bit-identity guarantee
        must also hold when chunks>1 -- buffered chunked == sync chunked,
        params AND ledgers AND wire_log."""
        train, test = data
        tcfg = TrainerConfig(lr=0.05, seed=0, chunks=16)
        rounds = 3
        sync = FederatedTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                                _stc(), tcfg)
        sync.run(rounds, eval_every=rounds)
        assert sync.protocol.spec.n_chunks > 1   # really multi-chunk
        buf = BufferedFederatedTrainer(
            MODEL_ZOO["logreg"], train, test, _env(), _stc(), tcfg,
            latency=LatencyModel(mean=3.0, sigma=1.0), deadline=math.inf)
        buf.run(rounds, eval_every=rounds)
        np.testing.assert_array_equal(np.asarray(sync.params_vec),
                                      np.asarray(buf.params_vec))
        assert sync.bits_up == buf.bits_up
        assert sync.bits_down == buf.bits_down
        assert sync.wire_log == buf.wire_log
        for hs, hb in zip(sync.history, buf.history):
            for key in hs:
                assert hs[key] == hb[key], key

    def test_chunked_zero_arrival_round_freezes_every_chunk_state(self, data):
        """Nothing lands by the deadline: EVERY per-chunk server residual
        (the (n_chunks, chunk_numel) state stack) must stay frozen."""
        train, test = data
        tr = BufferedFederatedTrainer(
            MODEL_ZOO["logreg"], train, test, _env(), _stc(),
            TrainerConfig(lr=0.05, seed=0, chunks=16),
            latency=LatencyModel(mean=50.0, sigma=0.0), deadline=1.0,
            max_staleness=100)
        res0 = np.asarray(tr.server_state.residual).copy()
        n_chunks = tr.protocol.spec.n_chunks
        assert res0.shape[0] == n_chunks > 1
        params0 = np.asarray(tr.params_vec).copy()
        tr.run_round()
        assert tr.bits_up == 0.0 and tr.wire_log == []
        np.testing.assert_array_equal(np.asarray(tr.params_vec), params0)
        np.testing.assert_array_equal(np.asarray(tr.server_state.residual),
                                      res0)

    def test_legacy_codec_without_mask_api_is_rejected(self):
        """The pre-mask 2-arg ``aggregate`` signature is gone: the class
        DEFINITION fails loudly (naming the migration), so a legacy codec
        can never reach a trainer or the registry."""
        with pytest.raises(TypeError, match="masked aggregation API"):
            @dataclasses.dataclass(frozen=True)
            class LegacyMean(Codec):
                name = "legacy-mean-test"

                def encode(self, delta, state):
                    return delta, state, None

                def aggregate(self, msgs, server_state):   # pre-mask
                    return jnp.mean(msgs, axis=0), server_state, None

        assert "legacy-mean-test" not in _REGISTRY


# ---------------------------------------------------------------------------
# check_bench regression gate
# ---------------------------------------------------------------------------


def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", os.path.join(REPO, "scripts", "check_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestCheckBench:
    def test_medians_handle_both_key_vintages_and_repeats(self):
        cb = _load_check_bench()
        payload = {"rows": [{"name": "a", "us": 10.0},
                            {"name": "a", "us": 30.0},
                            {"name": "a", "us": 20.0},
                            {"name": "b", "value": 5.0}]}
        med = cb.medians_by_name(payload)
        assert med == {"a": 20.0, "b": 5.0}

    def test_compare_flags_only_beyond_tolerance(self):
        cb = _load_check_bench()
        base = {"fast": 100.0, "slow": 100.0, "gone": 7.0}
        fresh = {"fast": 150.0, "slow": 300.0, "new": 1.0}
        report, regressions = cb.compare(base, fresh, tolerance=2.0)
        assert len(regressions) == 1 and "slow" in regressions[0]
        joined = "\n".join(report)
        assert "MISSING gone" in joined and "NEW" in joined

    def test_unparsed_rows_are_report_only_not_keyerror(self):
        """A bench family present in the fresh run but missing (or written
        by an older vintage without the value key) in the committed BENCH
        file must be a report-only warning, never a KeyError."""
        cb = _load_check_bench()
        payload = {"unit": "us",
                   "rows": [{"name": "chunked/new", "note": "no value key"},
                            {"note": "row without a name"},
                            {"name": "ok", "us": 3.0}]}
        unparsed: list = []
        med = cb.medians_by_name(payload, unparsed)
        assert med == {"ok": 3.0}
        assert unparsed == ["chunked/new", "<unnamed>"]
        # and without a collector it still never raises
        assert cb.medians_by_name(payload) == {"ok": 3.0}

    def test_fresh_only_family_reports_new_rows_without_failing(self):
        cb = _load_check_bench()
        base = {"old": 10.0}
        fresh = {"old": 11.0, "chunked/select": 5.0}
        report, regressions = cb.compare(base, fresh, tolerance=2.0)
        assert regressions == []
        assert any("NEW" in line and "chunked/select" in line
                   for line in report)

    def test_gate_passes_against_committed_baseline(self):
        """End-to-end wiring on the real committed files (huge tolerance: a
        dev may have rerun benchmarks.run locally on a slower machine -- the
        2x gate itself belongs to the slow lane, not this unit test)."""
        cb = _load_check_bench()
        assert cb.main(["--ref", "HEAD", "--tolerance", "1e6"]) == 0
