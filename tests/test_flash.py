"""Flash custom-VJP attention vs the naive chunked reference: forward and
gradients, over causal/window/cross/GQA/MLA-dim variations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import chunked_attention
from repro.models.flash import flash_attention

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)

CASES = [
    # sq, skv, h, kv, hd, hdv, causal, window, chunk
    (16, 16, 4, 2, 8, 8, True, 0, 4),
    (24, 24, 6, 3, 8, 8, True, 8, 8),       # sliding window
    (8, 20, 4, 4, 8, 4, False, 0, 8),       # cross attn, hdv != hd
    (33, 33, 4, 1, 16, 16, True, 0, 16),    # non-aligned length (padding)
    (16, 16, 8, 8, 8, 8, True, 0, 16),      # MHA, single chunk
]


@pytest.mark.parametrize("case", CASES)
def test_forward_matches_reference(case):
    sq, skv, h, kv, hd, hdv, causal, window, chunk = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, h, hd))
    k = jax.random.normal(ks[1], (2, skv, kv, hd))
    v = jax.random.normal(ks[2], (2, skv, kv, hdv))
    f = flash_attention(q, k, v, causal, window, chunk)
    c = chunked_attention(q, k, v, causal=causal, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(f), np.asarray(c), atol=3e-5)


@pytest.mark.parametrize("case", CASES)
def test_gradients_match_autodiff_reference(case):
    sq, skv, h, kv, hd, hdv, causal, window, chunk = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, h, hd))
    k = jax.random.normal(ks[1], (2, skv, kv, hd))
    v = jax.random.normal(ks[2], (2, skv, kv, hdv))

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal, window, chunk) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(chunked_attention(q, k, v, causal=causal,
                                         window=window, chunk=chunk) ** 2)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gc, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-3,
                                   err_msg=f"d{name} mismatch")


def test_chunk_invariance():
    """Output independent of the chunk size (tiling is an impl detail)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 32, 4, 8))
    k = jax.random.normal(ks[1], (1, 32, 2, 8))
    v = jax.random.normal(ks[2], (1, 32, 2, 8))
    outs = [np.asarray(flash_attention(q, k, v, True, 0, c))
            for c in (4, 8, 16, 32)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=2e-5)


def test_bf16_inputs():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 16, 4, 8), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 16, 2, 8), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 16, 2, 8), jnp.bfloat16)
    out = flash_attention(q, k, v, True, 0, 8)
    assert out.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
