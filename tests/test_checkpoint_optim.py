"""Checkpoint roundtrip + optimizer behavior."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.optim import (adamw_apply, adamw_init, constant_lr, cosine_lr,
                         sgd_apply, sgd_init, warmup_cosine_lr)

jax.config.update("jax_platform_name", "cpu")


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "w": jnp.asarray(np.random.randn(8, 4), jnp.float32),
            "b16": jnp.asarray(np.random.randn(6), jnp.bfloat16),
            "step": jnp.asarray(7, jnp.int32),
            "nested": [jnp.ones((2, 2)), {"x": jnp.zeros(3)}],
        }
        path = str(tmp_path / "ck.msgpack.zst")
        save_checkpoint(path, tree)
        back = restore_checkpoint(path, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_structure_mismatch_rejected(self, tmp_path):
        path = str(tmp_path / "ck.zst")
        save_checkpoint(path, {"a": jnp.ones(3)})
        with pytest.raises(ValueError):
            restore_checkpoint(path, {"a": jnp.ones(3), "b": jnp.ones(2)})


class TestOptim:
    def test_sgd_momentum_accumulates(self):
        p = {"w": jnp.zeros(3)}
        g = {"w": jnp.ones(3)}
        v = sgd_init(p)
        p1, v1 = sgd_apply(p, g, v, lr=1.0, momentum=0.9)
        p2, v2 = sgd_apply(p1, g, v1, lr=1.0, momentum=0.9)
        np.testing.assert_allclose(np.asarray(v2["w"]), 1.9)   # 0.9*1 + 1
        np.testing.assert_allclose(np.asarray(p2["w"]), -2.9)  # -(1 + 1.9)

    def test_adamw_step(self):
        p = {"w": jnp.ones(4)}
        g = {"w": jnp.full(4, 0.5)}
        st = adamw_init(p)
        p1, st1 = adamw_apply(p, g, st, lr=0.1)
        assert float(p1["w"][0]) < 1.0
        assert int(st1["t"]) == 1

    def test_schedules(self):
        assert float(constant_lr(0.1)(1000)) == pytest.approx(0.1)
        c = cosine_lr(1.0, 100)
        assert float(c(0)) == pytest.approx(1.0)
        assert float(c(100)) == pytest.approx(0.1, abs=1e-6)
        w = warmup_cosine_lr(1.0, warmup=10, total_steps=100)
        assert float(w(0)) == 0.0
        assert float(w(10)) == pytest.approx(1.0)
