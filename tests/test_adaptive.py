"""Adaptive per-chunk sparsity controllers: registry semantics, hyperparam
validation, the schedule/controller sparsity guards, and THE property test --
``controller="fixed"`` routes through the byte-identical static path in all
three trainers while adaptive controllers keep the measured wire bits under
the deterministic stream bound every round.
"""

import math

import numpy as np
import pytest

from repro.core import (FixedController, ResidualMassController,
                        SnrConstantController, chunk_codec,
                        chunk_spec_from_sizes, make_controller, make_protocol,
                        registered_controllers, validate_sparsity,
                        whole_vector_spec)
from repro.data import make_classification
from repro.fed import (BufferedFederatedTrainer, EventDrivenTrainer,
                       FederatedTrainer, FedEnvironment, LatencyModel,
                       TrainerConfig)
from repro.fed.scenarios import SteadyScenario
from repro.models.paper_models import MODEL_ZOO


@pytest.fixture(scope="module")
def data():
    return make_classification(seed=0, n=600, n_test=120)


def _env():
    return FedEnvironment(n_clients=6, participation=0.5,
                          classes_per_client=2, batch_size=10)


def _stc():
    return make_protocol("stc", sparsity_up=1 / 20, sparsity_down=1 / 20)


# ---------------------------------------------------------------------------
# registry + hyperparameter validation
# ---------------------------------------------------------------------------


class TestControllerRegistry:
    def test_registered_names(self):
        assert set(registered_controllers()) >= {
            "fixed", "residual_mass", "snr_constant"}

    def test_unknown_name_raises_keyerror_listing_known(self):
        with pytest.raises(KeyError, match="fixed"):
            make_controller("no-such-controller")

    def test_hyphen_and_underscore_are_interchangeable(self):
        assert isinstance(make_controller("residual-mass"),
                          ResidualMassController)
        assert isinstance(make_controller("snr_constant"),
                          SnrConstantController)

    def test_instance_passes_through_untouched(self):
        ctrl = ResidualMassController(budget=0.5)
        assert make_controller(ctrl) is ctrl

    def test_overrides_reach_the_constructor(self):
        assert make_controller("residual_mass", budget=0.25).budget == 0.25
        assert make_controller("snr_constant", snr=2.0, ema=0.0).snr == 2.0

    @pytest.mark.parametrize("kwargs", [dict(budget=0.0), dict(budget=-1.0),
                                        dict(budget=float("nan")),
                                        dict(budget=float("inf"))])
    def test_residual_mass_validates_budget(self, kwargs):
        with pytest.raises(ValueError, match="budget"):
            ResidualMassController(**kwargs)

    @pytest.mark.parametrize("kwargs", [dict(snr=0.0), dict(snr=-1.0),
                                        dict(snr=float("nan")),
                                        dict(ema=1.0), dict(ema=-0.1),
                                        dict(ema=float("nan"))])
    def test_snr_constant_validates_hyperparams(self, kwargs):
        with pytest.raises(ValueError, match="snr|ema"):
            SnrConstantController(**kwargs)

    @pytest.mark.parametrize("scale", [0.5, 0.0, float("nan"), float("inf")])
    def test_k_max_scale_validated(self, scale):
        with pytest.raises(ValueError, match="k_max_scale"):
            ResidualMassController(k_max_scale=scale)

    def test_caps_geometry(self):
        base = np.asarray([2, 5, 1])
        valid = np.asarray([16, 8, 3])
        ctrl = ResidualMassController(k_max_scale=3.0)
        # ceil(3 * base) clamped to [base, valid]
        np.testing.assert_array_equal(ctrl.caps(base, valid), [6, 8, 3])
        # the fixed marker never exceeds the static schedule
        np.testing.assert_array_equal(
            FixedController().caps(base, valid), base)
        assert not FixedController().adapts
        assert SnrConstantController().stateful


# ---------------------------------------------------------------------------
# sparsity guards (satellite: adversarial p_fn + controller p validation)
# ---------------------------------------------------------------------------


BAD_PS = [0.0, -0.25, 1.5, float("nan"), float("inf"), "dense", None]


class TestSparsityValidation:
    @pytest.mark.parametrize("p", BAD_PS)
    def test_validate_sparsity_rejects(self, p):
        with pytest.raises(ValueError, match="layer 'conv'"):
            validate_sparsity(p, "conv", 3)

    def test_validate_sparsity_accepts_the_boundary(self):
        assert validate_sparsity(1.0, "x", 0) == 1.0
        assert validate_sparsity(1e-6, "x", 0) == 1e-6
        assert validate_sparsity(np.float32(0.5), "x", 0) == 0.5

    @pytest.mark.parametrize("p", BAD_PS[:-1])  # None = "use default": legal
    def test_chunk_codec_rejects_adversarial_p_fn_at_wrap_time(self, p):
        spec = chunk_spec_from_sizes([16, 16], names=["dense", "embed"],
                                     chunk_size=8)
        with pytest.raises(ValueError, match="embed"):
            chunk_codec(_stc(), spec,
                        p_fn=lambda name, d: p if name == "embed" else None)

    @pytest.mark.parametrize("p", BAD_PS[:-1])
    def test_tree_path_rejects_adversarial_p_fn(self, p):
        import jax.numpy as jnp

        from repro.core.distributed import stc_compress_tree_chunked
        tree = {"w": jnp.ones((8, 4)), "b": jnp.ones(4)}
        with pytest.raises(ValueError, match="sparsity schedule"):
            stc_compress_tree_chunked(tree, 1 / 5, chunk_size=16,
                                      p_fn=lambda name, d: p)

    def test_adaptive_controller_requires_chunk_blocks_codec(self):
        spec = whole_vector_spec(32)
        with pytest.raises(TypeError, match="chunk-blocks"):
            chunk_codec(make_protocol("signsgd"), spec,
                        controller="residual_mass")
        # the non-adapting marker stays legal on any codec
        cc = chunk_codec(make_protocol("signsgd"), spec, controller="fixed")
        assert cc.controller.name == "fixed"

    def test_trainer_controller_without_chunks_is_loud(self, data):
        train, test = data
        with pytest.raises(ValueError, match="chunks"):
            FederatedTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                             _stc(), TrainerConfig(
                                 lr=0.05, seed=0, controller="snr_constant"))


# ---------------------------------------------------------------------------
# THE property test (satellite): controller="fixed" + chunks="whole" is the
# flat trainer BIT FOR BIT -- params, both ledgers, wire_log, history --
# for stc AND signsgd, in the sync, buffered and event trainers.
# ---------------------------------------------------------------------------


def _flat_and_fixed(data, name, trainer):
    train, test = data
    kw = {"stc": dict(sparsity_up=1 / 20, sparsity_down=1 / 20)}
    rounds = 3

    def build(tcfg):
        proto = make_protocol(name, **kw.get(name, {}))
        args = (MODEL_ZOO["logreg"], train, test, _env(), proto, tcfg)
        if trainer == "sync":
            return FederatedTrainer(*args)
        if trainer == "buffered":
            return BufferedFederatedTrainer(*args, deadline=math.inf)
        return EventDrivenTrainer(
            *args, scenario=SteadyScenario(latency=LatencyModel(mean=3.0,
                                                                sigma=0.0)))

    flat = build(TrainerConfig(lr=0.05, seed=0))
    flat.run(rounds, eval_every=rounds)
    fixed = build(TrainerConfig(lr=0.05, seed=0, chunks="whole",
                                controller="fixed"))
    fixed.run(rounds, eval_every=rounds)
    return flat, fixed


@pytest.mark.parametrize("trainer", ["sync", "buffered", "event"])
@pytest.mark.parametrize("name", ["stc", "signsgd"])
def test_fixed_controller_whole_vector_is_flat_path(data, name, trainer):
    flat, fixed = _flat_and_fixed(data, name, trainer)
    np.testing.assert_array_equal(np.asarray(flat.params_vec),
                                  np.asarray(fixed.params_vec))
    assert flat.bits_up == fixed.bits_up
    assert flat.bits_down == fixed.bits_down
    assert flat.bits_up_analytic == fixed.bits_up_analytic
    assert flat.bits_down_analytic == fixed.bits_down_analytic
    assert flat.wire_log == fixed.wire_log
    for hf, hc in zip(flat.history, fixed.history):
        for key in hf:
            assert hf[key] == hc[key], key


# ---------------------------------------------------------------------------
# adaptive controllers end to end: the wire bound stays a true ceiling
# under time-varying per-chunk k, and the controllers actually adapt
# ---------------------------------------------------------------------------


def _adaptive_trainer(data, controller, chunks=32, rounds=3):
    train, test = data
    tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, _env(), _stc(),
                          TrainerConfig(lr=0.05, seed=0, chunks=chunks,
                                        controller=controller))
    tr.run(rounds, eval_every=rounds)
    return tr


@pytest.mark.parametrize("controller", [
    ResidualMassController(budget=0.6),
    SnrConstantController(snr=1.0),
    "residual-mass", "snr-constant"])
def test_measured_bits_below_wire_bound_every_round(data, controller):
    tr = _adaptive_trainer(data, controller)
    assert len(tr.wire_log) == 3
    for row in tr.wire_log:
        assert row["bits_up_bound"] is not None
        assert row["bits_up"] <= row["bits_up_bound"]
    assert np.all(np.isfinite(np.asarray(tr.params_vec)))
    assert tr.history[-1]["acc"] > 0.0
    assert tr.bits_up > 0 and tr.bits_up_analytic > 0


def test_adaptive_controllers_change_the_bit_spend(data):
    """A sub-unit budget must spend strictly fewer measured upstream bits
    than the fixed schedule -- proof the per-chunk ks really vary.  Chunks
    must be large enough that base_k is well above the k >= 1 floor,
    otherwise the clip hides the budget."""
    fixed = _adaptive_trainer(data, "fixed", chunks=256)
    lean = _adaptive_trainer(data, ResidualMassController(budget=0.5),
                             chunks=256)
    assert lean.bits_up < fixed.bits_up
    snr = _adaptive_trainer(data, SnrConstantController(snr=1.0), chunks=256)
    assert snr.bits_up != fixed.bits_up


def test_snr_state_rides_checkpoints_bit_identically(data, tmp_path):
    """The stateful controller's EMA leaf lives in the codec state pytrees:
    kill-and-resume through the event trainer's checkpoint must reproduce
    the uninterrupted run exactly."""
    train, test = data
    ck = str(tmp_path / "snr.ck")

    def build():
        return EventDrivenTrainer(
            MODEL_ZOO["logreg"], train, test, _env(), _stc(),
            TrainerConfig(lr=0.05, seed=0, chunks=32,
                          controller=SnrConstantController(snr=1.0)))

    ref = build()
    for _ in range(4):
        ref.run_round()

    a = build()
    for _ in range(2):
        a.run_round()
    a.save_checkpoint(ck)

    b = build()
    b.restore_checkpoint(ck)
    for _ in range(2):
        b.run_round()
    np.testing.assert_array_equal(np.asarray(ref.params_vec),
                                  np.asarray(b.params_vec))
    assert ref.wire_log == b.wire_log
    assert (ref.bits_up, ref.bits_down) == (b.bits_up, b.bits_down)


# ---------------------------------------------------------------------------
# the dynamic selection primitive + the tree path
# ---------------------------------------------------------------------------


class TestDynamicSelection:
    def test_matches_static_select_at_constant_k(self):
        import jax.numpy as jnp

        from repro.core.compression import (get_stc_backend,
                                            select_batch_dynamic)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(6, 40)).astype(np.float32))
        static = get_stc_backend("jnp").select_batch(x, 5)
        dynamic = select_batch_dynamic(x, jnp.full((6,), 5, jnp.int32),
                                       k_cap=8)
        for s, d in zip(static, dynamic):   # (threshold, count, sum) triple
            np.testing.assert_array_equal(np.asarray(s), np.asarray(d))

    def test_per_row_k_selects_exactly_k(self):
        import jax.numpy as jnp

        from repro.core.compression import select_batch_dynamic
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
        ks = jnp.asarray([1, 3, 7, 2], jnp.int32)
        _, cnt, _ = select_batch_dynamic(x, ks, k_cap=8)
        np.testing.assert_array_equal(np.asarray(cnt), [1, 3, 7, 2])

    def test_tree_path_fixed_is_static_and_adaptive_jits(self):
        import jax
        import jax.numpy as jnp

        from repro.core.distributed import stc_compress_tree_chunked
        rng = np.random.default_rng(2)
        tree = {"w": jnp.asarray(rng.normal(size=(16, 8)),
                                 dtype=jnp.float32),
                "b": jnp.asarray(rng.normal(size=(8,)), dtype=jnp.float32)}
        t_static, _ = stc_compress_tree_chunked(tree, 1 / 5, chunk_size=16)
        t_fixed, _ = stc_compress_tree_chunked(tree, 1 / 5, chunk_size=16,
                                               controller="fixed")
        for k in tree:
            np.testing.assert_array_equal(np.asarray(t_static[k]),
                                          np.asarray(t_fixed[k]))

        @jax.jit
        def go(t):
            tern, _ = stc_compress_tree_chunked(
                t, 1 / 5, chunk_size=16,
                controller=ResidualMassController(budget=0.8))
            return tern
        tern = go(tree)
        for k in tree:
            nz = int((np.asarray(tern[k]) != 0).sum())
            assert 0 < nz < tree[k].size
