"""Distributed launch tests (subprocess: these need fake multi-device XLA,
which must not leak into the rest of the suite -- the main process keeps 1
CPU device per the dry-run isolation rule)."""

import os
import subprocess
import sys

import jax
import pytest

# The train_step uses partial-manual shard_map (manual client axes over an
# auto "model" axis).  jax < 0.5 has no jax.shard_map and its
# experimental shard_map's auto-subgroup support hard-crashes XLA
# (CHECK sharding.IsManualSubgroup()), so these tests need a newer jax.
_PARTIAL_MANUAL = hasattr(jax, "shard_map")
pytestmark = pytest.mark.skipif(
    not _PARTIAL_MANUAL,
    reason="partial-manual shard_map unsupported on this jax version")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": os.path.join(REPO, "src")}


def _run(code: str, timeout=900):
    return subprocess.run([sys.executable, "-c", code], env=ENV, cwd=REPO,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_step_loss_decreases_all_protocols():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.train import TrainConfig, init_train_state, make_train_step
from repro.data import make_lm_tokens

mesh = make_debug_mesh(data=2, model=2)
cfg = get_smoke_config("smollm-135m")
toks = make_lm_tokens(n_tokens=4*128+1, vocab=cfg.vocab_size)
batch = {"tokens": jnp.asarray(toks[:-1].reshape(4,128)),
         "labels": jnp.asarray(toks[1:].reshape(4,128))}
for proto in ("stc", "topk", "signsgd", "fedavg", "baseline"):
    tc = TrainConfig(protocol=proto, lr=0.05, sparsity_up=1/50,
                     sparsity_down=1/50, local_iters=2 if proto=="fedavg" else 1)
    state = init_train_state(cfg, tc, n_clients=2, key=jax.random.PRNGKey(0))
    step = make_train_step(cfg, mesh, tc)
    losses = []
    for _ in range(4):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), (proto, losses)
    assert losses[-1] < losses[0], (proto, losses)
    print(proto, "OK", losses[0], "->", losses[-1])
print("ALL_PROTOCOLS_OK")
"""
    r = _run(code)
    assert "ALL_PROTOCOLS_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_masked_step_drops_client_without_stalling():
    """TrainConfig(masked=True): with mask=(1,0) the dropped client's message
    gets zero weight -- the step equals the arrived client's update alone
    (baseline codec: global delta == client 0's delta) and the masked-out
    client's state never advances."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.train import TrainConfig, init_train_state, make_train_step
from repro.models import lm_loss

mesh = make_debug_mesh(data=2, model=2)
cfg = get_smoke_config("qwen2-0.5b")
tc = TrainConfig(protocol="baseline", lr=0.1, compute_dtype=jnp.float32,
                 masked=True)
state = init_train_state(cfg, tc, n_clients=2, key=jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
batch = {"tokens": toks, "labels": toks}
step = make_train_step(cfg, mesh, tc)
mask = jnp.asarray([1.0, 0.0]); stal = jnp.zeros(2)
new_state, metrics = step(state, batch, mask, stal)

# host reference: ONLY client 0 (batch rows 0:2) contributes, full weight
params = state["params"]
def loss_of(p): return lm_loss(p, cfg, toks[0:2], toks[0:2],
                               compute_dtype=jnp.float32)
g = jax.grad(loss_of)(params)
want = jax.tree.map(lambda p, gg: p - tc.lr * gg.astype(jnp.float32), params, g)
for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(want)[0],
        jax.tree_util.tree_flatten_with_path(new_state["params"])[0]):
    np.testing.assert_allclose(np.asarray(b, np.float32),
                               np.asarray(a, np.float32),
                               rtol=5e-3, atol=5e-5, err_msg=str(pa))

# zero-weight round: nothing arrives, params must not move
frozen, _ = step(state, batch, jnp.zeros(2), stal)
for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(state["params"])[0],
        jax.tree_util.tree_flatten_with_path(frozen["params"])[0]):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=str(pa))

# stateful codec (stc): a zero-arrival step must also freeze the server
# residual, not drain it into a parameter update
tc2 = TrainConfig(protocol="stc", lr=0.1, sparsity_up=1/20, sparsity_down=1/20,
                  compute_dtype=jnp.float32, masked=True)
state2 = init_train_state(cfg, tc2, n_clients=2, key=jax.random.PRNGKey(0))
state2["server_res"] = jax.tree.map(
    lambda p: 0.01 * jnp.ones(p.shape, jnp.float32), state2["params"])
step2 = make_train_step(cfg, mesh, tc2)
frozen2, _ = step2(state2, batch, jnp.zeros(2), stal)
for key in ("params", "server_res"):
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(state2[key])[0],
            jax.tree_util.tree_flatten_with_path(frozen2[key])[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=key + str(pa))
print("MASKED_STEP_OK")
"""
    r = _run(code)
    assert "MASKED_STEP_OK" in r.stdout, r.stdout[-3000:] + r.stderr[-3000:]


@pytest.mark.slow
def test_distributed_stc_matches_single_device_semantics():
    """2-client distributed STC == hand-computed reference on the host:
    per-client grad -> STC(EF) -> mean -> server STC(EF) -> apply."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.train import TrainConfig, init_train_state, make_train_step
from repro.core.distributed import stc_compress_tree, tree_add
from repro.models import lm_loss

mesh = make_debug_mesh(data=2, model=2)
cfg = get_smoke_config("qwen2-0.5b")
tc = TrainConfig(protocol="stc", lr=0.1, sparsity_up=1/20, sparsity_down=1/20,
                 compute_dtype=jnp.float32)
state = init_train_state(cfg, tc, n_clients=2, key=jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
batch = {"tokens": toks, "labels": toks}
step = make_train_step(cfg, mesh, tc)
new_state, metrics = step(state, batch)

# host reference
params = state["params"]
numel = cfg.param_count()
def loss_of(p, sl): return lm_loss(p, cfg, toks[sl], toks[sl], compute_dtype=jnp.float32)
msgs = []
for ci, sl in enumerate((slice(0,2), slice(2,4))):
    g = jax.grad(loss_of)(params, sl)
    delta = jax.tree.map(lambda u: -tc.lr*u.astype(jnp.float32), g)
    tern, _ = stc_compress_tree(delta, tc.sparsity_up, numel=numel)
    msgs.append(tern)
mean = jax.tree.map(lambda a,b: (a+b)/2, *msgs)
down, _ = stc_compress_tree(mean, tc.sparsity_down, numel=numel)
want = jax.tree.map(lambda p,d: p+d, params, down)
got = new_state["params"]
for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(want)[0],
        jax.tree_util.tree_flatten_with_path(got)[0]):
    np.testing.assert_allclose(np.asarray(b, np.float32),
                               np.asarray(a, np.float32),
                               rtol=5e-3, atol=5e-5, err_msg=str(pa))
print("DIST_MATCHES_REFERENCE")
"""
    r = _run(code)
    assert "DIST_MATCHES_REFERENCE" in r.stdout, r.stdout[-3000:] + r.stderr[-3000:]
