"""Golomb codec: roundtrip + analytic model (Eqs. 15-17) validation."""


import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal deterministic fallback (see the stub)
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import golomb


class TestAnalytic:
    def test_b_star_paper_value(self):
        # Paper quotes b̄_pos = 8.38 at p = 0.01, which corresponds to b* = 7;
        # the paper's own b* formula (Eq. 17) yields b* = 6 -> b̄ = 8.108,
        # which is strictly BETTER (fewer bits).  We follow the formula; the
        # ~x1.9 compression-vs-16-bit-distance claim still holds.
        assert golomb.golomb_b_star(0.01) == 6
        assert golomb.golomb_position_bits(0.01) == pytest.approx(8.108, abs=0.01)
        assert 16.0 / golomb.golomb_position_bits(0.01) == pytest.approx(1.9, abs=0.1)

    def test_entropy_gain_paper_value(self):
        # paper: ternarization gain H_sparse/H_STC = 4.414 at p = 0.01
        gain = golomb.entropy_sparse(0.01) / golomb.entropy_sparse_ternary(0.01)
        assert gain == pytest.approx(4.414, abs=0.01)

    def test_message_sizes_ordering(self):
        n = 100_000
        stc = golomb.stc_message_bits(n, 1 / 400)
        dense = golomb.fedavg_message_bits(n)
        sign = golomb.signsgd_message_bits(n)
        assert stc < sign < dense
        # x1050 compression claim at p=1/400 (within 15%)
        assert dense / stc == pytest.approx(1050, rel=0.15)


class TestCodec:
    def _random_ternary(self, n, p, seed):
        rng = np.random.default_rng(seed)
        x = np.zeros(n, np.float32)
        k = max(int(n * p), 1)
        idx = rng.choice(n, size=k, replace=False)
        mu = abs(float(rng.standard_normal())) + 0.1
        x[idx] = mu * rng.choice([-1.0, 1.0], size=k)
        return x, mu

    @given(st.integers(16, 3000), st.floats(0.005, 0.2),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip(self, n, p, seed):
        x, _ = self._random_ternary(n, p, seed)
        payload, bit_len, mu, n_out = golomb.encode_ternary(x, p)
        dec = golomb.decode_ternary(payload, bit_len, mu, n_out, p)
        np.testing.assert_allclose(dec, x, atol=1e-6)

    def test_empty_tensor(self):
        x = np.zeros(100, np.float32)
        payload, bit_len, mu, n = golomb.encode_ternary(x, 0.01)
        assert bit_len == 0 and len(payload) == 0
        dec = golomb.decode_ternary(payload, bit_len, mu, n, 0.01)
        np.testing.assert_array_equal(dec, x)

    def test_payload_is_packed_bytes(self):
        """Satellite fix: payload is bit-packed uint8 bytes (ceil(bits/8)),
        not the old one-BIT-per-uint8 blowup."""
        x, _ = self._random_ternary(4096, 0.05, seed=11)
        payload, bit_len, _, _ = golomb.encode_ternary(x, 0.05)
        assert payload.dtype == np.uint8
        assert len(payload) == (bit_len + 7) // 8
        # MSB-first convention: re-unpacking must give bit_len used bits
        assert int(np.unpackbits(payload)[bit_len:].sum()) == 0

    def test_measured_bits_match_analytic(self):
        """Real bitstream length ≈ Eq. 17 expectation (random sparsity)."""
        n, p = 200_000, 0.01
        x, _ = self._random_ternary(n, p, seed=3)
        _, bit_len, _, _ = golomb.encode_ternary(x, p)
        k = int(n * p)
        expected = k * (golomb.golomb_position_bits(p) + 1.0)
        assert bit_len == pytest.approx(expected, rel=0.02)

    def test_stream_bound_holds(self):
        """stc_stream_bound_bits is a TRUE ceiling on the measured stream."""
        for seed, p in [(3, 0.01), (4, 0.05), (5, 0.2)]:
            n = 30_000
            x, _ = self._random_ternary(n, p, seed)
            _, bit_len, _, _ = golomb.encode_ternary(x, p)
            nnz = int(np.count_nonzero(x))
            assert bit_len + 32 <= golomb.stc_stream_bound_bits(n, nnz, p)

    def test_dense_edge(self):
        """p close to 1: gaps all 1, codec must still roundtrip."""
        x = np.ones(64, np.float32) * 0.5
        x[::7] *= -1
        payload, bit_len, mu, n = golomb.encode_ternary(x, 0.9)
        dec = golomb.decode_ternary(payload, bit_len, mu, n, 0.9)
        np.testing.assert_allclose(dec, x, atol=1e-6)
