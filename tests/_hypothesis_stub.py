"""Minimal deterministic stand-in for ``hypothesis`` on images without it.

Implements just the surface the test-suite uses (``given``, ``settings``,
``strategies.integers/floats``): each ``@given`` test runs over a fixed number
of seeded pseudo-random examples instead of hypothesis' adaptive search.  The
real package is preferred whenever importable (see the try/except at the test
modules' import sites).
"""

from __future__ import annotations

import functools
import inspect
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))


_MAX_EXAMPLES = [25]


def settings(*, max_examples: int = 25, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        n_default = getattr(fn, "_stub_max_examples", _MAX_EXAMPLES[0])

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", n_default)
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                drawn = tuple(s.example(rng) for s in strats)
                fn(*args, *drawn, **kwargs)

        # hide the drawn parameters from pytest's fixture resolution
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())[: -len(strats)]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper
    return deco
