"""k-selection bisection accuracy contract (§Perf A3): 12 rounds keep the
selected count within 1% of k on Gaussian-like updates."""

import jax.numpy as jnp
import numpy as np

from repro.core.distributed import stc_compress_tree


def test_bisection_iteration_accuracy():
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.standard_normal(500_000), jnp.float32)}
    k = max(int(500_000 / 400), 1)
    _, st32 = stc_compress_tree(tree, 1 / 400, iters=32)
    _, st12 = stc_compress_tree(tree, 1 / 400, iters=12)
    assert int(st32.nnz) == k
    assert abs(int(st12.nnz) - k) / k < 0.01
