"""k-selection accuracy contracts on the tree path.

The histogram selector is exact, so ``iters`` only matters for the bisection
fallback — force that route (tiny ``cap`` overflows the refinement gather) to
keep the §Perf A3 contract tested: 12 rounds keep the selected count within
1% of k on Gaussian-like updates; 32 rounds are exact.
"""

import jax.numpy as jnp
import numpy as np

from repro.core.distributed import stc_compress_tree


def test_histogram_selection_exact():
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.standard_normal(500_000), jnp.float32)}
    k = max(int(500_000 / 400), 1)
    _, st = stc_compress_tree(tree, 1 / 400)
    assert int(st.nnz) == k


def test_bisection_fallback_iteration_accuracy():
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.standard_normal(500_000), jnp.float32)}
    k = max(int(500_000 / 400), 1)
    # cap=8 < k routes to the histogram path and overflows the candidate
    # bin, exercising the bisection fallback with the given iters budget
    _, st32 = stc_compress_tree(tree, 1 / 400, iters=32, cap=8)
    _, st12 = stc_compress_tree(tree, 1 / 400, iters=12, cap=8)
    assert int(st32.nnz) == k
    assert abs(int(st12.nnz) - k) / k < 0.01
