"""MoE dispatch equivalence + sharding-rule fitting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import MoEConfig
from repro.models.moe import moe_apply, moe_init

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)


class TestCapacityDispatch:
    def _setup(self, e=8, k=2, d=64, d_e=32, t=(2, 16)):
        cfg = MoEConfig(n_experts=e, top_k=k, n_shared=1, d_expert=d_e)
        params = moe_init(KEY, d, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), t + (d,))
        return cfg, params, x

    def test_matches_ragged_without_drops(self):
        cfg, params, x = self._setup()
        big = dataclasses.replace(cfg, dispatch="capacity",
                                  capacity_factor=8.0)
        o_r, aux_r = moe_apply(params, x, cfg)
        o_c, aux_c = moe_apply(params, x, big)
        np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r),
                                   atol=3e-5)
        np.testing.assert_allclose(float(aux_c), float(aux_r), rtol=1e-5)

    def test_dropping_is_bounded(self):
        """At cf=1.0 output differs only on dropped tokens; overall close."""
        cfg, params, x = self._setup()
        tight = dataclasses.replace(cfg, dispatch="capacity",
                                    capacity_factor=1.0)
        o_r, _ = moe_apply(params, x, cfg)
        o_c, _ = moe_apply(params, x, tight)
        rel = float(jnp.linalg.norm(o_c - o_r) / jnp.linalg.norm(o_r))
        assert rel < 0.5  # dropped mass is a minority of tokens
        assert bool(jnp.all(jnp.isfinite(o_c)))

    def test_gradients_finite(self):
        cfg, params, x = self._setup()
        cap = dataclasses.replace(cfg, dispatch="capacity")
        g = jax.grad(lambda p: jnp.sum(moe_apply(p, x, cap)[0] ** 2))(params)
        for leaf in jax.tree.leaves(g):
            assert bool(jnp.all(jnp.isfinite(leaf)))

    @pytest.mark.parametrize("e,k", [(4, 1), (8, 2), (16, 4)])
    def test_shapes_sweep(self, e, k):
        cfg, params, x = self._setup(e=e, k=k)
        cap = dataclasses.replace(cfg, dispatch="capacity")
        out, aux = moe_apply(params, x, cap)
        assert out.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(out)))


class TestFitSpec:
    def test_divisible_kept_nondivisible_dropped(self):
        if len(jax.devices()) < 4:
            pytest.skip("needs multi-device mesh")

    def test_fit_spec_pure(self):
        """fit_spec logic via a fake mesh-shape mapping."""
        from jax.sharding import PartitionSpec as P

        class FakeMesh:
            shape = {"model": 16, "data": 16, "pod": 2}

        from repro.sharding.rules import fit_spec
        # divisible: kept
        assert fit_spec(P("model", None), (49152, 64), FakeMesh()) == \
            P("model", None)
        # non-divisible vocab: dropped to replication
        assert fit_spec(P("model", None), (51865, 64), FakeMesh()) == \
            P(None, None)
        # tuple axes
        assert fit_spec(P(("pod", "data"), None), (64, 8), FakeMesh()) == \
            P(("pod", "data"), None)
        assert fit_spec(P(("pod", "data"), None), (33, 8), FakeMesh()) == \
            P(None, None)
        # KV heads smaller than the axis
        assert fit_spec(P(None, None, "model", None), (1, 2, 2, 64),
                        FakeMesh()) == P(None, None, None, None)
