"""Event-driven server subsystem: the deterministic event clock, the
K-arrival-triggered EventLoop/EventDrivenTrainer, the scenario library and
the client-sampler registry.

The load-bearing guarantee: with ``k_arrivals`` = cohort size (and the
default one-cohort concurrency) the event trainer consumes exactly one
dispatch cohort per aggregation, in dispatch order, through the SAME two
jitted phases as :class:`FederatedTrainer` -- so params, both ledgers and
the wire_log must match bit for bit under any no-loss scenario."""

import dataclasses
import math

import numpy as np
import pytest

from repro.core import make_protocol
from repro.data import make_classification
from repro.fed import (EventClock, EventDrivenTrainer, EventLoop,
                       FederatedTrainer, FedEnvironment, LatencyModel,
                       TrainerConfig, make_sampler, make_scenario,
                       registered_samplers, registered_scenarios,
                       simulate_scenario)
from repro.fed.sampling import SamplerView
from repro.fed.scenarios import Scenario, SteadyScenario
from repro.models.paper_models import MODEL_ZOO


@pytest.fixture(scope="module")
def data():
    return make_classification(seed=0, n=900, n_test=240)


def _env(n_clients=6, participation=0.5):
    return FedEnvironment(n_clients=n_clients, participation=participation,
                          classes_per_client=2, batch_size=10)


def _stc():
    return make_protocol("stc", sparsity_up=1 / 20, sparsity_down=1 / 20)


# ---------------------------------------------------------------------------
# event clock + event loop determinism
# ---------------------------------------------------------------------------


class TestEventClock:
    def test_equal_times_pop_in_push_order(self):
        """The heap tie-breaking invariant: (time, push-seq) strict order,
        payloads never compared (unorderable payloads must be fine)."""
        clock = EventClock()
        for i, t in enumerate([2.0, 1.0, 1.0, 3.0, 1.0]):
            clock.push(t, {"i": i})      # dicts are unorderable: seq decides
        got = [(t, item["i"]) for t, _, item in
               (clock.pop() for _ in range(5))]
        assert got == [(1.0, 1), (1.0, 2), (1.0, 4), (2.0, 0), (3.0, 3)]
        assert clock.now == 3.0

    def test_rejects_bad_times_and_empty_pops(self):
        clock = EventClock()
        with pytest.raises(ValueError, match="finite"):
            clock.push(math.inf, "x")
        with pytest.raises(ValueError, match="finite"):
            clock.push(-1.0, "x")
        with pytest.raises(IndexError):
            clock.pop()
        with pytest.raises(IndexError):
            clock.peek_time()


class TestEventLoopDeterminism:
    def _trace(self, seed):
        scen = make_scenario("regional-outage",
                             latency=LatencyModel(mean=0.8, sigma=0.6,
                                                  hetero=0.5,
                                                  straggler_frac=0.2))
        loop = EventLoop(scen, 32, cohort=4, k_arrivals=4, concurrency=8,
                         max_staleness=1, seed=seed)
        rng = np.random.default_rng(123)    # sampler rng, fixed across seeds
        trace = []
        for _ in range(6):
            while not loop.ready():
                if loop.wants_dispatch:
                    loop.dispatch(rng.choice(32, size=4, replace=False))
                else:
                    ev = loop.step()
                    trace.append((ev.kind, round(ev.t, 12), ev.client,
                                  ev.staleness, ev.dseq))
            loop.take_round()
        return trace

    def test_same_seed_same_event_trace(self):
        assert self._trace(5) == self._trace(5)

    def test_different_seed_different_trace(self):
        assert self._trace(5) != self._trace(6)

    def test_take_round_orders_by_dispatch_sequence(self):
        """Arrivals race, but an aggregation batch is consumed oldest
        dispatch first -- the invariant behind the K=cohort bit-identity."""
        scen = SteadyScenario(latency=LatencyModel(mean=1.0, sigma=1.5))
        loop = EventLoop(scen, 16, cohort=8, k_arrivals=8, concurrency=8,
                         max_staleness=0, seed=0)
        loop.dispatch(np.arange(8))
        while not loop.ready():
            loop.step()
        kept = loop.take_round()
        assert [r.dseq for r in kept] == list(range(8))
        assert loop.version == 1 and loop.buffer == []

    def test_drain_after_final_agg_does_not_deflate_rate(self):
        """Regression: ``stats()["aggs_per_time"]`` divides by the LAST
        aggregation's timestamp, not the drained clock -- serving post-final
        arrivals (or idling) must leave the throughput figure untouched."""
        scen = SteadyScenario(latency=LatencyModel(mean=1.0, sigma=0.5))
        loop = EventLoop(scen, 16, cohort=4, k_arrivals=4, concurrency=8,
                         max_staleness=1, seed=0)
        loop.dispatch(np.arange(4))
        while not loop.ready():
            loop.step()
        loop.take_round()
        t_agg = loop.last_agg_t
        assert t_agg == loop.clock.now > 0.0
        rate = loop.stats()["aggs_per_time"]
        assert rate == pytest.approx(1.0 / t_agg)
        # drain: three more arrivals land (sub-K: no aggregation) and the
        # clock moves past the final aggregation
        loop.dispatch(np.arange(4, 8))
        for _ in range(3):
            loop.step()
        assert loop.clock.now > t_agg and loop.version == 1
        assert loop.stats()["aggs_per_time"] == pytest.approx(rate)

    def test_loop_validates_configuration(self):
        scen = SteadyScenario()
        with pytest.raises(ValueError, match="k_arrivals"):
            EventLoop(scen, 8, cohort=2, k_arrivals=0, concurrency=4,
                      max_staleness=1)
        with pytest.raises(ValueError, match="concurrency"):
            EventLoop(scen, 8, cohort=4, k_arrivals=2, concurrency=2,
                      max_staleness=1)
        with pytest.raises(ValueError, match="max_staleness"):
            EventLoop(scen, 8, cohort=2, k_arrivals=2, concurrency=4,
                      max_staleness=-1)
        with pytest.raises(ValueError, match="cohort"):
            EventLoop(scen, 8, cohort=9, k_arrivals=2, concurrency=16,
                      max_staleness=1)


# ---------------------------------------------------------------------------
# event trainer: bit-identity + quiescence + staleness drops
# ---------------------------------------------------------------------------


class TestEventDrivenTrainer:
    @pytest.mark.parametrize("name", ["stc", "signsgd"])
    def test_k_cohort_bit_identical_to_synchronous(self, data, name):
        """Acceptance: K = cohort + on-time (homogeneous) latencies ==
        FederatedTrainer bit for bit -- params, measured AND analytic
        ledgers, wire_log, shared history columns."""
        train, test = data
        kw = {"stc": dict(sparsity_up=1 / 20, sparsity_down=1 / 20)}
        rounds = 4
        sync = FederatedTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                                make_protocol(name, **kw.get(name, {})),
                                TrainerConfig(lr=0.05, seed=0))
        sync.run(rounds, eval_every=2)
        ev = EventDrivenTrainer(
            MODEL_ZOO["logreg"], train, test, _env(),
            make_protocol(name, **kw.get(name, {})),
            TrainerConfig(lr=0.05, seed=0),
            scenario=SteadyScenario(latency=LatencyModel(mean=3.0,
                                                         sigma=0.0)))
        ev.run(rounds, eval_every=2)
        np.testing.assert_array_equal(np.asarray(sync.params_vec),
                                      np.asarray(ev.params_vec))
        assert sync.bits_up == ev.bits_up
        assert sync.bits_down == ev.bits_down
        assert sync.bits_up_analytic == ev.bits_up_analytic
        assert sync.bits_down_analytic == ev.bits_down_analytic
        assert sync.wire_log == ev.wire_log
        for hs, hb in zip(sync.history, ev.history):
            for key in hs:          # shared columns identical
                assert hs[key] == hb[key], key
        assert ev.n_dropped == 0 and ev.n_lost == 0

    def test_k_cohort_bit_identical_under_heterogeneous_latency(self, data):
        """Stronger than the acceptance bar: because the buffer is consumed
        in dispatch order, identity survives racing heterogeneous arrivals
        as long as nothing is lost or dropped."""
        train, test = data
        rounds = 3
        sync = FederatedTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                                _stc(), TrainerConfig(lr=0.05, seed=0))
        sync.run(rounds, eval_every=rounds)
        ev = EventDrivenTrainer(
            MODEL_ZOO["logreg"], train, test, _env(), _stc(),
            TrainerConfig(lr=0.05, seed=0),
            scenario=SteadyScenario(latency=LatencyModel(mean=0.7, sigma=0.9,
                                                         hetero=0.8)))
        ev.run(rounds, eval_every=rounds)
        np.testing.assert_array_equal(np.asarray(sync.params_vec),
                                      np.asarray(ev.params_vec))
        assert sync.wire_log == ev.wire_log

    def test_zero_arrival_quiescence_freezes_server(self, data):
        """advance_to with nothing in flight serves zero events and leaves
        params, the server codec state and every ledger untouched."""
        train, test = data
        tr = EventDrivenTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                                _stc(), TrainerConfig(lr=0.05, seed=0))
        params0 = np.asarray(tr.params_vec).copy()
        res0 = np.asarray(tr.server_state.residual).copy()
        assert tr.advance_to(1e9) == 0
        assert tr.bits_up == 0.0 and tr.bits_down == 0.0
        assert tr.wire_log == [] and tr.agg_log == [] and tr.round == 0
        np.testing.assert_array_equal(np.asarray(tr.params_vec), params0)
        np.testing.assert_array_equal(np.asarray(tr.server_state.residual),
                                      res0)
        # sub-K arrivals buffer but never aggregate: still quiescent
        tr._dispatch_cohort()
        k_minus_1 = tr.k_arrivals - 1
        served = 0
        while served < k_minus_1:
            served += tr.advance_to(tr.loop.clock.peek_time())
        assert tr.round == 0 and len(tr.loop.buffer) == k_minus_1
        np.testing.assert_array_equal(np.asarray(tr.params_vec), params0)
        np.testing.assert_array_equal(np.asarray(tr.server_state.residual),
                                      res0)

    def test_advance_to_quiesces_under_lossy_scenario(self, data):
        """Under heavy loss + chaos, advance_to(T) for large T must drain
        every in-flight event to a quiescent loop (nothing pending, clock
        empty) with the conservation ledger intact -- lost updates vanish
        from the heap without wedging the server."""
        from repro.fed import make_fault

        train, test = data
        for faults in (None, make_fault("duplicate", prob=0.8)):
            tr = EventDrivenTrainer(
                MODEL_ZOO["logreg"], train, test, _env(), _stc(),
                TrainerConfig(lr=0.05, seed=0), scenario="regional-outage",
                k_arrivals=2, concurrency=4, max_staleness=2, faults=faults)
            for _ in range(3):
                tr._dispatch_cohort()
            served = tr.advance_to(1e9)
            loop = tr.loop
            assert len(loop.clock) == 0 and loop.n_inflight == 0
            assert loop.n_dispatched + loop.n_injected == served
            assert served == (loop.n_arrived + loop.n_dropped + loop.n_lost
                              + loop.n_duplicates + loop.n_quarantined)
            # a further advance on the quiescent loop is a no-op
            assert tr.advance_to(2e9) == 0
            st = loop.stats()
            assert 0.0 <= st["drop_rate"] <= 1.0
            assert 0.0 <= st["duplicate_rate"] <= 1.0

    def test_total_loss_scenario_fails_loudly(self, data):
        """A scenario that loses every update must raise, not spin forever."""

        @dataclasses.dataclass(frozen=True)
        class BlackHole(Scenario):
            name = "black-hole-test"

            def loss_prob(self, t, ids):
                return np.ones(np.asarray(ids).size, np.float64)

        train, test = data
        tr = EventDrivenTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                                _stc(), TrainerConfig(lr=0.05, seed=0),
                                scenario=BlackHole())
        with pytest.raises(RuntimeError, match="starved"):
            tr.run_round()
        assert tr.n_lost > 0 and tr.bits_up == 0.0    # lost bills nothing

    def test_staleness_drops_bill_bits_but_never_aggregate(self, data):
        """K < concurrency overlap: updates arriving > max_staleness model
        versions after dispatch are dropped, their upload bits billed."""
        train, test = data
        env = _env(n_clients=8, participation=0.25)    # cohort of 2
        # huge latency spread: some updates land many aggregations late
        scen = SteadyScenario(latency=LatencyModel(mean=1.0, sigma=2.0,
                                                   hetero=1.0))
        tr = EventDrivenTrainer(MODEL_ZOO["logreg"], train, test, env,
                                _stc(), TrainerConfig(lr=0.05, seed=0),
                                scenario=scen, k_arrivals=2, concurrency=8,
                                max_staleness=0)
        tr.run(8, eval_every=8)
        drops = [r for r in tr.event_log if r["kind"] == "drop"]
        assert tr.n_dropped == len(drops) > 0
        assert all(r["staleness"] > 0 and r["bits_up"] > 0.0 for r in drops)
        assert np.all(np.isfinite(np.asarray(tr.params_vec)))
        # conservation: every billed event is an arrival or a drop
        billed = [r for r in tr.event_log if r["kind"] in ("arrival", "drop")]
        agg_total = sum(r["aggregated"] for r in tr.agg_log)
        assert agg_total + tr.n_dropped == len(billed)

    def test_event_ingest_matches_dense_aggregation(self, data):
        """TrainerConfig(ingest=True) rides the fused decode->aggregate
        path; params must match the dense event trainer to summation-order
        noise (the fused path accumulates in a different order, same as the
        buffered trainer's ingest mode)."""
        train, test = data
        kw = dict(scenario=SteadyScenario(latency=LatencyModel(mean=0.8,
                                                               sigma=0.4)),
                  k_arrivals=3, concurrency=6, max_staleness=4)
        dense = EventDrivenTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                                   _stc(), TrainerConfig(lr=0.05, seed=0),
                                   **kw)
        dense.run(4, eval_every=4)
        fused = EventDrivenTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                                   _stc(),
                                   TrainerConfig(lr=0.05, seed=0,
                                                 ingest=True), **kw)
        fused.run(4, eval_every=4)
        np.testing.assert_allclose(np.asarray(dense.params_vec),
                                   np.asarray(fused.params_vec),
                                   rtol=1e-5, atol=1e-7)
        assert dense.bits_up == pytest.approx(fused.bits_up)
        assert dense.n_dropped == fused.n_dropped
        assert dense.n_lost == fused.n_lost

    def test_legacy_codec_without_mask_api_is_rejected(self):
        """The pre-mask 2-arg ``tree_reduce`` override is equally dead:
        the class definition itself raises, naming the migration."""
        from repro.core import Codec
        from repro.core.protocols import _REGISTRY
        import jax.numpy as jnp

        with pytest.raises(TypeError, match="masked aggregation API"):
            @dataclasses.dataclass(frozen=True)
            class LegacyMeanEv(Codec):
                name = "legacy-mean-events-test"

                def tree_reduce(self, msgs, axes, n_clients):   # pre-mask
                    return msgs

        assert "legacy-mean-events-test" not in _REGISTRY


# ---------------------------------------------------------------------------
# scenario library
# ---------------------------------------------------------------------------


class TestScenarios:
    def test_registry_rejects_unknown_names_loudly(self):
        with pytest.raises(KeyError, match="steady"):
            make_scenario("no-such-scenario")

    def test_every_registered_scenario_simulates(self):
        """Model-free 3-aggregation smoke through the event loop for every
        registration: conservation + determinism per scenario."""
        assert len(registered_scenarios()) >= 5
        for name in registered_scenarios():
            a = simulate_scenario(name, n_clients=48, cohort=6,
                                  concurrency=12, max_staleness=2,
                                  aggregations=3, seed=3)
            b = simulate_scenario(name, n_clients=48, cohort=6,
                                  concurrency=12, max_staleness=2,
                                  aggregations=3, seed=3)
            assert a == b, name
            assert a["aggregations"] == 3
            assert (a["arrived"] + a["dropped"] + a["lost"] + a["pending"]
                    == a["dispatched"]), name
            assert a["sim_time"] > 0.0 and a["aggs_per_time"] > 0.0

    @pytest.mark.parametrize("name", sorted(registered_scenarios()))
    def test_every_registered_scenario_trains_3_rounds(self, data, name):
        """Satellite acceptance: every registration round-trips through a
        3-round training smoke on the event trainer."""
        train, test = data
        tr = EventDrivenTrainer(MODEL_ZOO["logreg"], train, test,
                                _env(n_clients=8, participation=0.25),
                                _stc(), TrainerConfig(lr=0.05, seed=0),
                                scenario=name, k_arrivals=2, concurrency=4,
                                max_staleness=3)
        hist = tr.run(3, eval_every=3)
        assert tr.round == 3 and len(hist) == 1
        assert np.all(np.isfinite(np.asarray(tr.params_vec)))
        assert hist[-1]["sim_time"] > 0.0

    def test_scenario_hooks_shape_the_fleet(self):
        rng = np.random.default_rng(0)
        ids = np.arange(64)
        scales = np.ones(64)
        # diurnal: mid-period latency strictly above trough latency
        di = make_scenario("diurnal", latency=LatencyModel(sigma=0.0))
        lat0, _ = di.sample(0.0, ids, scales, rng)
        lat_mid, _ = di.sample(di.period / 2.0, ids, scales, rng)
        np.testing.assert_allclose(lat_mid, lat0 * (1.0 + di.amp))
        # flash crowd: surge inside the window only
        fc = make_scenario("flash-crowd", latency=LatencyModel(sigma=0.0))
        inside, _ = fc.sample(fc.start, ids, scales, rng)
        outside, _ = fc.sample(fc.start + fc.width, ids, scales, rng)
        np.testing.assert_allclose(inside, outside * fc.surge)
        # regional outage: losses concentrate on ONE region inside the window
        ro = make_scenario("regional-outage", loss=1.0)
        _, lost = ro.sample(0.0, ids, scales, rng)
        assert set(ids[lost] % ro.regions) == {0}
        assert not ro.sample(ro.width, ids, scales, rng)[1].any()
        # straggler drift: the slow subpopulation slows with time
        sd = make_scenario("straggler-drift",
                           latency=LatencyModel(sigma=0.0))
        early, _ = sd.sample(0.0, ids, scales, rng)
        late, _ = sd.sample(10.0, ids, scales, rng)
        slow = late > early * 1.5
        assert 0 < slow.sum() < ids.size            # both populations exist
        # adaptive deadline: exactly the draws beyond factor x own median
        ad = make_scenario("adaptive-deadline",
                           latency=LatencyModel(sigma=0.8))
        lats, lost = ad.sample(0.0, ids, scales, rng)
        np.testing.assert_array_equal(
            lost, lats > ad.factor * scales * ad.latency.mean)
        assert 0 < lost.sum() < ids.size

    def test_scenario_validation_is_typed(self):
        with pytest.raises(ValueError, match="period"):
            make_scenario("diurnal", period=0.0)
        with pytest.raises(ValueError, match="loss"):
            make_scenario("regional-outage", loss=1.5)
        with pytest.raises(ValueError, match="frac"):
            make_scenario("straggler-drift", frac=-0.1)
        with pytest.raises(ValueError, match="factor"):
            make_scenario("adaptive-deadline", factor=0.0)


# ---------------------------------------------------------------------------
# client sampler registry
# ---------------------------------------------------------------------------


class TestSamplers:
    def test_registry_rejects_unknown_names_loudly(self):
        with pytest.raises(KeyError, match="uniform"):
            make_sampler("no-such-sampler")
        assert set(registered_samplers()) >= {"uniform", "staleness"}

    def test_uniform_matches_synchronous_selection_exactly(self):
        """The byte-for-byte contract behind the K=cohort bit-identity."""
        view = SamplerView(0, np.zeros(20, np.int64), np.zeros(20, bool))
        got = make_sampler("uniform").select(
            np.random.default_rng(9), view, 5)
        want = np.random.default_rng(9).choice(20, size=5, replace=False)
        np.testing.assert_array_equal(got, want)

    def test_staleness_sampler_prefers_unseen_and_skips_inflight(self):
        n = 40
        last_seen = np.zeros(n, np.int64)
        last_seen[: n // 2] = 99            # first half just participated
        inflight = np.zeros(n, bool)
        inflight[0] = True
        view = SamplerView(100, last_seen, inflight)
        smp = make_sampler("staleness", bias=3.0)
        rng = np.random.default_rng(0)
        picks = np.concatenate([smp.select(rng, view, 8) for _ in range(40)])
        assert not (picks == 0).any()               # in-flight never picked
        stale_frac = (picks >= n // 2).mean()
        assert stale_frac > 0.9                      # stale half dominates
        # duplicate-free cohorts
        one = smp.select(rng, view, 8)
        assert len(set(one.tolist())) == 8

    def test_staleness_sampler_readmits_inflight_when_starved(self):
        view = SamplerView(5, np.zeros(4, np.int64), np.ones(4, bool))
        got = make_sampler("staleness").select(
            np.random.default_rng(1), view, 3)
        assert len(set(got.tolist())) == 3

    def test_staleness_sampler_never_starves_unseen_clients(self):
        """Regression: a zero-initialized ``last_seen`` made never-seen
        clients tie with clients genuinely sampled at round 0.  With the
        ``seen`` mask they carry age ``round + 1`` -- strictly the oldest --
        so at bias > 0 an unseen client outweighs a round-0 participant."""
        n = 10
        last_seen = np.zeros(n, np.int64)        # all zeros: ambiguous
        seen = np.ones(n, bool)
        seen[-1] = False                          # client 9 never dispatched
        view = SamplerView(0, last_seen, np.zeros(n, bool), seen)
        smp = make_sampler("staleness", bias=6.0)
        rng = np.random.default_rng(3)
        picks = np.concatenate([smp.select(rng, view, 1)
                                for _ in range(200)])
        # age 1 vs age 0 at bias 6 => 2^6 : 1 odds per draw
        assert (picks == n - 1).mean() > 0.5
        # legacy callers without the mask keep the old (ambiguous) reading
        legacy = SamplerView(0, last_seen, np.zeros(n, bool))
        picks = np.concatenate([smp.select(rng, legacy, 1)
                                for _ in range(50)])
        assert len(set(picks.tolist())) > 1

    def test_event_trainer_marks_seen_and_reaches_every_client(self, data):
        """End to end: the trainer feeds its seen mask to the sampler, so at
        a strong staleness bias every client is dispatched early on instead
        of starving behind round-0 ties."""
        train, test = data
        tr = EventDrivenTrainer(MODEL_ZOO["logreg"], train, test,
                                _env(n_clients=8, participation=0.25),
                                _stc(), TrainerConfig(lr=0.05, seed=0),
                                sampler=make_sampler("staleness", bias=8.0))
        tr.run(5, eval_every=5)
        assert tr.seen_mask.all()

    def test_event_trainer_runs_with_staleness_sampler(self, data):
        train, test = data
        tr = EventDrivenTrainer(MODEL_ZOO["logreg"], train, test,
                                _env(n_clients=8, participation=0.25),
                                _stc(), TrainerConfig(lr=0.05, seed=0),
                                sampler="staleness", k_arrivals=2,
                                concurrency=4, max_staleness=3)
        tr.run(3, eval_every=3)
        assert np.all(np.isfinite(np.asarray(tr.params_vec)))


# ---------------------------------------------------------------------------
# arrivals edge cases (satellite)
# ---------------------------------------------------------------------------


class TestArrivalEdgeCases:
    def test_latency_model_validates_fields_with_typed_errors(self):
        with pytest.raises(ValueError, match="mean"):
            LatencyModel(mean=0.0)
        with pytest.raises(ValueError, match="mean"):
            LatencyModel(mean=-1.0)
        with pytest.raises(ValueError, match="sigma"):
            LatencyModel(sigma=-0.1)
        with pytest.raises(ValueError, match="hetero"):
            LatencyModel(hetero=-0.5)
        with pytest.raises(ValueError, match="straggler_frac"):
            LatencyModel(straggler_frac=1.5)
        with pytest.raises(ValueError, match="straggler_scale"):
            LatencyModel(straggler_scale=0.0)

    def test_exact_deadline_multiples_bucket_deterministically(self):
        """0.3 / 0.1 == 2.999...96 in binary floating point: an exact
        multiple of the deadline must STILL bucket as L/deadline rounds
        late, whatever the platform's division rounding did."""
        from repro.fed import ArrivalSimulator
        sim = ArrivalSimulator(LatencyModel(), n_clients=4, deadline=0.1)
        late = sim.rounds_late(np.asarray([0.3, 0.1, 0.25, 0.0999999999999]))
        np.testing.assert_array_equal(late, [3, 1, 2, 1])
        # and a genuinely-below-multiple latency still floors down
        np.testing.assert_array_equal(sim.rounds_late(np.asarray([0.29])),
                                      [2])

    def test_dispatch_with_latencies_matches_dispatch(self):
        from repro.fed import ArrivalSimulator
        lm = LatencyModel(mean=1.5, sigma=0.0)
        a = ArrivalSimulator(lm, n_clients=4, deadline=1.0, seed=0)
        b = ArrivalSimulator(lm, n_clients=4, deadline=1.0, seed=0)
        lats = a.dispatch(0, [0, 1], ["x", "y"])
        b.dispatch_with_latencies(0, [0, 1], ["x", "y"], lats)
        assert a.collect(1) == b.collect(1)
        with pytest.raises(ValueError, match="latencies"):
            b.dispatch_with_latencies(0, [0, 1], ["x", "y"], [1.0])
