"""The pluggable AggregationRule registry (repro.core.aggregation) and its
adversarial complement (repro.fed.faults Byzantine valid-update faults).

The load-bearing guarantees:

* ``rule="mean"`` is BIT-IDENTICAL to the historical combine -- params,
  both ledgers and the wire_log -- across the synchronous, buffered and
  event-driven trainers, for stc AND signsgd;
* every registered rule satisfies the combine algebra (permutation
  invariance, zero-weight-row invariance, masked == compacted);
* ``coordinate_median`` survives any f < P/2 sign-flipping cohort at the
  rule level (its breakdown point) while ``mean`` demonstrably does not;
* the ``Codec(norm_bound=...)`` shim deprecates into
  ``rule=norm_screened_mean(...)`` with bit-identical behavior;
* Byzantine faults rewrite payloads that remain VALID wire messages by
  construction (``validate_wire`` passes after the attack).
"""

import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_protocol
from repro.core.aggregation import (AggregationRule, CoordinateMedianRule,
                                    MeanRule, NormScreenedMeanRule,
                                    TrimmedMeanRule, get_rule_class,
                                    make_rule, register_rule,
                                    registered_rules)
from repro.core.registry import resolve
from repro.data import make_classification
from repro.fed import (BufferedFederatedTrainer, CollusionFault,
                       EventDrivenTrainer, FedEnvironment, FederatedTrainer,
                       LatencyModel, ScaleAttackFault, SignFlipFault,
                       TrainerConfig, make_fault, make_sampler,
                       make_scenario)
from repro.fed.faults import _rewrite_valid
from repro.fed.scenarios import SteadyScenario
from repro.models.paper_models import MODEL_ZOO

RULES = registered_rules()


def _msgs(p=7, d=24, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((p, d)), jnp.float32)


def _weights(p=7, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0.2, 2.0, p), jnp.float32)


# ---------------------------------------------------------------------------
# rule algebra: every registered rule
# ---------------------------------------------------------------------------


class TestRuleAlgebra:
    @pytest.mark.parametrize("name", RULES)
    def test_permutation_invariance(self, name):
        rule, msgs, w = make_rule(name), _msgs(), _weights()
        perm = np.random.default_rng(2).permutation(msgs.shape[0])
        np.testing.assert_allclose(
            np.asarray(rule.combine(msgs[perm], w[perm])),
            np.asarray(rule.combine(msgs, w)), rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("name", RULES)
    def test_zero_weight_row_is_invisible(self, name):
        """A weight-0 message must not move the combine -- however wild its
        contents (the combine-level half of the Byzantine story)."""
        rule, msgs, w = make_rule(name), _msgs(), _weights()
        garbage = 1e6 * jnp.ones((1, msgs.shape[1]), jnp.float32)
        msgs2 = jnp.concatenate([msgs, garbage])
        w2 = jnp.concatenate([w, jnp.zeros(1, jnp.float32)])
        np.testing.assert_allclose(np.asarray(rule.combine(msgs2, w2)),
                                   np.asarray(rule.combine(msgs, w)),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("name", RULES)
    def test_masked_equals_compacted(self, name):
        """Codec.combine with a 0/1 mask == combining only the surviving
        rows -- the contract the buffered/event trainers rely on."""
        codec = make_protocol("baseline", rule=make_rule(name))
        msgs = _msgs(p=8)
        mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 0], jnp.float32)
        kept = msgs[np.flatnonzero(np.asarray(mask))]
        np.testing.assert_allclose(
            np.asarray(codec.combine(msgs, mask)),
            np.asarray(codec.combine(kept, jnp.ones(kept.shape[0]))),
            rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("name", RULES)
    def test_staleness_decay_reweights(self, name):
        """combine(mask, staleness) == combine with the decayed weights
        folded into the mask: staleness is pure reweighting."""
        codec = make_protocol("baseline", rule=make_rule(name),
                              staleness_decay=1.0)
        msgs = _msgs(p=5)
        mask = jnp.ones(5, jnp.float32)
        stale = jnp.asarray([0, 1, 3, 0, 7], jnp.float32)
        w = np.asarray(codec.participation_weights(mask, stale))
        np.testing.assert_allclose(
            np.asarray(codec.combine(msgs, mask, stale)),
            np.asarray(codec.rule.combine(msgs, jnp.asarray(w))),
            rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("name", RULES)
    def test_zero_total_weight_combines_to_zero(self, name):
        rule = make_rule(name)
        out = rule.combine(_msgs(p=4), jnp.zeros(4, jnp.float32))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.zeros(out.shape, np.float32))


# ---------------------------------------------------------------------------
# rule-specific statistics
# ---------------------------------------------------------------------------


class TestRuleStatistics:
    @pytest.mark.parametrize("p", [5, 8])
    def test_median_matches_jnp_at_unit_weights(self, p):
        msgs = _msgs(p=p)
        np.testing.assert_allclose(
            np.asarray(make_rule("coordinate_median").combine(msgs)),
            np.median(np.asarray(msgs), axis=0), rtol=1e-6, atol=1e-7)

    def test_trimmed_beta0_is_the_weighted_mean(self):
        msgs, w = _msgs(), _weights()
        np.testing.assert_allclose(
            np.asarray(TrimmedMeanRule(beta=0.0).combine(msgs, w)),
            np.asarray(MeanRule().combine(msgs, w)), rtol=1e-5, atol=1e-6)

    def test_trimmed_clips_an_outlier_mean_does_not(self):
        msgs = jnp.concatenate([_msgs(p=9), 1e4 * jnp.ones((1, 24))])
        t = np.asarray(TrimmedMeanRule(beta=0.2).combine(msgs))
        m = np.asarray(MeanRule().combine(msgs))
        assert np.max(np.abs(t)) < 10.0 < np.min(np.abs(m))

    @pytest.mark.parametrize("f", [1, 2, 3, 4, 5])
    def test_median_breakdown_point(self, f):
        """P=11 messages, f of them sign-flipped at 10x: for every f < P/2
        the coordinate median stays inside the honest envelope, while the
        mean's direction flips as soon as 10f > P - f (f >= 2)."""
        p, d = 11, 16
        rng = np.random.default_rng(3)
        honest = 1.0 + 0.1 * rng.standard_normal((p - f, d))
        byz = -10.0 * (1.0 + 0.1 * rng.standard_normal((f, d)))
        msgs = jnp.asarray(np.concatenate([honest, byz]), jnp.float32)
        med = np.asarray(make_rule("coordinate_median").combine(msgs))
        assert np.all(med >= honest.min(axis=0) - 1e-6)
        assert np.all(med <= honest.max(axis=0) + 1e-6)
        assert np.all(med > 0)                       # honest direction
        mean = np.asarray(MeanRule().combine(msgs))
        if f >= 2:
            assert np.all(mean < 0)                  # captured by the cohort
        else:                # f=1 cancels exactly: dragged to the noise floor
            assert np.all(mean < 0.5)

    def test_norm_screen_reject_drops_only_oversized(self):
        msgs = jnp.concatenate([_msgs(p=6), 1e3 * jnp.ones((1, 24))])
        rule = NormScreenedMeanRule(bound=50.0, policy="reject")
        np.testing.assert_allclose(
            np.asarray(rule.combine(msgs)),
            np.asarray(MeanRule().combine(msgs[:6],
                                          jnp.ones(6, jnp.float32))),
            rtol=1e-5, atol=1e-6)

    def test_norm_screen_clip_rescales(self):
        msgs = 10.0 * jnp.ones((2, 4), jnp.float32)     # norm 20 per row
        rule = NormScreenedMeanRule(bound=10.0, policy="clip")
        np.testing.assert_allclose(np.asarray(rule.combine(msgs)),
                                   5.0 * np.ones((4,)), rtol=1e-5)


# ---------------------------------------------------------------------------
# registry + resolution
# ---------------------------------------------------------------------------


class TestRuleRegistry:
    def test_paper_rules_registered(self):
        for name in ("mean", "coordinate_median", "trimmed_mean",
                     "norm_screened_mean"):
            assert name in RULES
        assert get_rule_class("mean") is MeanRule

    def test_unknown_rule_lists_registered(self):
        with pytest.raises(KeyError) as ei:
            make_rule("nope")
        msg = str(ei.value)
        assert "unknown aggregation rule 'nope'" in msg
        for name in RULES:
            assert name in msg

    def test_instance_passes_through_untouched(self):
        r = TrimmedMeanRule(beta=0.3)
        assert make_rule(r) is r

    def test_overrides_on_an_instance_are_loud(self):
        with pytest.raises(TypeError, match="already-constructed"):
            make_rule(TrimmedMeanRule(), beta=0.3)

    def test_non_string_non_instance_is_loud(self):
        with pytest.raises(TypeError, match="aggregation rule"):
            make_rule(3.14)

    def test_duplicate_registration_is_loud(self):
        with pytest.raises(ValueError, match="already registered"):
            @register_rule
            @dataclasses.dataclass(frozen=True)
            class Impostor(AggregationRule):
                name = "mean"

    def test_every_registry_shares_the_error_shape(self):
        """Satellite: one resolve() behind every make_* factory -- the
        KeyError format is identical across protocol / scenario / sampler /
        fault / rule registries."""
        for factory, kind in ((make_protocol, "protocol"),
                              (make_scenario, "scenario"),
                              (make_sampler, "client sampler"),
                              (make_fault, "fault model"),
                              (make_rule, "aggregation rule")):
            with pytest.raises(KeyError) as ei:
                factory("definitely-not-registered")
            assert (f"unknown {kind} 'definitely-not-registered'; "
                    "registered:") in str(ei.value)

    def test_resolve_instantiates_with_overrides(self):
        out = resolve("aggregation rule", "trimmed_mean",
                      {"trimmed_mean": TrimmedMeanRule}, AggregationRule,
                      beta=0.25)
        assert out == TrimmedMeanRule(beta=0.25)

    def test_custom_rule_registration_roundtrip(self):
        from repro.core.aggregation import _REGISTRY

        @register_rule
        @dataclasses.dataclass(frozen=True)
        class MidrangeRule(AggregationRule):
            name = "midrange-test"

            def combine_weighted(self, msgs, weights):
                return 0.5 * (jnp.max(msgs, axis=0) + jnp.min(msgs, axis=0))

        try:
            codec = make_protocol("baseline", rule="midrange-test")
            out = codec.combine(jnp.asarray([[0.0], [1.0], [5.0]]))
            assert float(out[0]) == pytest.approx(2.5)
        finally:
            _REGISTRY.pop("midrange-test", None)


# ---------------------------------------------------------------------------
# mean bit-identity: the api_redesign acceptance bar
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def data():
    return make_classification(seed=0, n=900, n_test=240)


def _env(n_clients=6, participation=0.5):
    return FedEnvironment(n_clients=n_clients, participation=participation,
                          classes_per_client=2, batch_size=10)


def _proto(name, rule=None):
    kw = {"stc": dict(sparsity_up=1 / 20, sparsity_down=1 / 20)}.get(name, {})
    if rule is not None:
        kw["rule"] = rule
    return make_protocol(name, **kw)


def _assert_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.params_vec),
                                  np.asarray(b.params_vec))
    assert a.bits_up == b.bits_up and a.bits_down == b.bits_down
    assert a.bits_up_analytic == b.bits_up_analytic
    assert a.bits_down_analytic == b.bits_down_analytic
    assert a.wire_log == b.wire_log


class TestMeanBitIdentity:
    @pytest.mark.parametrize("name", ["stc", "signsgd"])
    def test_synchronous(self, data, name):
        train, test = data
        runs = []
        for rule in (None, "mean"):
            tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                                  _proto(name, rule),
                                  TrainerConfig(lr=0.05, seed=0))
            tr.run(3, eval_every=3)
            runs.append(tr)
        _assert_identical(*runs)

    @pytest.mark.parametrize("name", ["stc", "signsgd"])
    def test_buffered(self, data, name):
        train, test = data
        runs = []
        for rule in (None, "mean"):
            tr = BufferedFederatedTrainer(
                MODEL_ZOO["logreg"], train, test, _env(), _proto(name, rule),
                TrainerConfig(lr=0.05, seed=0),
                latency=LatencyModel(mean=1.0, sigma=0.6), deadline=1.5,
                max_staleness=3)
            tr.run(3, eval_every=3)
            runs.append(tr)
        _assert_identical(*runs)

    @pytest.mark.parametrize("name", ["stc", "signsgd"])
    def test_event_driven(self, data, name):
        train, test = data
        runs = []
        for rule in (None, "mean"):
            tr = EventDrivenTrainer(
                MODEL_ZOO["logreg"], train, test, _env(), _proto(name, rule),
                TrainerConfig(lr=0.05, seed=0),
                scenario=SteadyScenario(latency=LatencyModel(mean=0.7,
                                                             sigma=0.9)))
            tr.run(3, eval_every=3)
            runs.append(tr)
        _assert_identical(*runs)


# ---------------------------------------------------------------------------
# norm_bound deprecation shim
# ---------------------------------------------------------------------------


class TestNormBoundShim:
    def test_shim_warns_and_builds_the_rule(self):
        with pytest.warns(DeprecationWarning, match="norm_screened_mean"):
            codec = make_protocol("stc", norm_bound=2.0, norm_policy="reject")
        assert codec.rule == NormScreenedMeanRule(bound=2.0, policy="reject")

    def test_shim_is_bit_identical_to_the_rule(self, data):
        train, test = data
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            old = make_protocol("stc", sparsity_up=1 / 20,
                                sparsity_down=1 / 20, norm_bound=0.5)
        new = _proto("stc", NormScreenedMeanRule(bound=0.5, policy="clip"))
        runs = []
        for proto in (old, new):
            tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                                  proto, TrainerConfig(lr=0.05, seed=0))
            tr.run(3, eval_every=3)
            runs.append(tr)
        _assert_identical(*runs)

    def test_conflicting_shim_and_rule_is_loud(self):
        with pytest.raises(ValueError, match="norm_bound/norm_policy"):
            make_protocol("stc", norm_bound=2.0, rule="coordinate_median")

    def test_replace_on_a_shimmed_codec_stays_silent(self):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            codec = make_protocol("stc", norm_bound=2.0)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            replaced = dataclasses.replace(codec, sparsity_up=1 / 10)
        assert replaced.rule == codec.rule


# ---------------------------------------------------------------------------
# streaming declaration
# ---------------------------------------------------------------------------


class TestStreamingFallback:
    def test_rule_streaming_flags(self):
        assert MeanRule.supports_streaming
        assert NormScreenedMeanRule.supports_streaming
        assert not CoordinateMedianRule.supports_streaming
        assert not TrimmedMeanRule.supports_streaming

    def test_make_ingest_refuses_non_streaming_rules(self):
        codec = _proto("stc", "coordinate_median")
        with pytest.raises(NotImplementedError, match="cannot stream"):
            codec.make_ingest(100)

    def test_trainer_falls_back_loudly_and_identically(self, data):
        """ingest=True with a non-streaming rule warns, then trains exactly
        like the dense combine (the fallback is honest, not lossy)."""
        train, test = data
        with pytest.warns(RuntimeWarning, match="cannot stream"):
            fused = FederatedTrainer(
                MODEL_ZOO["logreg"], train, test, _env(),
                _proto("stc", "coordinate_median"),
                TrainerConfig(lr=0.05, seed=0, ingest=True))
        assert fused.ingest is False
        dense = FederatedTrainer(
            MODEL_ZOO["logreg"], train, test, _env(),
            _proto("stc", "coordinate_median"),
            TrainerConfig(lr=0.05, seed=0))
        fused.run(2, eval_every=2)
        dense.run(2, eval_every=2)
        _assert_identical(fused, dense)

    def test_streaming_rule_keeps_the_ingest_path(self, data):
        train, test = data
        tr = FederatedTrainer(MODEL_ZOO["logreg"], train, test, _env(),
                              _proto("stc"),
                              TrainerConfig(lr=0.05, seed=0, ingest=True))
        assert tr.ingest is True


# ---------------------------------------------------------------------------
# Byzantine valid-update faults
# ---------------------------------------------------------------------------


class TestByzantineFaults:
    def test_membership_is_deterministic_and_calibrated(self):
        f = SignFlipFault(fraction=0.3)
        ids = np.arange(20000)
        member = np.asarray([f.is_byzantine(int(c)) for c in ids[:200]])
        member2 = np.asarray([f.is_byzantine(int(c)) for c in ids[:200]])
        np.testing.assert_array_equal(member, member2)
        frac = np.mean([f.is_byzantine(int(c)) for c in ids])
        assert abs(frac - 0.3) < 0.02

    def test_fraction_bounds_validated(self):
        with pytest.raises(ValueError, match="fraction"):
            SignFlipFault(fraction=1.5)

    def test_honest_clients_untouched_and_no_rng_draws(self):
        f = SignFlipFault(fraction=0.5)
        honest = next(c for c in range(100) if not f.is_byzantine(c))
        byz = next(c for c in range(100) if f.is_byzantine(c))
        v = np.ones(8, np.float32)
        # rng=None proves the hook consumes NO draws (the determinism
        # contract: inserting the attack must not shift the crash/corrupt
        # fault streams of an existing trace)
        assert f.byzantine(v, honest, None) is v
        np.testing.assert_array_equal(f.byzantine(v, byz, None), -v)

    def test_rewrite_dense_scales(self):
        v = np.asarray([1.0, -2.0, 3.0], np.float32)
        np.testing.assert_array_equal(_rewrite_valid(v, -2.0),
                                      np.asarray([-2.0, 4.0, -6.0]))

    def test_rewrite_stc_wire_stays_valid(self):
        """Sign-flipping an STC stream negates µ only -- the positions and
        length are untouched, so admission control passes by construction
        and the decode is exactly the negated update."""
        p = make_protocol("stc", sparsity_up=0.1, sparsity_down=0.1)
        vec = np.random.default_rng(0).standard_normal(400).astype(np.float32)
        st = p.init_client_state(400)
        msg, _, _ = p.encode(jnp.asarray(vec), st)
        wm = p.encode_wire(np.asarray(msg), direction="up")
        flipped = _rewrite_valid(wm, -1.0)
        p.validate_wire(flipped, direction="up")    # must not raise
        np.testing.assert_allclose(p.decode_wire(flipped, direction="up"),
                                   -np.asarray(msg), rtol=1e-5, atol=1e-7)
        assert flipped.bit_len == wm.bit_len

    def test_rewrite_sign_plane_stays_valid(self):
        """A sign plane has no µ to negate: the attack inverts the plane
        bits; a positive factor (scale attack) cannot scale ±1 symbols and
        leaves the message untouched."""
        p = make_protocol("signsgd")
        vec = np.random.default_rng(1).standard_normal(200).astype(np.float32)
        wm = p.encode_wire(np.sign(vec), direction="up")
        flipped = _rewrite_valid(wm, -1.0)
        p.validate_wire(flipped, direction="up")    # must not raise
        np.testing.assert_allclose(p.decode_wire(flipped, direction="up"),
                                   -p.decode_wire(wm, direction="up"))
        assert _rewrite_valid(wm, 2.0) is wm

    def test_negated_mu_cannot_sneak_past_the_norm_screen(self):
        """StcCodec.wire_norm must report the MAGNITUDE: a Byzantine
        negated-µ stream has the same norm as its honest original."""
        p = make_protocol("stc", sparsity_up=0.1, sparsity_down=0.1)
        vec = np.random.default_rng(2).standard_normal(400).astype(np.float32)
        msg, _, _ = p.encode(jnp.asarray(vec), p.init_client_state(400))
        wm = p.encode_wire(np.asarray(msg), direction="up")
        assert p.wire_norm(_rewrite_valid(wm, -1.0)) == \
            pytest.approx(p.wire_norm(wm))
        assert p.wire_norm(wm) > 0

    def test_collusion_cohort_shares_one_direction(self):
        f = CollusionFault(fraction=0.5, scale=1.0)
        byz = [c for c in range(40) if f.is_byzantine(c)][:2]
        v1 = np.random.default_rng(3).standard_normal(50).astype(np.float32)
        v2 = np.random.default_rng(4).standard_normal(50).astype(np.float32)
        a1 = np.asarray(f.byzantine(v1, byz[0], None))
        a2 = np.asarray(f.byzantine(v2, byz[1], None))
        cos = np.dot(a1, a2) / (np.linalg.norm(a1) * np.linalg.norm(a2))
        assert cos == pytest.approx(1.0, abs=1e-5)   # same direction ...
        assert np.linalg.norm(a1) == pytest.approx(np.linalg.norm(v1),
                                                   rel=1e-5)  # ... own norm

    def test_scale_attack_scales(self):
        f = ScaleAttackFault(fraction=0.5, factor=100.0)
        byz = next(c for c in range(100) if f.is_byzantine(c))
        v = np.ones(4, np.float32)
        np.testing.assert_allclose(f.byzantine(v, byz, None), 100.0 * v)

    def test_median_holds_under_20pct_signflip_mean_collapses(self, data):
        """End-to-end micro version of BENCH_robust's acceptance bar."""
        train, test = data
        env = FedEnvironment(n_clients=20, participation=0.5,
                             classes_per_client=10, batch_size=10)
        accs = {}
        for rname in ("mean", "coordinate_median"):
            tr = EventDrivenTrainer(
                MODEL_ZOO["logreg"], train, test, env,
                make_protocol("baseline", rule=rname),
                TrainerConfig(lr=0.06, seed=0), scenario="steady",
                faults=make_fault("sign-flip", scale=10.0, fraction=0.2))
            hist = tr.run(12, eval_every=12)
            accs[rname] = hist[-1]["acc"]
        assert accs["coordinate_median"] > 0.75
        assert accs["mean"] < 0.4
