"""Unit + property tests for the core STC compression operators."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal deterministic fallback (see the stub)
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.compression import (flatten_pytree, majority_vote_sign,
                                    sign_compress, stc_compress,
                                    stc_compress_pytree,
                                    top_k_mask, top_k_sparsify,
                                    unflatten_pytree)

jax.config.update("jax_platform_name", "cpu")


def _rand(n, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(n),
                       jnp.float32)


class TestTopK:
    def test_mask_keeps_largest(self):
        x = jnp.asarray([0.1, -5.0, 0.3, 2.0, -0.2])
        mask = top_k_mask(x, 2)
        assert list(np.asarray(mask)) == [False, True, False, True, False]

    def test_sparsify_exact_k(self):
        x = _rand(1000)
        out, stats = top_k_sparsify(x, 0.01)
        assert int(stats.nnz) == 10
        kept = np.flatnonzero(np.asarray(out))
        top = np.argsort(-np.abs(np.asarray(x)))[:10]
        assert set(kept) == set(top)
        # kept values unchanged
        np.testing.assert_allclose(np.asarray(out)[kept],
                                   np.asarray(x)[kept])

    def test_k_floor_one(self):
        x = _rand(5)
        out, stats = top_k_sparsify(x, 1e-9)  # np < 1 -> k = 1
        assert int(stats.nnz) == 1

    @given(st.integers(10, 500), st.floats(0.005, 0.5),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_property_topk(self, n, p, seed):
        x = _rand(n, seed)
        out, stats = top_k_sparsify(x, p)
        k = max(int(n * p), 1)
        assert int(stats.nnz) == k  # continuous data: ties measure-zero
        # every kept magnitude >= every dropped magnitude
        a = np.abs(np.asarray(x))
        o = np.asarray(out)
        kept_min = a[np.flatnonzero(o)].min()
        dropped = a[o == 0]
        if dropped.size:
            assert kept_min >= dropped.max() - 1e-7


class TestTernarize:
    def test_algorithm1(self):
        """Exact Algorithm 1 semantics on a hand-computed example."""
        x = jnp.asarray([3.0, -1.0, 0.5, -4.0, 0.1])
        out, stats = stc_compress(x, 0.4)  # k = 2 -> keep 3.0, -4.0
        mu = (3.0 + 4.0) / 2
        np.testing.assert_allclose(np.asarray(out),
                                   [mu, 0.0, 0.0, -mu, 0.0], rtol=1e-6)
        assert float(stats.mu) == pytest.approx(mu)

    @given(st.integers(20, 400), st.floats(0.01, 0.3),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_property_ternary_values(self, n, p, seed):
        x = _rand(n, seed)
        out, stats = stc_compress(x, p)
        o = np.asarray(out)
        mu = float(stats.mu)
        vals = np.unique(o)
        assert all(np.isclose(v, 0.0) or np.isclose(abs(v), mu, rtol=1e-5)
                   for v in vals)
        # sign preserved on kept entries
        kept = np.flatnonzero(o)
        assert np.all(np.sign(o[kept]) == np.sign(np.asarray(x)[kept]))
        # mu == mean magnitude of kept population of the INPUT
        np.testing.assert_allclose(mu, np.abs(np.asarray(x)[kept]).mean(),
                                   rtol=1e-5)

    def test_all_zero_input(self):
        out, stats = stc_compress(jnp.zeros(64), 0.1)
        assert float(jnp.sum(jnp.abs(out))) == 0.0


class TestSign:
    def test_sign_compress(self):
        x = jnp.asarray([1.5, -0.2, 0.0])
        out, _ = sign_compress(x, 0.01)
        np.testing.assert_allclose(np.asarray(out), [0.01, -0.01, 0.0])

    def test_majority_vote(self):
        s = jnp.asarray([[1.0, -1.0], [1.0, 1.0], [-1.0, -1.0]])
        out = majority_vote_sign(s, 0.5)
        np.testing.assert_allclose(np.asarray(out), [0.5, -0.5])


class TestPytree:
    def test_flatten_roundtrip(self):
        tree = {"a": _rand(10, 1).reshape(2, 5),
                "b": [_rand(3, 2), _rand(4, 3).astype(jnp.bfloat16)]}
        vec, spec = flatten_pytree(tree)
        back = unflatten_pytree(vec, spec)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert x.dtype == y.dtype
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32), rtol=1e-2)

    def test_global_topk_across_leaves(self):
        """top-k must compete globally, not per-leaf."""
        tree = {"small": jnp.asarray([0.001, 0.002]),
                "big": jnp.asarray([10.0, 20.0, 30.0, 40.0])}
        out, stats = stc_compress_pytree(tree, 3 / 6)
        assert float(jnp.sum(jnp.abs(out["small"]))) == 0.0
        assert int(jnp.sum(out["big"] != 0)) == 3
