"""Chunked (layer, chunk) codec states: property-based round-trips against
the per-chunk flat oracle for EVERY registered codec, adversarial chunk
boundaries (chunk=1, chunk=numel, ragged tails, empty layers), bit-ledger
equality at chunk=whole-vector, the per-row-k selection primitive, the
chunked tree path, and the trainer-level flat-path bit-identity regression
(the acceptance criterion: chunk=whole reproduces the flat path bit for bit
-- params, measured + analytic ledgers, wire_log)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal deterministic fallback (see the stub)
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import (Codec, chunk_codec, chunk_spec_from_sizes,
                        chunk_spec_from_tree, get_stc_backend, make_protocol,
                        registered_protocols, whole_vector_spec)
from repro.core.chunking import ChunkedCodec
from repro.core.protocols import _REGISTRY
from repro.core.residual import stack_states

# demo-scale hyperparameters so tiny test vectors keep a few non-zeros
DEMO = {"stc": dict(sparsity_up=1 / 8, sparsity_down=1 / 8),
        "topk": dict(sparsity_up=1 / 8)}

# adversarial layer layouts: single layer, empty layer in the middle,
# many tiny layers, ragged everything
LAYOUTS = ([64], [40, 0, 33, 27], [7, 19, 5], [1, 1, 1, 1], [2, 61])
P = 3


def _codec(name: str) -> Codec:
    return make_protocol(name, **DEMO.get(name, {}))


def _spec(layout_idx: int, chunk_mode: int):
    sizes = LAYOUTS[layout_idx % len(LAYOUTS)]
    numel = sum(sizes)
    mode = chunk_mode % 4
    if mode == 0:
        return chunk_spec_from_sizes(sizes, chunk_size=1)        # chunk = 1
    if mode == 1:
        return chunk_spec_from_sizes(sizes, chunk_size=numel)    # chunk=numel
    if mode == 2:
        return chunk_spec_from_sizes(sizes, chunk_size=13)       # ragged
    return whole_vector_spec(numel)                              # flat twin


def _deltas(numel: int, seed: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((P, numel)), jnp.float32)


def _oracle_round(cc: ChunkedCodec, deltas, chunk_states):
    """The flat oracle: the base codec applied to every chunk's UNPADDED
    slice independently.  ``chunk_states`` is a per-chunk list of per-client
    base states (threaded across rounds).  Returns (msgs, states)."""
    spec = cc.spec
    msgs = np.zeros((P, spec.numel), np.float32)
    for ci in range(spec.n_chunks):
        codec = cc.layer_codecs[spec.chunk_layer[ci]]
        s, v = spec.chunk_start[ci], spec.chunk_valid[ci]
        for pi in range(P):
            m, st1, _ = codec.encode(deltas[pi, s:s + v],
                                     chunk_states[ci][pi])
            msgs[pi, s:s + v] = np.asarray(m)
            chunk_states[ci][pi] = st1
    return msgs, chunk_states


def _oracle_aggregate(cc: ChunkedCodec, msgs, server_states, mask, stal):
    spec = cc.spec
    out = np.zeros(spec.numel, np.float32)
    for ci in range(spec.n_chunks):
        codec = cc.layer_codecs[spec.chunk_layer[ci]]
        s, v = spec.chunk_start[ci], spec.chunk_valid[ci]
        g, st1, _ = codec.aggregate(jnp.asarray(msgs[:, s:s + v]),
                                    server_states[ci], mask=mask,
                                    staleness=stal)
        out[s:s + v] = np.asarray(g)
        server_states[ci] = st1
    return out, server_states


def _valid_state_slices(cc: ChunkedCodec, states):
    """Unpadded per-chunk views of a stacked chunked client state."""
    spec = cc.spec
    return [
        jax.tree.map(lambda x, ci=ci, v=spec.chunk_valid[ci]:
                     np.asarray(x)[:, ci, :v], states)
        for ci in range(spec.n_chunks)]


# ---------------------------------------------------------------------------
# the headline property: chunked encode -> wire -> decode -> aggregate is
# the per-chunk flat oracle, for every registered codec
# ---------------------------------------------------------------------------


class TestChunkedVsFlatOracle:
    @given(st.integers(0, len(LAYOUTS) - 1), st.integers(0, 3),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=5, deadline=None)
    def test_roundtrip_matches_oracle(self, layout_idx, chunk_mode, seed):
        # EVERY registered codec (incl. third-party registrations) must obey
        # the per-chunk flat-oracle contract on adversarial boundaries
        for name in sorted(registered_protocols()):
            self._roundtrip_one(name, layout_idx, chunk_mode, seed)

    def _roundtrip_one(self, name, layout_idx, chunk_mode, seed):
        base = _codec(name)
        spec = _spec(layout_idx, chunk_mode)
        cc = chunk_codec(base, spec)
        deltas = _deltas(spec.numel, seed)

        states = stack_states(cc.init_client_state(spec.numel), P)
        oracle_states = [[base.init_client_state(spec.chunk_valid[ci])
                          for _ in range(P)] for ci in range(spec.n_chunks)]
        server = cc.init_server_state(spec.numel)
        oracle_server = [base.init_server_state(spec.chunk_valid[ci])
                         for ci in range(spec.n_chunks)]

        mask = jnp.asarray([1.0, 0.0, 1.0])
        stal = jnp.asarray([0.0, 0.0, 2.0])
        for rnd in range(2):            # two rounds: states must thread
            d = deltas if rnd == 0 else deltas * 0.5
            msgs, states, _ = cc.encode_batch(d, states)
            msgs = np.asarray(msgs)
            o_msgs, oracle_states = _oracle_round(cc, np.asarray(d),
                                                  oracle_states)
            np.testing.assert_allclose(msgs, o_msgs, rtol=1e-6, atol=1e-7)

            if cc.wire_format:          # wire round-trip is exact
                batch = cc.encode_wire_batch(msgs, direction="up")
                dec = cc.decode_wire_batch(batch, direction="up")
                np.testing.assert_allclose(dec, msgs, rtol=1e-6, atol=0)
                assert cc.measured_batch_bits(batch) >= 0.0

            g, server, _ = cc.aggregate(jnp.asarray(msgs), server,
                                        mask=mask, staleness=stal)
            o_g, oracle_server = _oracle_aggregate(cc, o_msgs, oracle_server,
                                                   mask, stal)
            np.testing.assert_allclose(np.asarray(g), o_g,
                                       rtol=1e-6, atol=1e-7)

        # client codec state threads identically (unpadded region)
        if states is not None:
            for ci, sl in enumerate(_valid_state_slices(cc, states)):
                for pi in range(P):
                    np.testing.assert_allclose(
                        np.asarray(jax.tree.leaves(sl)[0][pi])
                        if jax.tree.leaves(sl) else 0.0,
                        np.asarray(jax.tree.leaves(
                            oracle_states[ci][pi])[0])
                        if jax.tree.leaves(oracle_states[ci][pi]) else 0.0,
                        rtol=1e-6, atol=1e-7)

    @pytest.mark.parametrize("name", sorted(["baseline", "fedavg", "signsgd",
                                             "topk", "stc", "ternquant"]))
    def test_bit_ledger_equality_at_whole_vector(self, name):
        base = _codec(name)
        numel = 96
        cc = chunk_codec(base, whole_vector_spec(numel))
        assert cc.upload_bits(numel) == base.upload_bits(numel)
        for npart in (1, 4):
            assert cc.download_bits(numel, n_participating=npart) == \
                base.download_bits(numel, n_participating=npart)
        if not base.wire_format:
            return
        msgs, _, _ = cc.encode_batch(
            _deltas(numel, 7), stack_states(cc.init_client_state(numel), P))
        msgs = np.asarray(msgs)
        assert cc.measured_batch_bits(cc.encode_wire_batch(msgs)) == \
            base.measured_batch_bits(base.encode_wire_batch(msgs))
        m1, b1 = cc.encode_wire(msgs[0]), base.encode_wire(msgs[0])
        assert cc.measured_message_bits(m1) == base.measured_message_bits(b1)
        assert m1.nnz == b1.nnz and m1.bit_len == b1.bit_len
        assert cc.wire_bound_bits(numel, m1.nnz) == \
            base.wire_bound_bits(numel, b1.nnz)


# ---------------------------------------------------------------------------
# ChunkSpec geometry
# ---------------------------------------------------------------------------


class TestChunkSpec:
    @given(st.integers(0, len(LAYOUTS) - 1), st.integers(1, 70),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_split_merge_roundtrip(self, layout_idx, chunk_size, seed):
        sizes = LAYOUTS[layout_idx % len(LAYOUTS)]
        spec = chunk_spec_from_sizes(sizes, chunk_size=chunk_size)
        x = np.random.default_rng(seed).standard_normal(
            (2, spec.numel)).astype(np.float32)
        blocks = spec.split(x)
        assert blocks.shape == (2, spec.n_chunks, spec.chunk_numel)
        # padding is exactly zero
        assert np.all(blocks[:, ~spec.valid_mask()] == 0.0) \
            if (~spec.valid_mask()).any() else True
        np.testing.assert_array_equal(spec.merge(blocks), x)
        # jnp view agrees
        np.testing.assert_array_equal(
            np.asarray(spec.merge(spec.split(jnp.asarray(x)))), x)

    def test_layer_boundaries_never_crossed(self):
        spec = chunk_spec_from_sizes([10, 0, 7], chunk_size=4)
        for ci in range(spec.n_chunks):
            li = spec.chunk_layer[ci]
            layer_start = sum(spec.layer_sizes[:li])
            s, v = spec.chunk_start[ci], spec.chunk_valid[ci]
            assert layer_start <= s
            assert s + v <= layer_start + spec.layer_sizes[li]
        assert spec.n_chunks == 3 + 2          # 10 -> 4+4+2, 0 -> none, 7 -> 4+3
        assert sum(spec.chunk_valid) == spec.numel == 17

    def test_whole_vector_spec_is_whole(self):
        spec = whole_vector_spec(33)
        assert spec.is_whole_vector() and spec.n_chunks == 1
        assert not chunk_spec_from_sizes([20, 13], chunk_size=16
                                         ).is_whole_vector()

    def test_chunk_ks_clamps_to_one(self):
        spec = chunk_spec_from_sizes([5, 3], chunk_size=2)
        ks = spec.chunk_ks(1e-6)
        assert np.all(ks == 1)
        ks2 = spec.chunk_ks([0.5] * spec.n_chunks)
        np.testing.assert_array_equal(
            ks2, np.maximum(np.asarray(spec.chunk_valid) // 2, 1))

    def test_from_tree_matches_flatten_order(self):
        tree = {"a": jnp.zeros((3, 4)), "b": jnp.zeros((0,)),
                "c": jnp.zeros((5,))}
        spec = chunk_spec_from_tree(tree, chunk_size=6)
        assert spec.numel == 17
        assert len(spec.layer_names) == 3 and spec.layer_sizes == (12, 0, 5)

    def test_rejects_bad_inputs(self):
        with pytest.raises(ValueError, match="chunk_size"):
            chunk_spec_from_sizes([4], chunk_size=0)
        with pytest.raises(ValueError, match="non-empty"):
            chunk_spec_from_sizes([0, 0], chunk_size=4)


# ---------------------------------------------------------------------------
# the per-row-k selection primitive behind the registry
# ---------------------------------------------------------------------------


class TestSelectBatch:
    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_backends_agree_per_row_k(self, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((5, 64)), jnp.float32)
        ks = rng.integers(1, 64, size=5)
        tj, cj, sj = get_stc_backend("jnp").select_batch(x, ks)
        tk, ck, sk = get_stc_backend("kernel").select_batch(x, ks)
        np.testing.assert_array_equal(np.asarray(tj), np.asarray(tk))
        np.testing.assert_array_equal(np.asarray(cj), np.asarray(ck))
        np.testing.assert_allclose(np.asarray(sj), np.asarray(sk), rtol=1e-5)
        # the threshold IS the k-th largest magnitude, per row
        a = np.abs(np.asarray(x))
        for b in range(5):
            assert float(tj[b]) == float(np.sort(a[b])[::-1][ks[b] - 1])
            assert int(cj[b]) >= ks[b]          # ties kept

    def test_rejects_out_of_range_k(self):
        x = jnp.ones((2, 8))
        with pytest.raises(ValueError, match="out of range"):
            get_stc_backend("jnp").select_batch(x, [0, 3])


# ---------------------------------------------------------------------------
# chunked tree path (the mesh trainer's selection)
# ---------------------------------------------------------------------------


class TestChunkedTree:
    def _tree(self, seed=0):
        rng = np.random.default_rng(seed)
        return {"w": jnp.asarray(rng.standard_normal((10, 7)), jnp.float32),
                "b": jnp.asarray(rng.standard_normal((13,)), jnp.float32),
                "e": jnp.zeros((0,), jnp.float32)}

    def test_matches_per_chunk_flat_oracle(self):
        from repro.core.compression import stc_compress
        from repro.core.distributed import stc_compress_tree_chunked
        tree = self._tree()
        tern, stats = stc_compress_tree_chunked(tree, 1 / 5, chunk_size=16)
        for name, leaf in tree.items():
            flat = np.asarray(leaf, np.float32).reshape(-1)
            out = np.zeros_like(flat)
            for s in range(0, flat.size, 16):
                sl = flat[s:s + 16]
                m, _ = stc_compress(jnp.asarray(sl), 1 / 5)
                out[s:s + 16] = np.asarray(m)
            np.testing.assert_array_equal(out.reshape(leaf.shape),
                                          np.asarray(tern[name]))
        assert int(stats.nnz) > 0

    def test_p_fn_schedule_rescales_layers(self):
        from repro.core.distributed import stc_compress_tree_chunked
        tree = self._tree()
        _, base = stc_compress_tree_chunked(tree, 1 / 5, chunk_size=16)
        _, dense = stc_compress_tree_chunked(
            tree, 1 / 5, chunk_size=16,
            p_fn=lambda name, depth: 1.0 if "w" in name else None)
        assert int(dense.nnz) > int(base.nnz)

    def test_codec_tree_hooks_use_chunking(self):
        codec = make_protocol("stc", sparsity_up=1 / 5, sparsity_down=1 / 5,
                              chunk_size=16)
        tree = self._tree()
        res = jax.tree.map(jnp.zeros_like, tree)
        msg, new_res, m = codec.tree_encode(tree, res, numel=83)
        # error feedback: carried - msg
        for k in tree:
            np.testing.assert_allclose(
                np.asarray(new_res[k]),
                np.asarray(tree[k]) - np.asarray(msg[k]), rtol=1e-6)
        gd, _, md = codec.tree_decode(
            codec.tree_reduce(msg, (), 1), res, numel=83)
        assert int(m["nnz_up"]) > 0 and int(md["nnz_down"]) > 0


# ---------------------------------------------------------------------------
# wrapper contract
# ---------------------------------------------------------------------------


class TestChunkedCodecContract:
    def test_forwards_base_knobs(self):
        base = make_protocol("fedavg")
        cc = chunk_codec(base, whole_vector_spec(10))
        assert cc.local_iters == base.local_iters == 400
        assert cc.wire_format == base.wire_format
        assert cc.error_feedback == base.error_feedback

    def test_rejects_double_wrap_and_legacy(self):
        cc = chunk_codec(_codec("stc"), whole_vector_spec(10))
        with pytest.raises(TypeError, match="already-chunked"):
            chunk_codec(cc, whole_vector_spec(10))

        # a pre-mask 2-arg aggregate can no longer even be DEFINED, so
        # chunk_codec never sees one
        with pytest.raises(TypeError, match="masked aggregation API"):
            @dataclasses.dataclass(frozen=True)
            class LegacyAgg(Codec):
                name = "legacy-agg-chunk-test"

                def aggregate(self, msgs, server_state):    # pre-mask
                    return jnp.mean(msgs, axis=0), server_state, None

        assert "legacy-agg-chunk-test" not in _REGISTRY

    def test_p_fn_builds_per_layer_codecs(self):
        spec = chunk_spec_from_sizes([16, 16], names=["dense", "embed"],
                                     chunk_size=8)
        cc = chunk_codec(_codec("stc"), spec,
                         p_fn=lambda name, d: 0.5 if name == "embed" else None)
        assert cc.layer_codecs[0].sparsity_up == pytest.approx(1 / 8)
        assert cc.layer_codecs[1].sparsity_up == 0.5
        # per-chunk ks follow the schedule
        ks = cc.spec.chunk_ks(cc._chunk_ps("up"))
        np.testing.assert_array_equal(ks, [1, 1, 4, 4])


# ---------------------------------------------------------------------------
# THE acceptance regression: chunk=whole-vector trainers == flat trainers,
# bit for bit (params, measured + analytic ledgers, wire_log)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["stc", "signsgd"])
def test_trainer_whole_vector_chunking_is_flat_path(name):
    from repro.data import make_classification
    from repro.fed import FedEnvironment, FederatedTrainer, TrainerConfig
    from repro.models.paper_models import MODEL_ZOO

    train, test = make_classification(seed=0, n=600, n_test=120)
    env = FedEnvironment(n_clients=6, participation=0.5,
                         classes_per_client=2, batch_size=10)
    kw = {"stc": dict(sparsity_up=1 / 20, sparsity_down=1 / 20)}
    rounds = 3
    flat = FederatedTrainer(MODEL_ZOO["logreg"], train, test, env,
                            make_protocol(name, **kw.get(name, {})),
                            TrainerConfig(lr=0.05, seed=0))
    flat.run(rounds, eval_every=rounds)
    chunked = FederatedTrainer(MODEL_ZOO["logreg"], train, test, env,
                               make_protocol(name, **kw.get(name, {})),
                               TrainerConfig(lr=0.05, seed=0, chunks="whole"))
    chunked.run(rounds, eval_every=rounds)

    np.testing.assert_array_equal(np.asarray(flat.params_vec),
                                  np.asarray(chunked.params_vec))
    assert flat.bits_up == chunked.bits_up
    assert flat.bits_down == chunked.bits_down
    assert flat.bits_up_analytic == chunked.bits_up_analytic
    assert flat.bits_down_analytic == chunked.bits_down_analytic
    assert flat.wire_log == chunked.wire_log
    for hf, hc in zip(flat.history, chunked.history):
        assert hf == hc


def test_trainer_multi_chunk_trains_and_ledger_counts_headers():
    from repro.data import make_classification
    from repro.fed import FedEnvironment, FederatedTrainer, TrainerConfig
    from repro.models.paper_models import MODEL_ZOO

    train, test = make_classification(seed=0, n=600, n_test=120)
    env = FedEnvironment(n_clients=6, participation=0.5,
                         classes_per_client=2, batch_size=10)
    tr = FederatedTrainer(
        MODEL_ZOO["logreg"], train, test, env,
        make_protocol("stc", sparsity_up=1 / 20, sparsity_down=1 / 20),
        TrainerConfig(lr=0.05, seed=0, chunks=32,
                      p_fn=lambda name, d: 1 / 10 if "b" in name else None))
    hist = tr.run(3, eval_every=3)
    assert np.all(np.isfinite(np.asarray(tr.params_vec)))
    assert hist[-1]["acc"] > 0.0
    assert tr.bits_up > 0 and tr.bits_down > 0
    # every chunk pays its own 32-bit µ header in the measured ledger
    n_chunks = tr.protocol.spec.n_chunks
    assert n_chunks > 1
    for row in tr.wire_log:
        assert row["bits_up_bound"] is None or \
            row["bits_up"] <= row["bits_up_bound"]
