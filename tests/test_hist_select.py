"""Histogram k-selection: exactness vs the lax.top_k oracle on adversarial
inputs, batched-vs-per-client equivalence, streaming-pass budget, and the
three-way (kernel / jnp operator / tree) oracle agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import flatten_pytree, stc_compress
from repro.core.distributed import stc_compress_tree
from repro.kernels import (PASSES, hist_topk_threshold,
                           hist_topk_threshold_batched, magnitude_histogram,
                           magnitude_histogram_batched, stc_compress_batch,
                           stc_compress_kernel, topk_threshold)
from repro.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")

SHAPES = [64, 1000, 4096, 100_003]   # incl. n not a multiple of block*128


def _rand(n, seed=0, scale=1.0):
    x = np.random.default_rng(seed).standard_normal(n) * scale
    return jnp.asarray(x, jnp.float32)


def _sort_oracle(x, k):
    """(v_k, count, sum) with lax.top_k semantics: mask = |x| >= kth value."""
    a = np.abs(np.asarray(x, np.float32))
    vk = np.sort(a)[-k]
    m = a >= vk
    return vk, int(m.sum()), float(a[m].sum())


class TestHistogramKernel:
    @pytest.mark.parametrize("n", SHAPES)
    def test_vs_ref(self, n):
        x = _rand(n, seed=n)
        a_max = jnp.max(jnp.abs(x))
        scale = jnp.float32(256.0) / a_max
        cnt_k, sum_k = magnitude_histogram(x, scale, block_rows=64)
        cnt_r, sum_r = kref.magnitude_histogram_ref(x, scale)
        np.testing.assert_array_equal(np.asarray(cnt_k), np.asarray(cnt_r))
        np.testing.assert_allclose(np.asarray(sum_k), np.asarray(sum_r),
                                   rtol=1e-5)
        assert int(jnp.sum(cnt_k)) == n   # padding must not leak into bin 0

    def test_batched_vs_single(self):
        xs = jnp.stack([_rand(4096, seed=i, scale=1 + i) for i in range(4)])
        a_max = jnp.max(jnp.abs(xs), axis=1)
        scale = jnp.float32(256.0) / a_max
        cnt_b, sum_b = magnitude_histogram_batched(xs, scale, block_rows=16)
        for i in range(4):
            cnt_i, sum_i = magnitude_histogram(xs[i], scale[i], block_rows=16)
            np.testing.assert_array_equal(np.asarray(cnt_b[i]),
                                          np.asarray(cnt_i))
            np.testing.assert_allclose(np.asarray(sum_b[i]),
                                       np.asarray(sum_i), rtol=1e-5)


class TestBlockHistChunking:
    def test_chunked_equals_single_shot(self):
        """The compiled-mode (VMEM-bounded) chunked one-hot accumulation must
        equal the interpret-mode single-shot block histogram."""
        from repro.kernels.hist_select import _block_hist
        rng = np.random.default_rng(9)
        rows, lane, bins = 64, 128, 256
        a = jnp.asarray(np.abs(rng.standard_normal((rows, lane))), jnp.float32)
        idx = jnp.clip((a * 80.0).astype(jnp.int32), 0, bins - 1)
        valid = jnp.asarray(rng.random((rows, lane)) < 0.9)
        cnt_1, sum_1 = _block_hist(a, idx, valid, bins=bins, chunk_rows=rows)
        cnt_c, sum_c = _block_hist(a, idx, valid, bins=bins, chunk_rows=8)
        np.testing.assert_array_equal(np.asarray(cnt_1), np.asarray(cnt_c))
        np.testing.assert_allclose(np.asarray(sum_1), np.asarray(sum_c),
                                   rtol=1e-5)


class TestExactSelection:
    @pytest.mark.parametrize("n", SHAPES)
    @pytest.mark.parametrize("p", [0.001, 0.01, 0.1])
    def test_matches_sort_oracle(self, n, p):
        x = _rand(n, seed=n + int(p * 1e4))
        k = max(int(n * p), 1)
        t, cnt, s = hist_topk_threshold(x, k, block_rows=64)
        vk, cnt_o, sum_o = _sort_oracle(x, k)
        assert np.float32(t) == np.float32(vk)   # EXACT kth magnitude
        assert int(cnt) == cnt_o
        np.testing.assert_allclose(float(s), sum_o, rtol=1e-5)

    @pytest.mark.parametrize("cap", [64, 8192])
    def test_heavy_ties_at_threshold(self, cap):
        """Half the entries tie at the kth magnitude: mask must keep all ties
        (lax.top_k >= semantics), through the exact path AND the overflow
        fallback (cap=64 forces it)."""
        rng = np.random.default_rng(0)
        n, k = 4000, 100
        vals = np.where(rng.random(n) < 0.5, 1.0,
                        rng.uniform(0.0, 0.5, n)).astype(np.float32)
        x = jnp.asarray(vals * np.sign(rng.standard_normal(n)))
        t, cnt, s = hist_topk_threshold(x, k, block_rows=8, cap=cap)
        vk, cnt_o, sum_o = _sort_oracle(x, k)
        assert np.float32(t) == np.float32(vk) == np.float32(1.0)
        assert int(cnt) == cnt_o == int((vals == 1.0).sum())
        np.testing.assert_allclose(float(s), sum_o, rtol=1e-5)

    def test_all_zero_vector(self):
        x = jnp.zeros(5000, jnp.float32)
        t, cnt, s = hist_topk_threshold(x, 50, block_rows=8)
        assert float(t) == 0.0 and float(s) == 0.0
        tern, res, mu, _, _ = stc_compress_kernel(x, x, 0.01, block_rows=8)
        assert float(mu) == 0.0
        np.testing.assert_array_equal(np.asarray(tern), 0.0)
        np.testing.assert_array_equal(np.asarray(res), 0.0)

    def test_extreme_dynamic_range(self):
        """Magnitudes spanning 1e-30..1e30 concentrate nearly everything in
        histogram bin 0; selection must stay exact via the fallback."""
        rng = np.random.default_rng(7)
        n = 20_000
        mags = 10.0 ** rng.uniform(-30, 30, n)
        x = jnp.asarray(mags * np.sign(rng.standard_normal(n)), jnp.float32)
        for k in (37, 5000, 19_000):
            t, cnt, s = hist_topk_threshold(x, k, block_rows=16)
            vk, cnt_o, sum_o = _sort_oracle(x, k)
            assert np.float32(t) == np.float32(vk), k
            assert int(cnt) == cnt_o, k
            np.testing.assert_allclose(float(s), sum_o, rtol=1e-4)

    def test_single_spike(self):
        """k=1 with one dominant value."""
        x = jnp.zeros(3000, jnp.float32).at[1234].set(-7.5)
        t, cnt, _ = hist_topk_threshold(x, 1, block_rows=8)
        assert float(t) == 7.5 and int(cnt) == 1


class TestBatchedSelection:
    def test_batched_vs_per_client(self):
        """One (client, block)-grid launch == independent per-client calls."""
        rng = np.random.default_rng(3)
        B, n, k = 6, 4096, 41
        xs = jnp.asarray(rng.standard_normal((B, n)) *
                         (1 + np.arange(B))[:, None], jnp.float32)
        tb, cb, sb = hist_topk_threshold_batched(xs, k, block_rows=16)
        for i in range(B):
            ti, ci, si = hist_topk_threshold(xs[i], k, block_rows=16)
            assert np.float32(tb[i]) == np.float32(ti)
            assert int(cb[i]) == int(ci)
            np.testing.assert_allclose(float(sb[i]), float(si), rtol=1e-5)

    def test_batched_mixed_overflow(self):
        """Rows that overflow the gather cap (ties) next to rows that don't:
        the per-row fallback mix must stay exact for every row."""
        rng = np.random.default_rng(4)
        n, k = 3000, 64
        tied = np.where(rng.random(n) < 0.5, 2.0,
                        rng.uniform(0, 1, n)).astype(np.float32)
        smooth = rng.standard_normal(n).astype(np.float32)
        xs = jnp.asarray(np.stack([tied, smooth]))
        tb, cb, sb = hist_topk_threshold_batched(xs, k, block_rows=8, cap=128)
        for i in range(2):
            vk, cnt_o, sum_o = _sort_oracle(xs[i], k)
            assert np.float32(tb[i]) == np.float32(vk), i
            assert int(cb[i]) == cnt_o, i
            np.testing.assert_allclose(float(sb[i]), sum_o, rtol=1e-5)

    def test_compress_batch_vs_single(self):
        rng = np.random.default_rng(5)
        B, n = 4, 8192
        ds = jnp.asarray(rng.standard_normal((B, n)), jnp.float32)
        rs = jnp.asarray(rng.standard_normal((B, n)) * 0.1, jnp.float32)
        tb, rb, mb, thb, cb = stc_compress_batch(ds, rs, 0.01, block_rows=16)
        for i in range(B):
            ti, ri, mi, thi, ci = stc_compress_kernel(ds[i], rs[i], 0.01,
                                                      block_rows=16)
            np.testing.assert_allclose(np.asarray(tb[i]), np.asarray(ti),
                                       atol=1e-6)
            np.testing.assert_allclose(np.asarray(rb[i]), np.asarray(ri),
                                       atol=1e-6)
            assert int(cb[i]) == int(ci)


class TestStreamingPassBudget:
    """Acceptance: ≤3 streaming passes per selection vs 33 for bisection."""

    def test_hist_passes(self):
        x = _rand(65_536, seed=11)
        PASSES.reset()
        hist_topk_threshold(x, 655, block_rows=64)
        assert PASSES.total() <= 3, PASSES.counts
        # on CPU the small-k shortcut does it in ONE gather pass
        assert PASSES.counts == {"topk_gather": 1}

    def test_hist_passes_general_path(self):
        """cap < k forces the histogram route: exactly max+histogram+refine."""
        x = _rand(65_536, seed=15)
        PASSES.reset()
        t, cnt, _ = hist_topk_threshold(x, 655, block_rows=64, cap=64)
        assert PASSES.counts == {"max": 1, "histogram": 1, "refine": 1}
        vk, cnt_o, _ = _sort_oracle(x, 655)
        assert np.float32(t) == np.float32(vk) and int(cnt) == cnt_o

    def test_hist_batched_passes(self):
        xs = jnp.stack([_rand(8192, seed=i) for i in range(3)])
        PASSES.reset()
        hist_topk_threshold_batched(xs, 81, block_rows=16)
        assert PASSES.total() <= 3, PASSES.counts

    def test_bisect_passes(self):
        x = _rand(65_536, seed=12)
        PASSES.reset()
        topk_threshold(x, 655, block_rows=64)
        assert PASSES.total() == 33, PASSES.counts

    def test_tree_passes(self):
        tree = {"w": _rand(65_536, seed=13), "b": _rand(1000, seed=14)}
        PASSES.reset()
        stc_compress_tree(tree, 0.01)
        assert PASSES.total() <= 3, PASSES.counts


class TestTreeForcedPaths:
    """On CPU every default-cap tree call takes the small-k shortcut; force
    the TPU-route branches (histogram sweep + refine, bisection fallback)
    with a small ``cap`` so they stay covered."""

    def _tree(self, seed=21):
        rng = np.random.default_rng(seed)
        return {
            "w": jnp.asarray(rng.standard_normal(100_000), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((40, 25)), jnp.float32),
        }

    def test_histogram_refine_branch(self):
        """cap < k skips the shortcut; the candidate bin (~n/256 · density)
        still fits the gather, so histogram + exact refine runs."""
        tree = self._tree()
        p = 0.02                                  # k = 2020 > cap
        PASSES.reset()
        tern_t, st = stc_compress_tree(tree, p, cap=1000)
        assert PASSES.counts == {"max": 1, "histogram": 1, "refine": 1}
        vec, _ = flatten_pytree(tree)
        tern_j, stats_j = stc_compress(vec, p)
        got, _ = flatten_pytree(tern_t)
        assert int(st.nnz) == int(stats_j.nnz)
        np.testing.assert_allclose(float(st.mu), float(stats_j.mu), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(tern_j),
                                   atol=1e-6)

    def test_bisection_fallback_branch(self):
        """cap tiny -> candidate bin overflows the gather -> bisection."""
        tree = self._tree(22)
        p = 0.02
        tern_t, st = stc_compress_tree(tree, p, cap=8)
        vec, _ = flatten_pytree(tree)
        tern_j, stats_j = stc_compress(vec, p)
        got, _ = flatten_pytree(tern_t)
        assert int(st.nnz) == int(stats_j.nnz)
        np.testing.assert_allclose(np.asarray(got), np.asarray(tern_j),
                                   atol=1e-6)


class TestThreeWayOracle:
    """Acceptance: kernel path, stc_compress (jnp), and stc_compress_tree
    agree on (masked nnz, µ, ternary output) on randomized pytrees."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("p", [0.005, 0.02, 0.1])
    def test_agreement(self, seed, p):
        rng = np.random.default_rng(seed)
        tree = {
            "w": jnp.asarray(rng.standard_normal((129, 33)), jnp.float32),
            "layers": [jnp.asarray(rng.standard_normal(517), jnp.float32),
                       jnp.asarray(rng.standard_normal((3, 111)) * 5,
                                   jnp.float32)],
        }
        vec, _ = flatten_pytree(tree)

        tern_j, stats_j = stc_compress(vec, p)
        tern_k, _, mu_k, _, nnz_k = stc_compress_kernel(
            vec, jnp.zeros_like(vec), p, block_rows=8)
        tern_t, stats_t = stc_compress_tree(tree, p)
        tern_t_flat, _ = flatten_pytree(tern_t)

        assert int(nnz_k) == int(stats_j.nnz) == int(stats_t.nnz)
        np.testing.assert_allclose(float(mu_k), float(stats_j.mu), rtol=1e-5)
        np.testing.assert_allclose(float(stats_t.mu), float(stats_j.mu),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(tern_k), np.asarray(tern_j),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(tern_t_flat),
                                   np.asarray(tern_j), atol=1e-6)
